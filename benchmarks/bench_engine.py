"""Event-kernel benchmark: the simulator measuring itself.

Two synthetic workloads, each run through the PRE-PR kernel ("legacy":
the seed's single binary heap with every arrival pushed upfront) and the
fast path ("fast": calendar-queue scheduler + lazily merged arrival
stream):

  kernel   the event loop alone — N mostly-monotone arrivals where every
           64th handler schedules an out-of-band completion, i.e. the
           push pattern pools generate, with no pool work attached. This
           isolates scheduler + dispatch cost and is where the >= 5x
           headline is measured.
  system   a full ServingSystem (2 pools, autoscaling, admission,
           batching) under Poisson traffic — how much of the end-to-end
           wall clock the kernel win actually buys back.

Each (workload, mode, n) cell runs in its OWN subprocess so peak RSS
(resource.ru_maxrss) is attributable to that cell — the legacy mode's
N-tuple heap shows up as resident memory the streamed mode never
allocates.

`--smoke` keeps the 100k and 1M kernel cells (the 1M run IS the CI
criterion) but shrinks the system horizon, asserts events/sec floors,
and a CONSERVATIVE speedup floor (the demonstrated speedup is >= 5x;
the floor is set low enough to survive noisy shared CI runners).
`--json PATH` dumps every cell as a perf artifact (BENCH_engine.json)
so the kernel's own perf trajectory is tracked alongside
BENCH_serving.json.
"""
# simlint: disable=SL001  (benchmarks time REAL work: the wall
# clock IS the measurement here, never the simulated clock)
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time

# the kernel cells need no jax and no benchmarks.common — keep it that way
from repro.core.serving.events import EventLoop

SMOKE_SPEEDUP_FLOOR = 2.5  # conservative CI floor; demonstrated >= 5x
SMOKE_EVENTS_PER_S_FLOOR = 200_000.0  # fast kernel mode, 1M arrivals


# ---------------------------------------------------------------------------
# workloads (run inside the worker subprocess)
# ---------------------------------------------------------------------------


def kernel_cell(mode: str, n: int) -> dict:
    """The loop alone: n arrivals 10us apart; every 64th arrival's
    handler pushes a completion 2ms out (an out-of-band push landing in
    the calendar's current window). legacy = heap scheduler + one pushed
    tuple per arrival (the pre-PR kernel, bit-for-bit); fast = calendar
    scheduler + lazy arrival stream."""
    loop = EventLoop(scheduler="heap" if mode == "legacy" else "calendar")

    def on_arrive(t, payload):
        if not (payload & 63):
            loop.push(t + 0.002, "done", payload)

    loop.on("arrive", on_arrive)
    loop.on("done", lambda t, p: None)

    dt = 1e-5
    t0 = time.perf_counter()
    if mode == "legacy":
        for i in range(n):
            loop.push(i * dt, "arrive", i)
    else:
        loop.add_stream("arrive", ((i * dt, i) for i in range(n)))
    loop.run()
    wall = time.perf_counter() - t0
    return {"events": loop.processed, "wall_s": wall}


def system_cell(mode: str, n: int) -> dict:
    """Full serving stack under Poisson traffic sized to ~n arrivals.
    legacy reproduces the pre-PR ServingSystem.run: heap scheduler and
    every arrival pushed upfront; fast is the shipped run() path."""
    from repro.core.serving.engine import (
        PoolSpec, ServingSystem, poisson_arrivals,
    )
    from repro.core.serving.pool import PoolConfig
    from repro.core.serving.replica import LatencyModel, ReplicaSpec

    rate = 2000.0
    horizon = n / rate
    arrivals = poisson_arrivals(lambda t: rate, horizon, seed=0)
    spec = ReplicaSpec("bench", LatencyModel.analytic(0.004, 1.5e-4),
                       cold_start_s=5.0, warm_start_s=0.2)
    pools = {
        name: PoolSpec(spec, PoolConfig(n_replicas=2, max_batch=64,
                                        max_wait_s=0.005))
        for name in ("a", "b")
    }
    sys_ = ServingSystem(pools, slo_p99_s=0.15, capacity=16,
                         scheduler="heap" if mode == "legacy" else "calendar")
    t0 = time.perf_counter()
    if mode == "legacy":
        # the pre-PR ServingSystem.run, replayed on its public pieces:
        # one pushed heap tuple per arrival, then drain
        for r in arrivals:
            sys_.loop.push(r.t_arrive, "arrive", r)
        sys_.start(horizon)
        sys_.loop.run()
        sys_.summary()
    else:
        sys_.run(arrivals, until=horizon)
    wall = time.perf_counter() - t0
    return {"events": sys_.loop.processed, "wall_s": wall}


WORKLOADS = {"kernel": kernel_cell, "system": system_cell}


def worker(spec: dict) -> dict:
    row = WORKLOADS[spec["workload"]](spec["mode"], spec["n"])
    # Linux reports ru_maxrss in KiB; this is the subprocess's own peak
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    row.update(spec)
    row["peak_rss_mb"] = rss_kb / 1024.0
    row["events_per_s"] = row["events"] / max(row["wall_s"], 1e-9)
    return row


def run_cell(spec: dict) -> dict:
    """One (workload, mode, n) cell in its own interpreter, so each
    cell's peak RSS is its own."""
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker", json.dumps(spec)],
        capture_output=True, text=True, env=os.environ.copy(),
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench cell {spec} failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.splitlines()[-1])


def run(smoke: bool = False) -> list:
    cells = [
        {"workload": "kernel", "mode": mode, "n": n}
        for n in (100_000, 1_000_000)
        for mode in ("legacy", "fast")
    ] + [
        {"workload": "system", "mode": mode, "n": 20_000 if smoke else 200_000}
        for mode in ("legacy", "fast")
    ]
    rows = []
    for spec in cells:
        row = run_cell(spec)
        rows.append(row)
        print(f"{row['workload']},{row['mode']},{row['n']},"
              f"{row['events']},{row['events_per_s']:.0f},"
              f"{row['wall_s']:.2f},{row['peak_rss_mb']:.1f}", flush=True)
    return rows


def speedups(rows: list) -> dict:
    """fast-over-legacy events/sec ratio per (workload, n) pair."""
    by_key = {(r["workload"], r["n"], r["mode"]): r for r in rows}
    out = {}
    for (workload, n, mode) in list(by_key):
        if mode != "fast":
            continue
        legacy = by_key.get((workload, n, "legacy"))
        if legacy:
            out[f"{workload}_{n}"] = (
                by_key[(workload, n, "fast")]["events_per_s"]
                / legacy["events_per_s"])
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: smaller system cell + perf-floor asserts "
                         "(the 100k/1M kernel cells always run)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump every cell as a JSON perf artifact, "
                         "e.g. BENCH_engine.json")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile and print the top-25 "
                         "cumulative table (in-process, no subprocesses: "
                         "RSS numbers are fleet-wide, not per-cell)")
    ap.add_argument("--profile-out", metavar="PATH", default=None,
                    help="also write the FULL cProfile table to this path "
                         "(implies --profile); CI uploads it next to "
                         "BENCH_engine.json so hot-loop profiles diff "
                         "across runs")
    ap.add_argument("--worker", metavar="JSON", default=None,
                    help=argparse.SUPPRESS)  # internal: one cell, then exit
    args = ap.parse_args(argv)
    if args.profile_out:
        args.profile = True

    if args.worker:
        print(json.dumps(worker(json.loads(args.worker))))
        return None

    print("workload,mode,n,events,events_per_s,wall_s,peak_rss_mb")
    if args.profile:
        # profile in-process (subprocess RSS isolation would hide the
        # profile): run each cell's workload directly. Script-mode runs
        # have benchmarks/ itself on sys.path, not the repo root.
        try:
            from benchmarks.profiling import profiled
        except ImportError:
            from profiling import profiled

        rows = profiled(
            lambda: [worker(s) for s in (
                {"workload": "kernel", "mode": "legacy", "n": 100_000},
                {"workload": "kernel", "mode": "fast", "n": 100_000},
            )],
            out=args.profile_out,
        )
        for row in rows:
            print(f"{row['workload']},{row['mode']},{row['n']},"
                  f"{row['events']},{row['events_per_s']:.0f},"
                  f"{row['wall_s']:.2f},{row['peak_rss_mb']:.1f}")
    else:
        rows = run(smoke=args.smoke)

    ratios = speedups(rows)
    for key, ratio in sorted(ratios.items()):
        print(f"speedup_{key}={ratio:.2f}x")

    if args.json:
        # lazy, and jax-free since common.py defers its model imports:
        # the measurement path above must stay import-light either way
        try:
            from benchmarks.common import bench_payload
        except ImportError:
            from common import bench_payload
        payload = bench_payload(
            "engine", rows, smoke=args.smoke,
            row_keys=("workload", "mode", "n", "events_per_s", "peak_rss_mb"),
            speedups=ratios)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"# wrote {len(rows)} cells to {args.json}"
              f" (schema v{payload['schema_version']})")

    if args.smoke and not args.profile:
        fast_1m = next(r for r in rows
                       if r["workload"] == "kernel" and r["n"] == 1_000_000
                       and r["mode"] == "fast")
        assert fast_1m["events_per_s"] >= SMOKE_EVENTS_PER_S_FLOOR, (
            f"fast kernel fell below the events/sec floor: "
            f"{fast_1m['events_per_s']:,.0f} < {SMOKE_EVENTS_PER_S_FLOOR:,.0f}")
        assert ratios["kernel_1000000"] >= SMOKE_SPEEDUP_FLOOR, (
            f"calendar+stream kernel speedup fell below the CI floor: "
            f"{ratios['kernel_1000000']:.2f}x < {SMOKE_SPEEDUP_FLOOR}x")
        print(f"smoke_floors_ok=True (>= {SMOKE_SPEEDUP_FLOOR}x, "
              f">= {SMOKE_EVENTS_PER_S_FLOOR:,.0f} ev/s)")
    return rows


if __name__ == "__main__":
    main()
