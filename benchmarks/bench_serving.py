"""Elastic-scheduling benchmark (paper §IV.B): the five variants under a
traffic spike, autoscaling on/off — latency/throughput/shedding tradeoffs.
Service times from LatencyModels calibrated on the real executables."""
from __future__ import annotations

import jax

from benchmarks.common import VARIANTS, bench_world, serve_batch
from repro.core.serving.engine import ElasticEngine, EngineConfig, poisson_arrivals
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.models.recsys import api

SPIKE = lambda t: 150.0 if t < 10 else (1000.0 if t < 30 else 200.0)


def run() -> list:
    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    arrivals = poisson_arrivals(SPIKE, 45.0, seed=0)
    rows = []
    for name in VARIANTS:
        v = ladder[name]
        fixed = {b: serve_batch(cfg, world, b) for b in (1, 8, 32, 128, 512)}
        jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))

        def call(b):
            jax.block_until_ready(jitted(v["params"], fixed[b]))

        lat = LatencyModel.calibrate(call, reps=2)
        spec = ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2)
        for autoscale in (False, True):
            eng = ElasticEngine(
                spec,
                EngineConfig(n_replicas=2, autoscale=autoscale, slo_p99_s=0.15,
                             max_batch=64),
                tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
            )
            res = eng.run(arrivals, until=45.0)
            rows.append({
                "variant": name, "autoscale": autoscale,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "max_replicas": max(res["trace"]["replicas"]) if res["trace"]["replicas"] else 2,
                "svc_ms_b1": lat(1) * 1e3, "svc_ms_b512": lat(512) * 1e3,
            })
    return rows


def main():
    rows = run()
    print("# elastic serving under a 150->1000 QPS spike")
    print("variant,autoscale,p50_ms,p99_ms,throughput,rejected,max_replicas,svc_ms_b1,svc_ms_b512")
    for r in rows:
        print(f"{r['variant']},{r['autoscale']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['max_replicas']},"
              f"{r['svc_ms_b1']:.2f},{r['svc_ms_b512']:.1f}")
    return rows


if __name__ == "__main__":
    main()
