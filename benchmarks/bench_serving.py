"""Elastic-scheduling benchmark (paper §IV.B) on the multi-pool engine.
Service times come from LatencyModels calibrated on the real jitted
executables of the five Table-I variants (or analytic stand-ins under
--smoke), then four experiments run on the same discrete-event kernel:

  1. single-pool: each variant alone under the spike, autoscaling on/off
     (the pre-refactor table, kept for continuity);
  2. heterogeneous: ALL FIVE variant pools live at once behind each router
     policy (least-loaded / power-of-two / SLO-aware / cost-model),
     pointwise traffic;
  3. cascade: ranking traffic (512 candidates/query) served either by the
     baseline pool alone or as a RecPipe-style cascade — distilled pool
     scores all 512, baseline pool reranks the top-32 — under the SAME
     shared capacity budget and SLO-protected admission;
  4. mixed batching (cost-aware path): 90% pointwise + 10% ranking traffic
     through the five-pool fleet, count-closed batches (max_batch only) vs
     item-closed batches (max_batch_items), for all four router policies;
  5. federation: the same fleet split into 3 cells with skewed sticky
     traffic (60/25/15) at ~80% of fleet capacity — cross-cell spillover
     off vs on. The hot cell is past its local capacity while the fleet
     has headroom; spillover cuts fleet p99 under the cell-local overload
     at equal-or-better fleet throughput, paying only the inter-cell RTT
     per hop.
  7. adaptive control plane (serving/control.py): (a) mis-calibration
     recovery — two identical pools behind the cost-model router, one
     whose OFFLINE latency model is 2x off its true curve; the static
     run misroutes on the stale calibration while the adaptive run's
     OnlineLatencyModel learns the correction from observed service
     times and recovers p99 to within 20% of a correctly-calibrated
     run. (b) SLO-aware batch sizing — a load step served with a static
     max_batch_items vs a BatchSizeController that narrows the item cap
     on SLO breach and widens it under headroom: better p99 at equal
     offered load and throughput. Both runs replay deterministically;
     --smoke asserts all three claims.
  6. caching: Zipf-skewed embedding-id traffic where every MISSED row
     pays an embedding-fetch cost on top of the dense service time
     (memory model, serving/cache.py). Part one sweeps cache capacity x
     eviction policy (lru / lfu / s3fifo, plus a result-cache config on
     repeat-query traffic) on one pool at an offered load past the
     NO-cache fleet's capacity but inside the warm-cache fleet's — warm
     p99 AND throughput are strictly better at equal offered load. Part
     two splits the fleet into 2 cells with DISJOINT hot id sets:
     spillover still rescues the skewed hot cell, but every spilled
     request misses the remote cell's cache cold — the locality /
     spillover tradeoff, visible as a fleet hit-rate drop.

  8. sharded embedding tier (serving/shard.py): (a) a 2-cell fleet whose
     embedding table is sharded across BOTH cells (every cell's misses
     are ~half remote), pool L1s only vs L1s plus the cell-shared L2 —
     at equal offered load the L2 absorbs most of what falls through the
     small L1s, cutting shard-fetch volume (remote fetches strictly
     lower) and p99. (b) online table updates: a standalone system under
     a Poisson stream of versioned row publishes at increasing rates,
     invalidation on vs off — invalidation refetches updated rows (zero
     stale serves, slightly cooler caches); without it staleness climbs
     with the update rate. --smoke asserts (a) and the staleness
     dichotomy of (b).

  9. heterogeneous platform classes (DeepRecSys): a mixed fleet of
     CPU-class pools (low fixed cost, steep per-item curve) and
     accelerator-class pools (high fixed cost, near-flat curve) under
     bimodal pointwise + 512-candidate ranking traffic at fixed offered
     load. Query-size-aware routing (class affinity by size, cost-model
     within the class) vs the size-BLIND cost-model ablation that prices
     every arrival at the pointwise unit — blind routing lands ranking
     batches on the steep CPU curve, the backlog spirals, and throughput
     collapses. --smoke asserts size-aware >= 1.5x the blind router's
     throughput at equal-or-better p99, and that the heterogeneous fleet
     replays bit-identically.

  10. latency waterfall (serving/tracing.py): the experiment-9 routing
     comparison re-read through the always-on attribution layer — every
     completed request's latency decomposes into named components
     (queue wait, replica wait, dense compute, embedding fetches, shard
     transit, inter-cell transit) whose sum equals the end-to-end
     latency bit-exactly. --smoke asserts the size-blind router's
     latency premium is attributed >= majority to the WAIT components:
     misrouting changes where requests queue, not what they compute.

`--smoke` skips calibration (analytic Table-I-shaped latency models) and
shrinks every horizon so CI can run the whole file in seconds.
`--trace-out` / `--metrics-out` additionally run one TRACED federated
run over the sharded embedding tier and write a Perfetto-loadable
Chrome trace (tools/check_trace.py validates it) and/or a Prometheus
text exposition whose conserved counters are asserted against
`federated_rollup` before the file is written.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core.serving.cache import CacheConfig
from repro.core.serving.control import ControlConfig
from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.engine import (
    ElasticEngine, EngineConfig, PoolSpec, ServingSystem, attach_zipf_ids,
    poisson_arrivals,
)
from repro.core.serving.federation import CellSpec, FederatedSystem, assign_homes
from repro.core.serving.metrics import MetricsRegistry, federated_rollup
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec, sustainable_rate
from repro.core.serving.router import make_router
from repro.core.serving.shard import EmbeddingShardService
from repro.core.serving.tracing import COMPONENTS, Tracer
from repro.data.synthetic import bimodal_cost_mix, update_event_stream, zipf_id_stream

def spike(horizon: float):
    """150 -> 1000 QPS spike -> 200, at the same relative times whatever the
    horizon (absolute breakpoints would erase the spike under --smoke)."""
    return lambda t: 150.0 if t < 0.22 * horizon else (
        1000.0 if t < 0.67 * horizon else 200.0)


CANDIDATES, RERANK_K = 512, 32

# Table-I-shaped analytic service curves (base_s, per_item_s) for --smoke:
# same relative ordering as the calibrated variants, no training required.
ANALYTIC = {
    "baseline": (0.020, 1.0e-3),
    "quantized": (0.015, 7.5e-4),
    "pruned": (0.012, 6.0e-4),
    "pruned_quantized": (0.009, 4.5e-4),
    "distilled": (0.004, 1.5e-4),
}

ROUTER_CFGS = (
    ("least_loaded", {}),
    ("power_of_two", {"seed": 0}),
    ("slo_aware", {"slo_p99_s": 0.15,
                   "quality_order": ("baseline", "quantized", "pruned")}),
    ("cost_model", {}),
)


def calibrated_specs() -> dict:
    """ReplicaSpec per Table-I variant, timed on the real executables."""
    import jax

    from benchmarks.common import VARIANTS, bench_world, serve_batch
    from repro.models.recsys import api

    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    fixed = {b: serve_batch(cfg, world, b) for b in (1, 8, 32, 128, 512)}
    specs = {}
    for name in VARIANTS:
        v = ladder[name]
        jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))

        def call(b):
            jax.block_until_ready(jitted(v["params"], fixed[b]))

        lat = LatencyModel.calibrate(call, reps=2)
        specs[name] = ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2)
    return specs


def analytic_specs() -> dict:
    return {
        name: ReplicaSpec(name, LatencyModel.analytic(base, per),
                          cold_start_s=5.0, warm_start_s=0.2)
        for name, (base, per) in ANALYTIC.items()
    }


def single_pool_rows(specs, horizon=45.0) -> list:
    arrivals_for = lambda: poisson_arrivals(spike(horizon), horizon, seed=0)
    rows = []
    for name, spec in specs.items():
        for autoscale in (False, True):
            eng = ElasticEngine(
                spec,
                EngineConfig(n_replicas=2, autoscale=autoscale, slo_p99_s=0.15,
                             max_batch=64),
                tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
            )
            res = eng.run(arrivals_for(), until=horizon)
            rows.append({
                "experiment": "single_pool", "variant": name, "autoscale": autoscale,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "max_replicas": max(res["trace"]["replicas"], default=2),
                "svc_ms_b1": spec.latency(1) * 1e3,
                "svc_ms_b512": spec.latency(512) * 1e3,
            })
    return rows


def heterogeneous_rows(specs, horizon=45.0) -> list:
    """All five variant pools live simultaneously behind one router."""
    rows = []
    for policy, kw in ROUTER_CFGS:
        pools = {
            name: PoolSpec(spec, PoolConfig(n_replicas=1, max_batch=64))
            for name, spec in specs.items()
        }
        sys_ = ServingSystem(
            pools, make_router(policy, **kw),
            tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
            slo_p99_s=0.15, capacity=16,
        )
        res = sys_.run(poisson_arrivals(spike(horizon), horizon, seed=0,
                                        priority_frac=0.05),
                       until=horizon)
        rows.append({
            "experiment": "heterogeneous", "router": policy,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "slo_attainment": res["slo_attainment"],
            "pool_share": {n: p["completed"] for n, p in res["pools"].items()},
        })
    return rows


def cascade_rows(specs, horizon=55.0) -> list:
    """Ranking traffic: baseline-only vs distilled-filter -> baseline-rerank,
    same capacity budget, same admission, same SLO. Each ranking request is
    already a full candidate-set batch, so pools serve one request per call
    (max_batch=1: the calibrated regime — co-batching several 512-candidate
    queries would push service time into extrapolation territory where the
    CPU-calibrated variants converge). The spike is scaled to the CALIBRATED
    capacity of the baseline-only fleet (0.4x off-peak, 1.15x during the
    spike) so the experiment stresses the same relative operating point on
    any host: just past what baseline-only can sustain, inside what the
    cascade can."""
    from repro.core.serving.autoscaler import ScalerConfig

    budget = 8
    t_base = specs["baseline"].latency(CANDIDATES)  # s per ranking request
    cap_base = budget / t_base  # req/s of the baseline-only fleet
    spike_window = (0.2 * horizon, 0.72 * horizon)  # relative, horizon-proof
    rate = lambda t: (1.15 * cap_base
                      if spike_window[0] <= t < spike_window[1] else 0.4 * cap_base)
    tiers = lambda: {"tier0": TierPolicy(1e9, 1e9), "tier1": TierPolicy(1e9, 1e9)}
    pcfg = lambda n: PoolConfig(n_replicas=n, max_batch=1, priority_bypass=False)
    rows = []

    base_sys = ServingSystem(
        {"baseline": PoolSpec(specs["baseline"], pcfg(2))},
        tiers=tiers(), slo_p99_s=4 * t_base, capacity=budget,
    )
    res = base_sys.run(
        poisson_arrivals(rate, horizon, seed=0, cost=CANDIDATES, priority_frac=0.0),
        until=horizon)
    rows.append({"experiment": "cascade", "mode": "baseline_only",
                 "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                 "throughput": res["throughput"], "rejected": res["rejected"],
                 "slo_attainment": res["slo_attainment"]})

    casc_sys = ServingSystem(
        {
            # the filter stage does ~all the work: deploy it wide from the
            # start; the rerank stage sees RERANK_K items/query and needs a
            # small share of the budget, so it starts (and shrinks back) to 1
            "distilled": PoolSpec(specs["distilled"], pcfg(4)),
            "baseline": PoolSpec(specs["baseline"], pcfg(1),
                                 ScalerConfig(min_replicas=1)),
        },
        cascade=CascadeConfig("distilled", "baseline",
                              candidates=CANDIDATES, rerank_k=RERANK_K),
        tiers=tiers(), slo_p99_s=4 * t_base, capacity=budget,
    )
    res = casc_sys.run(
        poisson_arrivals(rate, horizon, seed=0, priority_frac=0.0),
        until=horizon)
    rows.append({"experiment": "cascade", "mode": "distilled_filter_baseline_rerank",
                 "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                 "throughput": res["throughput"], "rejected": res["rejected"],
                 "slo_attainment": res["slo_attainment"]})
    return rows


def mixed_batching_rows(specs, horizon=40.0) -> list:
    """Experiment 4 (cost-aware path): mixed pointwise + ranking traffic
    through the heterogeneous five-pool fleet — batches closed by request
    count alone vs by accumulated work items — for every router policy.
    One 256-candidate ranking query in a count-closed batch stalls the
    dozens of pointwise queries sharing it; the item budget keeps batch
    service time bounded, so the tail drops at the same sustained rate."""
    mix = ((1, 0.9), (256, 0.1))
    rate = lambda t: 112.0 if t < 0.2 * horizon else (
        280.0 if t < 0.65 * horizon else 140.0)
    rows = []
    for policy, kw in ROUTER_CFGS:
        for batching, cap in (("count", None), ("items", 256)):
            pools = {
                name: PoolSpec(spec, PoolConfig(n_replicas=2, max_batch=64,
                                                max_wait_s=0.02,
                                                max_batch_items=cap))
                for name, spec in specs.items()
            }
            sys_ = ServingSystem(
                pools, make_router(policy, **kw),
                tiers={"tier0": TierPolicy(1500, 300), "tier1": TierPolicy(1500, 300)},
                slo_p99_s=0.15, capacity=16,
            )
            res = sys_.run(
                poisson_arrivals(rate, horizon, seed=0, priority_frac=0.02,
                                 cost_mix=mix),
                until=horizon)
            rows.append({
                "experiment": "mixed_batching", "router": policy, "batching": batching,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "slo_attainment": res["slo_attainment"],
            })
    return rows


def federation_rows(specs, horizon=30.0) -> list:
    """Experiment 5: one fleet split into 3 cells, sticky (home-cell)
    routing with skewed per-cell traffic — 60% of homes on the hot cell vs
    its 1/3 share of capacity — spillover off vs on. The fleet rate is
    scaled to ~80% of the CALIBRATED fleet capacity so the hot cell is
    overloaded (~1.4x its local capacity) while the fleet as a whole has
    headroom: exactly the regime where cross-cell spillover must win."""
    spec = specs["baseline"]
    # Sustainable cell rate from the shared timeout-batching equilibrium
    # (replica.sustainable_rate). 80% of fleet capacity keeps the fleet
    # healthy while the 60%-skewed hot cell runs ~1.4x its local share.
    replicas, wait = 2, 0.02
    r_cell = sustainable_rate(spec, replicas, wait)
    r_cell = min(r_cell, 32 / wait * replicas)  # max_batch-bound regime
    fleet_rate = 0.8 * 3 * r_cell
    skew = {"cell0": 0.60, "cell1": 0.25, "cell2": 0.15}
    rows = []
    for spillover in (False, True):
        cells = {
            name: CellSpec(
                pools={"baseline": PoolSpec(
                    spec, PoolConfig(n_replicas=replicas, autoscale=False,
                                     max_batch=32, max_wait_s=wait))},
                slo_p99_s=0.15,
            )
            for name in skew
        }
        fed = FederatedSystem(cells, policy="sticky", spillover=spillover,
                              rtt_s=0.005, slo_p99_s=0.15)
        arr = poisson_arrivals(lambda t: fleet_rate, horizon, seed=0,
                               priority_frac=0.0)
        assign_homes(arr, skew, seed=1)
        res = fed.run(arr, until=horizon)
        rows.append({
            "experiment": "federation", "spillover": spillover,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "spilled": res["spilled"],
            "slo_attainment": res["slo_attainment"],
            "cell_p99_ms": {n: c["p99"] * 1e3 for n, c in res["cells"].items()},
        })
    return rows


CACHE_VOCAB, CACHE_IDS, CACHE_ALPHA = 20_000, 16, 1.1


def _cached_spec(spec: ReplicaSpec) -> ReplicaSpec:
    """The experiment-6 replica: the variant's calibrated dense curve plus
    a per-missed-row embedding-fetch cost sized so a COLD batch spends 2x
    its dense time fetching rows (the memory-bound regime the related
    workload studies report) — self-calibrating on any host."""
    fetch = 2.0 * spec.latency(32) / (32 * CACHE_IDS)
    return dataclasses.replace(spec, embed_fetch_s=fetch)


def caching_rows(specs, horizon=30.0) -> list:
    """Experiment 6: hit-rate sweep x cache policy x cell spillover."""
    spec = _cached_spec(specs["baseline"])
    replicas, wait = 2, 0.02
    pcfg = lambda: PoolConfig(n_replicas=replicas, autoscale=False,
                              max_batch=32, max_wait_s=wait)
    # operating point from the shared timeout-batching equilibrium
    # (replica.sustainable_rate, the experiment-5 model plus the fetch
    # term): no cache fetches every row; a warm cache at ~85% hit pays
    # 15% of it — the offered load sits past the cold fleet's
    # equilibrium but inside the warm fleet's.
    r_cold = sustainable_rate(spec, replicas, wait, CACHE_IDS, hit_rate=0.0)
    r_warm = sustainable_rate(spec, replicas, wait, CACHE_IDS, hit_rate=0.85)
    rate = min(1.2 * r_cold, 0.9 * r_warm)
    warm_stream = zipf_id_stream(8 * CACHE_VOCAB // 4, CACHE_VOCAB,
                                 CACHE_ALPHA, seed=2)
    rows = []

    sweeps = [("none", None, None)]
    for policy in ("lru", "lfu", "s3fifo"):
        for cap in (CACHE_VOCAB // 32, CACHE_VOCAB // 8):
            sweeps.append((policy, CacheConfig(cap, policy), None))
    # repeat-query traffic: the result cache serves fresh repeats outright
    sweeps.append(("lru+result",
                   CacheConfig(CACHE_VOCAB // 8, "lru",
                               result_capacity=4096, result_ttl_s=2.0),
                   2000))
    for label, cache, n_distinct in sweeps:
        sys_ = ServingSystem(
            {"baseline": PoolSpec(spec, pcfg(), cache=cache)},
            slo_p99_s=0.15, adaptive_shedding=False)
        if cache is not None:
            sys_.pools["baseline"].embed_cache.warm(warm_stream)
        arr = poisson_arrivals(lambda t: rate, horizon, seed=0,
                               priority_frac=0.0)
        attach_zipf_ids(arr, CACHE_VOCAB, CACHE_IDS, alpha=CACHE_ALPHA,
                        seed=1, n_distinct=n_distinct)
        res = sys_.run(arr, until=horizon)
        rows.append({
            "experiment": "caching", "mode": "single", "config": label,
            "capacity_rows": cache.capacity_rows if cache else 0,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "hit_rate": res["cache"]["hit_rate"],
            "result_hits": res["cache"]["result_hits"],
        })

    # part two: 2 cells with DISJOINT hot id sets (offset id ranges),
    # sticky homes skewed 75/25 at ~75% of the warm fleet's equilibrium —
    # the hot cell runs ~1.1x its local warm capacity and must spill, and
    # every spilled request misses the remote cell's cache cold
    fleet_rate = 0.75 * 2 * r_warm
    cap = CACHE_VOCAB // 8
    for spillover in (False, True):
        cells = {
            name: CellSpec(
                pools={"baseline": PoolSpec(
                    spec, pcfg(),
                    cache=CacheConfig(cap, "lru"))},
                slo_p99_s=0.15, adaptive_shedding=False)
            for name in ("hot", "cold")
        }
        fed = FederatedSystem(cells, policy="sticky", spillover=spillover,
                              rtt_s=0.005, slo_p99_s=0.15)
        for i, name in enumerate(("hot", "cold")):
            fed.cells[name].system.pools["baseline"].embed_cache.warm(
                warm_stream + i * CACHE_VOCAB)
        arr = poisson_arrivals(lambda t: fleet_rate, horizon, seed=3,
                               priority_frac=0.0)
        assign_homes(arr, {"hot": 0.75, "cold": 0.25}, seed=4)
        # each home's ids live in its own range: spilled lookups are
        # foreign to the serving cell's cache
        for i, name in enumerate(("hot", "cold")):
            mine = [r for r in arr if r.home == name]
            attach_zipf_ids(mine, CACHE_VOCAB, CACHE_IDS, alpha=CACHE_ALPHA,
                            seed=5 + i, offset=i * CACHE_VOCAB)
        res = fed.run(arr, until=horizon)
        roll = res["cells"]
        rows.append({
            "experiment": "caching", "mode": "cells", "config":
                f"spillover={spillover}",
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "spilled": res["spilled"],
            "hit_rate": {n: c["cache"]["hit_rate"] for n, c in roll.items()},
        })
    return rows


def _scaled_model(lat: LatencyModel, factor: float) -> LatencyModel:
    """A copy of a (possibly host-calibrated) curve with every service
    time scaled — the drift/mis-calibration model for experiment 7."""
    return LatencyModel(lat.sizes.copy(), lat.times * factor)


CTRL_COST = 64  # work items per ranking request in experiment 7a


def _miscal_run(spec: ReplicaSpec, horizon: float, *, offline_factor: float,
                control: bool) -> dict:
    """Two identical pools behind the cost-model router; pool "drifted"
    predicts from an offline curve `offline_factor` x its TRUE curve
    (1.0 = correctly calibrated). With `control`, both pools learn the
    correction online. Offered load ~80% of the true fleet capacity."""
    true_lat = spec.latency
    ctl = ControlConfig(online_latency=True, adapt_batch=False) if control else None
    pcfg = lambda: PoolConfig(n_replicas=2, autoscale=False, max_batch=4,
                              max_wait_s=0.02, priority_bypass=False)
    pools = {
        "accurate": PoolSpec(dataclasses.replace(spec, variant="accurate"),
                             pcfg(), control=ctl),
        "drifted": PoolSpec(
            dataclasses.replace(
                spec, variant="drifted",
                latency=_scaled_model(true_lat, offline_factor),
                true_latency=true_lat),
            pcfg(), control=ctl),
    }
    # fleet capacity: 4 replicas, each serving 4-request batches of the
    # TRUE curve; offer 80% of it so routing quality decides the tail
    batch_s = true_lat(4 * CTRL_COST)
    rate = 0.8 * 4 * (4.0 / batch_s)
    sys_ = ServingSystem(pools, make_router("cost_model"),
                         slo_p99_s=4 * batch_s, adaptive_shedding=False)
    arr = poisson_arrivals(lambda t: rate, horizon, seed=0,
                           cost=CTRL_COST, priority_frac=0.0)
    return sys_.run(arr, until=horizon)


def _batch_sizing_run(spec: ReplicaSpec, horizon: float, *,
                      adaptive: bool) -> dict:
    """One pool under a low -> high load step, ranking requests of 16
    items, in the ITEM-CAPPED batching regime (max_wait_s sized above
    the wide cap's fill time, so the cap — not the timeout — closes
    batches). Static: max_batch_items stays at the wide 1024-item cap,
    and every request eats the wide batch's fill + service time.
    Adaptive: a BatchSizeController narrows the cap on SLO breach
    (bounding per-batch fill and service) and widens it back under
    headroom. All rates derive from the spec's own curve — the offered
    load sits at 85% of the FLOOR cap's capacity on any host, so both
    runs are equally sustainable and only the tails differ."""
    cost, cap_wide, cap_floor = 16, 1024, 128
    # work-item arrival rate: 85% of what 2 replicas sustain at the
    # floor cap (the narrowest batches the controller may reach)
    items_per_s = 0.85 * 2 * cap_floor / spec.latency(cap_floor)
    wait = 1.5 * cap_wide / items_per_s  # wide cap fills before timeout
    slo = 2.5 * (cap_floor / items_per_s + spec.latency(cap_floor))
    ctl = ControlConfig(online_latency=False, adapt_batch=True,
                        min_batch_items=cap_floor, max_batch_items=cap_wide)
    pools = {"pool": PoolSpec(
        spec,
        PoolConfig(n_replicas=2, autoscale=False, max_batch=256,
                   max_wait_s=wait, max_batch_items=cap_wide,
                   priority_bypass=False),
        control=ctl if adaptive else None)}
    rate = lambda t: (0.25 if t < 0.3 * horizon else 1.0) * items_per_s / cost
    sys_ = ServingSystem(pools, slo_p99_s=slo, adaptive_shedding=False)
    arr = poisson_arrivals(rate, horizon, seed=1, cost=cost, priority_frac=0.0)
    return sys_.run(arr, until=horizon)


def control_rows(specs, horizon=30.0, check=False) -> list:
    """Experiment 7: the adaptive control plane. Part a: mis-calibration
    recovery under cost-model routing. Part b: static vs SLO-aware batch
    sizing under a load step. With `check`, the headline claims (and
    bit-determinism of the adaptive runs) are asserted, not just
    printed — CI runs --smoke with checks on."""
    spec = specs["baseline"]
    rows = []

    runs = {
        "oracle": _miscal_run(spec, horizon, offline_factor=1.0, control=False),
        "miscal_static": _miscal_run(spec, horizon, offline_factor=0.5,
                                     control=False),
        "miscal_adaptive": _miscal_run(spec, horizon, offline_factor=0.5,
                                       control=True),
    }
    for mode, res in runs.items():
        rows.append({
            "experiment": "control", "part": "miscalibration", "mode": mode,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "latency_corr": {
                n: p["control"]["latency_correction"]
                for n, p in res["pools"].items()},
        })
    if check:
        replay = _miscal_run(spec, horizon, offline_factor=0.5, control=True)
        assert replay["p99"] == runs["miscal_adaptive"]["p99"], \
            "adaptive mis-calibration run must replay bit-identically"
        assert runs["miscal_adaptive"]["p99"] <= 1.2 * runs["oracle"]["p99"], (
            "online latency model must recover a 2x mis-calibrated spec to "
            f"within 20% of the oracle: adaptive {runs['miscal_adaptive']['p99']:.3f}s"
            f" vs oracle {runs['oracle']['p99']:.3f}s")
        assert runs["miscal_static"]["p99"] > runs["miscal_adaptive"]["p99"], \
            "static mis-calibrated routing must be worse than adaptive"

    step = {
        "static": _batch_sizing_run(spec, horizon, adaptive=False),
        "adaptive": _batch_sizing_run(spec, horizon, adaptive=True),
    }
    for mode, res in step.items():
        pool = res["pools"]["pool"]
        rows.append({
            "experiment": "control", "part": "batch_sizing", "mode": mode,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "final_batch_items": pool["control"]["max_batch_items"],
            "min_traced_items": min(pool["trace"]["max_batch_items"],
                                    default=0.0),
        })
    if check:
        replay = _batch_sizing_run(spec, horizon, adaptive=True)
        assert replay["p99"] == step["adaptive"]["p99"], \
            "adaptive batch-sizing run must replay bit-identically"
        assert step["adaptive"]["p99"] < step["static"]["p99"], (
            "SLO-aware batch sizing must beat the static cap on p99: "
            f"adaptive {step['adaptive']['p99']:.3f}s vs "
            f"static {step['static']['p99']:.3f}s")
        assert (step["adaptive"]["completed_in_horizon"]
                >= 0.999 * step["static"]["completed_in_horizon"]), \
            "adaptive batch sizing must not give up throughput at equal load"
    return rows


SHARD_VOCAB, N_SHARDS, SHARD_RTT_S = 20_000, 16, 0.002


def shard_rows(specs, horizon=25.0, check=False) -> list:
    """Experiment 8: the sharded embedding tier. Part a: 2 cells, table
    sharded across both, L1-only vs L1+shared-L2 at equal offered load.
    Part b: update-rate sweep, invalidation on/off, staleness vs
    hit-rate. Operating points are self-calibrating: the Zipf head mass
    at each cache capacity feeds `sustainable_rate`, so the load sits
    past the L1-only equilibrium but inside the warm-L2 one on any
    host."""
    spec = _cached_spec(specs["baseline"])
    replicas, wait = 2, 0.02
    pcfg = lambda: PoolConfig(n_replicas=replicas, autoscale=False,
                              max_batch=32, max_wait_s=wait)
    l1_rows, l2_rows = SHARD_VOCAB // 64, SHARD_VOCAB // 4
    # ideal hit rate of a warm top-k cache under the Zipf stream = the
    # head mass at its capacity (LRU tracks it closely at this skew)
    p = np.arange(1, SHARD_VOCAB + 1, dtype=np.float64) ** -CACHE_ALPHA
    p /= p.sum()
    h_l1, h_l2 = float(p[:l1_rows].sum()), float(p[:l2_rows].sum())
    r_l1 = sustainable_rate(spec, replicas, wait, CACHE_IDS, hit_rate=h_l1)
    r_l2 = sustainable_rate(spec, replicas, wait, CACHE_IDS, hit_rate=h_l2)
    rate = min(1.15 * r_l1, 0.9 * r_l2)  # per cell
    warm_stream = zipf_id_stream(2 * SHARD_VOCAB, SHARD_VOCAB,
                                 CACHE_ALPHA, seed=2)
    rows, part_a = [], {}

    # part a: both cells' misses are ~half remote (table sharded across
    # the fleet); the only difference between the runs is the shared L2
    for l2_on in (False, True):
        shard = EmbeddingShardService(N_SHARDS, ("a", "b"))
        cache = CacheConfig(l1_rows,
                            l2=CacheConfig(l2_rows) if l2_on else None)
        cells = {
            name: CellSpec(
                pools={"baseline": PoolSpec(spec, pcfg(), cache=cache)},
                slo_p99_s=0.15, adaptive_shedding=False)
            for name in ("a", "b")
        }
        fed = FederatedSystem(cells, policy="sticky", spillover=False,
                              rtt_s=SHARD_RTT_S, slo_p99_s=0.15, shard=shard)
        for name in ("a", "b"):
            fed.cells[name].system.pools["baseline"].embed_cache.warm(warm_stream)
            if l2_on:
                fed.cells[name].system.l2_cache.warm(warm_stream)
        arr = poisson_arrivals(lambda t: 2 * rate, horizon, seed=0,
                               priority_frac=0.0)
        assign_homes(arr, {"a": 0.5, "b": 0.5}, seed=1)
        # one shared hot set (not per-cell disjoint ranges): the table is
        # fleet-global, so both cells contend for the same sharded rows
        attach_zipf_ids(arr, SHARD_VOCAB, CACHE_IDS, alpha=CACHE_ALPHA, seed=1)
        res = fed.run(arr, until=horizon)
        part_a[l2_on] = res
        sh = res["shard"]
        rows.append({
            "experiment": "shard", "part": "l2",
            "config": "l1+l2" if l2_on else "l1_only",
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "local_fetches": sh["local_fetches"],
            "remote_fetches": sh["remote_fetches"],
            "transit_s": sh["transit_s"],
            "l2_hit_rate": {n: c["cache"]["l2_hit_rate"]
                            for n, c in res["cells"].items()},
        })
    if check:
        assert part_a[True]["p99"] < part_a[False]["p99"], (
            "the shared L2 must cut p99 at equal offered load: "
            f"l1+l2 {part_a[True]['p99']:.3f}s vs "
            f"l1_only {part_a[False]['p99']:.3f}s")
        assert (part_a[True]["shard"]["remote_fetches"]
                < part_a[False]["shard"]["remote_fetches"]), \
            "the shared L2 must strictly cut remote shard-fetch volume"

    # part b: online table updates at increasing rates — versioned rows
    # publish through the shard and either invalidate down the hierarchy
    # (refetch, staleness 0) or keep serving superseded copies (staleness
    # climbs with the rate). Placement () keeps every fetch local: the
    # sweep isolates the freshness/hit-rate tradeoff from transit.
    sweep = {}
    for invalidation in (True, False):
        for upd_rate in (0.0, 20.0, 80.0):
            shard = EmbeddingShardService(N_SHARDS, invalidation=invalidation)
            sys_ = ServingSystem(
                {"baseline": PoolSpec(
                    spec, pcfg(),
                    cache=CacheConfig(l1_rows, l2=CacheConfig(l2_rows)))},
                slo_p99_s=0.15, adaptive_shedding=False, shard=shard)
            sys_.pools["baseline"].embed_cache.warm(warm_stream)
            sys_.l2_cache.warm(warm_stream)
            if upd_rate:
                sys_.loop.add_stream(
                    "shard_update",
                    update_event_stream(upd_rate, horizon, SHARD_VOCAB, 32,
                                        alpha=CACHE_ALPHA, seed=7))
            arr = poisson_arrivals(lambda t: rate, horizon, seed=3,
                                   priority_frac=0.0)
            attach_zipf_ids(arr, SHARD_VOCAB, CACHE_IDS, alpha=CACHE_ALPHA,
                            seed=3)
            res = sys_.run(arr, until=horizon)
            cache = res["cache"]
            sweep[(invalidation, upd_rate)] = cache
            rows.append({
                "experiment": "shard", "part": "updates",
                "config": f"invalidation={invalidation}",
                "update_rate": upd_rate,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "hit_rate": cache["hit_rate"],
                "l2_hit_rate": cache["l2_hit_rate"],
                "staleness": cache["staleness"],
                "invalidated": cache["invalidated"],
            })
    if check:
        for upd_rate in (20.0, 80.0):
            assert sweep[(True, upd_rate)]["staleness"] == 0, \
                "invalidation must leave zero stale serves"
            assert sweep[(True, upd_rate)]["invalidated"] > 0
            assert sweep[(False, upd_rate)]["staleness"] > 0, \
                "without invalidation superseded rows keep being served"
        assert (sweep[(False, 80.0)]["staleness"]
                > sweep[(False, 20.0)]["staleness"]), \
            "staleness must climb with the update rate"
        assert sweep[(False, 0.0)]["staleness"] == 0
    return rows


PLATFORM_RANK_COST = 512
PLATFORM_RATIO_FLOOR = 1.5  # asserted; measured 1.60-1.66 across seeds
PLATFORM_POINT_RATE = 1800.0  # pointwise probes / s offered to the fleet
PLATFORM_RANK_RATE = 48.0  # 512-candidate ranking queries / s


def _platform_fleet():
    """The experiment-9/10 mixed fleet: 3 CPU-class + 2 accelerator-class
    replicas, batched per platform (shared by the routing comparison and
    the latency-waterfall attribution of the same comparison)."""
    return {
        "baseline_cpu": PoolSpec(
            ReplicaSpec.cpu_like("baseline"),
            PoolConfig.for_platform("cpu", n_replicas=3, autoscale=False)),
        "baseline_acc": PoolSpec(
            ReplicaSpec.accelerator_like("baseline"),
            PoolConfig.for_platform("accelerator", n_replicas=2,
                                    autoscale=False)),
    }


def platform_rows(horizon=20.0, check=False) -> list:
    """Experiment 9: heterogeneous platform classes + query-size-aware
    routing (DeepRecSys). The fleet mixes both platform classes — 3
    CPU-class replicas (cheap pointwise, steep batch curve) and 2
    accelerator-class replicas (expensive fixed cost, near-flat curve),
    each pool batched per `PoolConfig.for_platform` — under bimodal
    traffic: ~97% pointwise probes + ~3% 512-candidate ranking queries
    at a fixed offered load sized so the fleet is healthy ONLY when
    every query size lands on its right class. SizeAwareRouter enforces
    that; the ablation (SizeBlindCostModelRouter) runs the identical
    cost model but prices every admission at the pointwise unit — the
    front door that learns candidate counts only after retrieval. Blind
    routing sends ranking to the cheapest-pointwise quote (the CPU
    class), one 512-item batch burns ~0.4s of steep-curve capacity, the
    CPU backlog spirals, pointwise floods the accelerators' fixed cost,
    and throughput collapses in both directions. Plain cost_model (sees
    true sizes) is included as the reference point between the two.
    All latency curves are analytic class shapes — no host calibration,
    so the run (and its asserted margins) replays bit-identically
    anywhere. Fixed fleet (autoscale off) and no adaptive shedding:
    routing quality alone separates the rows."""
    total = PLATFORM_POINT_RATE + PLATFORM_RANK_RATE
    mix = bimodal_cost_mix(rank_cost=PLATFORM_RANK_COST,
                           rank_frac=PLATFORM_RANK_RATE / total)

    def one(router: str) -> dict:
        sys_ = ServingSystem(_platform_fleet(), make_router(router),
                             slo_p99_s=0.15, adaptive_shedding=False)
        # default priority_frac: the 2% of head queries that bypass
        # batching are part of the workload — a priority ranking query
        # blind-routed onto a CPU-class pool occupies a replica solo for
        # the full steep-curve service time, exactly the poisoning the
        # class-affinity split prevents
        arr = poisson_arrivals(lambda t: total, horizon, seed=0, cost_mix=mix)
        return sys_.run(arr, until=horizon)

    rows, res = [], {}
    for router in ("size_aware", "cost_model", "cost_model_blind"):
        r = one(router)
        res[router] = r
        share = {}
        for p in r["pools"].values():
            share[p["platform"]] = share.get(p["platform"], 0) + p["completed"]
        rows.append({
            "experiment": "platform_classes", "router": router,
            "p50_ms": r["p50"] * 1e3, "p99_ms": r["p99"] * 1e3,
            "throughput": r["throughput"], "rejected": r["rejected"],
            "slo_attainment": r["slo_attainment"],
            "platform_share": share,
        })
    if check:
        aware, blind = res["size_aware"], res["cost_model_blind"]
        ratio = aware["throughput"] / max(blind["throughput"], 1e-9)
        assert ratio >= PLATFORM_RATIO_FLOOR, (
            "size-aware routing must hold >= "
            f"{PLATFORM_RATIO_FLOOR}x the size-blind router's throughput on "
            f"the mixed fleet: {aware['throughput']:.0f} vs "
            f"{blind['throughput']:.0f} req/s ({ratio:.2f}x)")
        assert aware["p99"] <= blind["p99"], (
            "the size-aware throughput win must not spend tail latency: "
            f"aware p99 {aware['p99']:.3f}s vs blind {blind['p99']:.3f}s")
        replay = one("size_aware")
        assert (replay["p99"] == aware["p99"]
                and replay["throughput"] == aware["throughput"]), \
            "heterogeneous platform fleet must replay bit-identically"
    return rows


# the components the size-blind router's collapse should concentrate in:
# time spent waiting for a batch to close or a replica to free up
WATERFALL_WAIT_COMPONENTS = ("queue_wait", "replica_wait")


def waterfall_rows(horizon=20.0, check=False) -> list:
    """Experiment 10: the latency WATERFALL of experiment 9's routing
    comparison. The always-on attribution layer (serving/tracing.py)
    decomposes every completed request's latency into named components
    whose sum equals the end-to-end latency bit-exactly, so the
    size-aware-vs-blind gap is not just measurable but attributable: the
    blind router's extra latency must sit in the WAIT components (queue
    wait behind poisoned steep-curve batches + replica wait), not in
    compute — the work per request is identical, only where it queues
    differs. `check` asserts the majority attribution, which turns the
    experiment-9 headline number into an explained number."""
    total = PLATFORM_POINT_RATE + PLATFORM_RANK_RATE
    mix = bimodal_cost_mix(rank_cost=PLATFORM_RANK_COST,
                           rank_frac=PLATFORM_RANK_RATE / total)

    def one(router: str) -> dict:
        sys_ = ServingSystem(_platform_fleet(), make_router(router),
                             slo_p99_s=0.15, adaptive_shedding=False)
        arr = poisson_arrivals(lambda t: total, horizon, seed=0, cost_mix=mix)
        return sys_.run(arr, until=horizon)

    rows, res = [], {}
    for router in ("size_aware", "cost_model_blind"):
        r = one(router)
        res[router] = r
        bd = r["latency_breakdown"]
        n = max(bd["count"], 1)
        rows.append({
            "experiment": "latency_waterfall", "router": router,
            "p99_ms": r["p99"] * 1e3, "throughput": r["throughput"],
            "count": bd["count"],
            "mean_end_to_end_ms": bd["end_to_end_s"] / n * 1e3,
            "component_s": dict(bd["components"]),
            "mean_ms": {c: bd["components"][c] / n * 1e3 for c in COMPONENTS},
            "share": dict(bd["shares"]),
        })
    if check:
        aware = res["size_aware"]["latency_breakdown"]
        blind = res["cost_model_blind"]["latency_breakdown"]
        mean = lambda bd, c: bd["components"][c] / max(bd["count"], 1)
        d_total = (blind["end_to_end_s"] / max(blind["count"], 1)
                   - aware["end_to_end_s"] / max(aware["count"], 1))
        d_wait = sum(mean(blind, c) - mean(aware, c)
                     for c in WATERFALL_WAIT_COMPONENTS)
        assert d_total > 0, (
            "the size-blind router must pay a mean-latency premium for the"
            f" waterfall to attribute: delta {d_total * 1e3:.2f}ms")
        assert d_wait >= 0.5 * d_total, (
            "the size-aware-vs-blind latency gap (the p99 collapse of"
            " experiment 9) must be attributed >= majority to batch-wait /"
            " queue components — misrouting changes where requests WAIT,"
            f" not what they compute: wait delta {d_wait * 1e3:.2f}ms of"
            f" {d_total * 1e3:.2f}ms total")
        assert res["cost_model_blind"]["p99"] > res["size_aware"]["p99"]
    return rows


def export_observability(trace_path=None, metrics_path=None,
                         smoke: bool = False) -> dict:
    """--trace-out / --metrics-out: one traced 2-cell federated run over
    the sharded embedding tier (the experiment-8 operating point, so the
    trace shows every span kind: queue/replica waits, dense + local and
    REMOTE embedding fetches, shard transit, inter-cell hops), exported
    as a Perfetto-loadable Chrome trace and/or a Prometheus text
    exposition. The exposition's conserved counters are asserted against
    `federated_rollup` before anything hits disk — the artifact CI
    uploads is self-checked, not best-effort."""
    horizon = 6.0 if smoke else 20.0
    spec = _cached_spec(analytic_specs()["baseline"])
    replicas, wait = 2, 0.02
    l1_rows, l2_rows = SHARD_VOCAB // 64, SHARD_VOCAB // 4
    p = np.arange(1, SHARD_VOCAB + 1, dtype=np.float64) ** -CACHE_ALPHA
    p /= p.sum()
    r_l2 = sustainable_rate(spec, replicas, wait, CACHE_IDS,
                            hit_rate=float(p[:l2_rows].sum()))
    tracer = Tracer(sample_every=4 if smoke else 16, seed=0)
    shard = EmbeddingShardService(N_SHARDS, ("a", "b"))
    cache = CacheConfig(l1_rows, l2=CacheConfig(l2_rows))
    cells = {
        name: CellSpec(
            pools={"baseline": PoolSpec(
                spec, PoolConfig(n_replicas=replicas, autoscale=False,
                                 max_batch=32, max_wait_s=wait),
                cache=cache)},
            slo_p99_s=0.15, adaptive_shedding=False)
        for name in ("a", "b")
    }
    fed = FederatedSystem(cells, policy="least_loaded", rtt_s=SHARD_RTT_S,
                          slo_p99_s=0.15, shard=shard, tracer=tracer)
    arr = poisson_arrivals(lambda t: 2 * 0.8 * r_l2, horizon, seed=0,
                           priority_frac=0.0)
    assign_homes(arr, {"a": 0.6, "b": 0.4}, seed=1)
    attach_zipf_ids(arr, SHARD_VOCAB, CACHE_IDS, alpha=CACHE_ALPHA, seed=1)
    res = fed.run(arr, until=horizon)

    rollup = federated_rollup(res["cells"])
    assert rollup["latency_breakdown"]["count"] == res["completed"], \
        "fleet breakdown must account for exactly the completed requests"
    text = MetricsRegistry.from_summary(res).to_prometheus_text()
    for key in ("completed", "rejected"):
        line = next(
            l for l in text.splitlines()
            if l.startswith(f'repro_serving_{key}_total{{scope="fleet"}}'))
        assert int(line.split()[-1]) == res[key] == rollup[key], (
            f"prometheus {key} counter must match the federated rollup")
    stats = {"completed": res["completed"], "spans": len(tracer),
             "dropped_spans": tracer.dropped_spans}
    if trace_path:
        with open(trace_path, "w") as fh:
            json.dump(tracer.to_chrome_trace(), fh)
        print(f"# wrote {len(tracer)} spans ({tracer.summary()['tracks']}"
              f" tracks, 1-in-{tracer.sample_every} sampling) to {trace_path}")
    if metrics_path:
        with open(metrics_path, "w") as fh:
            fh.write(text)
        print(f"# wrote {len(text.splitlines())} exposition lines to"
              f" {metrics_path}")
    return stats


def run(smoke: bool = False) -> list:
    if smoke:
        specs = analytic_specs()
        return (single_pool_rows(specs, horizon=8.0)
                + heterogeneous_rows(specs, horizon=8.0)
                + cascade_rows(specs, horizon=15.0)
                + mixed_batching_rows(specs, horizon=10.0)
                + federation_rows(specs, horizon=12.0)
                + caching_rows(specs, horizon=10.0)
                + control_rows(specs, horizon=12.0, check=True)
                + shard_rows(specs, horizon=10.0, check=True)
                + platform_rows(horizon=8.0, check=True)
                + waterfall_rows(horizon=8.0, check=True))
    specs = calibrated_specs()
    return (single_pool_rows(specs) + heterogeneous_rows(specs)
            + cascade_rows(specs) + mixed_batching_rows(specs)
            + federation_rows(specs) + caching_rows(specs)
            + control_rows(specs) + shard_rows(specs) + platform_rows()
            + waterfall_rows())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="analytic latency models + tiny horizons (CI guard)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump every experiment row (p99/throughput/...)"
                         " as a JSON perf artifact, e.g. BENCH_serving.json")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile and print the top-25 cumulative"
                         " table (hot-loop regressions diagnosable without"
                         " editing code)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="write a Perfetto-loadable Chrome trace (span"
                         " waterfall of a traced federated run) to PATH,"
                         " e.g. BENCH_trace.json")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the same run's Prometheus text exposition"
                         " (conserved counters, cache/shard tallies, latency"
                         " component histograms) to PATH, e.g."
                         " BENCH_metrics.prom")
    args = ap.parse_args(argv)
    if args.profile:
        # script-mode runs have benchmarks/ itself on sys.path, not the root
        try:
            from benchmarks.profiling import profiled
        except ImportError:
            from profiling import profiled

        rows = profiled(run, smoke=args.smoke)
    else:
        rows = run(smoke=args.smoke)
    if args.trace_out or args.metrics_out:
        export_observability(args.trace_out, args.metrics_out,
                             smoke=args.smoke)
    if args.json:
        # lazy: only the artifact writer needs the shared schema helper
        try:
            from benchmarks.common import bench_payload
        except ImportError:
            from common import bench_payload
        # schema v2: the waterfall rows flatten into the breakdown block
        # so attribution diffs across PRs without a bench-specific parser
        breakdown = [
            {"label": r["router"], "component": c,
             "seconds": r["component_s"][c], "share": r["share"][c],
             "mean_ms": r["mean_ms"][c]}
            for r in rows if r["experiment"] == "latency_waterfall"
            for c in COMPONENTS
        ]
        payload = bench_payload(
            "serving", rows, smoke=args.smoke,
            row_keys=("experiment", "p99_ms", "throughput"),
            breakdown=breakdown)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"# wrote {len(rows)} experiment rows to {args.json}"
              f" (schema v{payload['schema_version']})")
    print("# 1. each variant alone under a 150->1000 QPS spike")
    print("variant,autoscale,p50_ms,p99_ms,throughput,rejected,max_replicas,"
          "svc_ms_b1,svc_ms_b512")
    for r in rows:
        if r["experiment"] != "single_pool":
            continue
        print(f"{r['variant']},{r['autoscale']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['max_replicas']},"
              f"{r['svc_ms_b1']:.2f},{r['svc_ms_b512']:.1f}")

    print("\n# 2. all five variant pools live at once (capacity budget 16)")
    print("router,p50_ms,p99_ms,throughput,rejected,slo_attainment,pool_share")
    for r in rows:
        if r["experiment"] != "heterogeneous":
            continue
        share = " ".join(f"{n}:{c}" for n, c in r["pool_share"].items())
        print(f"{r['router']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f},{share}")

    print(f"\n# 3. ranking spike ({CANDIDATES} candidates/query, capacity budget 8):"
          f" baseline-only vs cascade (top-{RERANK_K} rerank)")
    print("mode,p50_ms,p99_ms,throughput,rejected,slo_attainment")
    for r in rows:
        if r["experiment"] != "cascade":
            continue
        print(f"{r['mode']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f}")
    casc = {r["mode"]: r for r in rows if r["experiment"] == "cascade"}
    better = (casc["distilled_filter_baseline_rerank"]["throughput"]
              > casc["baseline_only"]["throughput"]
              and casc["distilled_filter_baseline_rerank"]["p99_ms"]
              <= casc["baseline_only"]["p99_ms"])
    print(f"cascade_beats_baseline_only={better}")

    print("\n# 4. mixed 90% pointwise / 10% ranking-256 traffic, five pools:"
          " count-closed vs item-closed batches")
    print("router,batching,p50_ms,p99_ms,throughput,rejected,slo_attainment")
    mixed = {}
    for r in rows:
        if r["experiment"] != "mixed_batching":
            continue
        mixed[(r["router"], r["batching"])] = r
        print(f"{r['router']},{r['batching']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f}")
    wins = all(
        mixed[(p, "items")]["throughput"] > mixed[(p, "count")]["throughput"]
        or (mixed[(p, "items")]["throughput"] >= 0.999 * mixed[(p, "count")]["throughput"]
            and mixed[(p, "items")]["p99_ms"] < mixed[(p, "count")]["p99_ms"])
        for p, _ in ROUTER_CFGS
    )
    print(f"item_batching_wins_or_ties_every_router={wins}")

    print("\n# 5. 3-cell federation, sticky homes skewed 60/25/15, ~80% fleet"
          " load: cross-cell spillover off vs on (5ms inter-cell RTT)")
    print("spillover,p50_ms,p99_ms,throughput,rejected,spilled,slo_attainment,"
          "cell_p99_ms")
    fed = {}
    for r in rows:
        if r["experiment"] != "federation":
            continue
        fed[r["spillover"]] = r
        cell_p99 = " ".join(f"{n}:{p:.0f}" for n, p in r["cell_p99_ms"].items())
        print(f"{r['spillover']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['spilled']},"
              f"{r['slo_attainment']:.3f},{cell_p99}")
    spill_wins = (fed[True]["p99_ms"] < fed[False]["p99_ms"]
                  and fed[True]["throughput"] >= 0.999 * fed[False]["throughput"])
    print(f"spillover_cuts_p99_at_equal_throughput={spill_wins}")

    print(f"\n# 6. hot-ID caching: Zipf({CACHE_ALPHA}) ids over {CACHE_VOCAB}"
          f" rows, {CACHE_IDS} ids/query, offered load past the NO-cache"
          " equilibrium (min(1.2x cold, 0.9x warm)): capacity x policy"
          " sweep, then 2 cells w/ disjoint hot sets")
    print("config,capacity_rows,p50_ms,p99_ms,throughput,rejected,hit_rate,"
          "result_hits")
    single = [r for r in rows
              if r["experiment"] == "caching" and r["mode"] == "single"]
    for r in single:
        print(f"{r['config']},{r['capacity_rows']},{r['p50_ms']:.1f},"
              f"{r['p99_ms']:.1f},{r['throughput']:.0f},{r['rejected']},"
              f"{r['hit_rate']:.3f},{r['result_hits']}")
    # like-for-like only: the lru+result row ran easier repeat-query
    # traffic, so it must not decide the warm-vs-none claim (every
    # capacity of every eviction policy competes)
    (none_row,) = [r for r in single if r["config"] == "none"]
    best_warm = min((r for r in single if r["config"] in ("lru", "lfu", "s3fifo")),
                    key=lambda r: r["p99_ms"])
    warm_wins = (best_warm["p99_ms"] < none_row["p99_ms"]
                 and best_warm["throughput"] > none_row["throughput"])
    print(f"warm_cache_beats_no_cache={warm_wins}")

    print("\nspillover_config,p50_ms,p99_ms,throughput,rejected,spilled,"
          "cell_hit_rates")
    cells = {}
    for r in rows:
        if r["experiment"] != "caching" or r["mode"] != "cells":
            continue
        cells[r["config"]] = r
        hr = " ".join(f"{n}:{h:.3f}" for n, h in r["hit_rate"].items())
        print(f"{r['config']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['spilled']},{hr}")
    on, off = cells["spillover=True"], cells["spillover=False"]
    fleet_hit = lambda r: min(r["hit_rate"].values())
    print(f"spillover_rescues_hot_cell={on['p99_ms'] < off['p99_ms']}"
          f" but_pays_cold_misses={fleet_hit(on) < fleet_hit(off)}")

    print(f"\n# 7. adaptive control plane: (a) cost-model routing with one"
          f" pool's offline calibration 2x off, (b) static vs SLO-aware"
          f" batch sizing under a load step")
    print("part,mode,p50_ms,p99_ms,throughput,rejected,detail")
    ctl = {}
    for r in rows:
        if r["experiment"] != "control":
            continue
        ctl[(r["part"], r["mode"])] = r
        if r["part"] == "miscalibration":
            detail = "corr " + " ".join(
                f"{n}:{c:.2f}" for n, c in r["latency_corr"].items())
        else:
            detail = (f"cap {r['final_batch_items']}"
                      f" (min traced {r['min_traced_items']:.0f})")
        print(f"{r['part']},{r['mode']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{detail}")
    recovers = (ctl[("miscalibration", "miscal_adaptive")]["p99_ms"]
                <= 1.2 * ctl[("miscalibration", "oracle")]["p99_ms"])
    print(f"online_model_recovers_miscalibrated_spec={recovers}")
    adapt_wins = (ctl[("batch_sizing", "adaptive")]["p99_ms"]
                  < ctl[("batch_sizing", "static")]["p99_ms"]
                  and ctl[("batch_sizing", "adaptive")]["throughput"]
                  >= 0.999 * ctl[("batch_sizing", "static")]["throughput"])
    print(f"adaptive_batch_sizing_beats_static={adapt_wins}")

    print(f"\n# 8. sharded embedding tier: table hashed over {N_SHARDS}"
          f" shards across 2 cells ({SHARD_RTT_S*1e3:.0f}ms remote-shard"
          " RTT) — pool L1s alone vs the cell-shared L2")
    print("config,p50_ms,p99_ms,throughput,rejected,local_fetches,"
          "remote_fetches,transit_s")
    l2cmp = {}
    for r in rows:
        if r["experiment"] != "shard" or r["part"] != "l2":
            continue
        l2cmp[r["config"]] = r
        print(f"{r['config']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['local_fetches']},"
              f"{r['remote_fetches']},{r['transit_s']:.2f}")
    l2_wins = (l2cmp["l1+l2"]["p99_ms"] < l2cmp["l1_only"]["p99_ms"]
               and l2cmp["l1+l2"]["remote_fetches"]
               < l2cmp["l1_only"]["remote_fetches"])
    print(f"shared_l2_cuts_remote_fetches_and_p99={l2_wins}")

    print("\n# online table updates: Poisson row publishes, versioned"
          " invalidation shard -> L2 -> L1 on vs off")
    print("config,update_rate,p99_ms,hit_rate,l2_hit_rate,staleness,"
          "invalidated")
    stale_on, stale_off = 0, 0
    for r in rows:
        if r["experiment"] != "shard" or r["part"] != "updates":
            continue
        if r["config"] == "invalidation=True":
            stale_on += r["staleness"]
        else:
            stale_off += r["staleness"]
        print(f"{r['config']},{r['update_rate']:.0f},{r['p99_ms']:.1f},"
              f"{r['hit_rate']:.3f},{r['l2_hit_rate']:.3f},{r['staleness']},"
              f"{r['invalidated']}")
    print(f"invalidation_serves_zero_stale_rows={stale_on == 0 and stale_off > 0}")

    print(f"\n# 9. heterogeneous platform classes: 3 CPU-class + 2"
          f" accelerator-class replicas, ~97% pointwise + ~3%"
          f" ranking-{PLATFORM_RANK_COST} traffic at fixed offered load —"
          " size-aware vs size-blind admission")
    print("router,p50_ms,p99_ms,throughput,rejected,slo_attainment,"
          "platform_share")
    plat = {}
    for r in rows:
        if r["experiment"] != "platform_classes":
            continue
        plat[r["router"]] = r
        share = " ".join(f"{n}:{c}" for n, c in sorted(r["platform_share"].items()))
        print(f"{r['router']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},"
              f"{r['slo_attainment']:.3f},{share}")
    ratio = (plat["size_aware"]["throughput"]
             / max(plat["cost_model_blind"]["throughput"], 1e-9))
    aware_wins = (ratio >= PLATFORM_RATIO_FLOOR
                  and plat["size_aware"]["p99_ms"]
                  <= plat["cost_model_blind"]["p99_ms"])
    print(f"size_aware_over_blind_throughput={ratio:.2f}x")
    print(f"size_aware_beats_size_blind={aware_wins}")

    print("\n# 10. latency waterfall of the experiment-9 gap: per-request"
          " attribution (sums to end-to-end latency bit-exactly), mean ms"
          " per component")
    wf_cols = [c for c in COMPONENTS if c != "closure"]
    print("router,count,mean_e2e_ms," + ",".join(wf_cols))
    wf = {}
    for r in rows:
        if r["experiment"] != "latency_waterfall":
            continue
        wf[r["router"]] = r
        comps = ",".join(f"{r['mean_ms'][c]:.2f}" for c in wf_cols)
        print(f"{r['router']},{r['count']},{r['mean_end_to_end_ms']:.1f},"
              f"{comps}")
    aware_wf, blind_wf = wf["size_aware"], wf["cost_model_blind"]
    d_total = blind_wf["mean_end_to_end_ms"] - aware_wf["mean_end_to_end_ms"]
    d_wait = sum(blind_wf["mean_ms"][c] - aware_wf["mean_ms"][c]
                 for c in WATERFALL_WAIT_COMPONENTS)
    frac = d_wait / d_total if d_total else float("nan")
    print(f"blind_premium_ms={d_total:.1f}"
          f" wait_attributed_ms={d_wait:.1f} ({frac:.0%})")
    print(f"gap_is_majority_wait={d_wait >= 0.5 * d_total and d_total > 0}")
    return rows


if __name__ == "__main__":
    main()
