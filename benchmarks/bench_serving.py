"""Elastic-scheduling benchmark (paper §IV.B) on the multi-pool engine.
Service times come from LatencyModels calibrated on the real jitted
executables of the five Table-I variants (or analytic stand-ins under
--smoke), then four experiments run on the same discrete-event kernel:

  1. single-pool: each variant alone under the spike, autoscaling on/off
     (the pre-refactor table, kept for continuity);
  2. heterogeneous: ALL FIVE variant pools live at once behind each router
     policy (least-loaded / power-of-two / SLO-aware / cost-model),
     pointwise traffic;
  3. cascade: ranking traffic (512 candidates/query) served either by the
     baseline pool alone or as a RecPipe-style cascade — distilled pool
     scores all 512, baseline pool reranks the top-32 — under the SAME
     shared capacity budget and SLO-protected admission;
  4. mixed batching (cost-aware path): 90% pointwise + 10% ranking traffic
     through the five-pool fleet, count-closed batches (max_batch only) vs
     item-closed batches (max_batch_items), for all four router policies;
  5. federation: the same fleet split into 3 cells with skewed sticky
     traffic (60/25/15) at ~80% of fleet capacity — cross-cell spillover
     off vs on. The hot cell is past its local capacity while the fleet
     has headroom; spillover cuts fleet p99 under the cell-local overload
     at equal-or-better fleet throughput, paying only the inter-cell RTT
     per hop.

`--smoke` skips calibration (analytic Table-I-shaped latency models) and
shrinks every horizon so CI can run the whole file in seconds.
"""
from __future__ import annotations

import argparse

from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.engine import (
    ElasticEngine, EngineConfig, PoolSpec, ServingSystem, poisson_arrivals,
)
from repro.core.serving.federation import CellSpec, FederatedSystem, assign_homes
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.core.serving.router import make_router

def spike(horizon: float):
    """150 -> 1000 QPS spike -> 200, at the same relative times whatever the
    horizon (absolute breakpoints would erase the spike under --smoke)."""
    return lambda t: 150.0 if t < 0.22 * horizon else (
        1000.0 if t < 0.67 * horizon else 200.0)


CANDIDATES, RERANK_K = 512, 32

# Table-I-shaped analytic service curves (base_s, per_item_s) for --smoke:
# same relative ordering as the calibrated variants, no training required.
ANALYTIC = {
    "baseline": (0.020, 1.0e-3),
    "quantized": (0.015, 7.5e-4),
    "pruned": (0.012, 6.0e-4),
    "pruned_quantized": (0.009, 4.5e-4),
    "distilled": (0.004, 1.5e-4),
}

ROUTER_CFGS = (
    ("least_loaded", {}),
    ("power_of_two", {"seed": 0}),
    ("slo_aware", {"slo_p99_s": 0.15,
                   "quality_order": ("baseline", "quantized", "pruned")}),
    ("cost_model", {}),
)


def calibrated_specs() -> dict:
    """ReplicaSpec per Table-I variant, timed on the real executables."""
    import jax

    from benchmarks.common import VARIANTS, bench_world, serve_batch
    from repro.models.recsys import api

    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    fixed = {b: serve_batch(cfg, world, b) for b in (1, 8, 32, 128, 512)}
    specs = {}
    for name in VARIANTS:
        v = ladder[name]
        jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))

        def call(b):
            jax.block_until_ready(jitted(v["params"], fixed[b]))

        lat = LatencyModel.calibrate(call, reps=2)
        specs[name] = ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2)
    return specs


def analytic_specs() -> dict:
    return {
        name: ReplicaSpec(name, LatencyModel.analytic(base, per),
                          cold_start_s=5.0, warm_start_s=0.2)
        for name, (base, per) in ANALYTIC.items()
    }


def single_pool_rows(specs, horizon=45.0) -> list:
    arrivals_for = lambda: poisson_arrivals(spike(horizon), horizon, seed=0)
    rows = []
    for name, spec in specs.items():
        for autoscale in (False, True):
            eng = ElasticEngine(
                spec,
                EngineConfig(n_replicas=2, autoscale=autoscale, slo_p99_s=0.15,
                             max_batch=64),
                tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
            )
            res = eng.run(arrivals_for(), until=horizon)
            rows.append({
                "experiment": "single_pool", "variant": name, "autoscale": autoscale,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "max_replicas": max(res["trace"]["replicas"], default=2),
                "svc_ms_b1": spec.latency(1) * 1e3,
                "svc_ms_b512": spec.latency(512) * 1e3,
            })
    return rows


def heterogeneous_rows(specs, horizon=45.0) -> list:
    """All five variant pools live simultaneously behind one router."""
    rows = []
    for policy, kw in ROUTER_CFGS:
        pools = {
            name: PoolSpec(spec, PoolConfig(n_replicas=1, max_batch=64))
            for name, spec in specs.items()
        }
        sys_ = ServingSystem(
            pools, make_router(policy, **kw),
            tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
            slo_p99_s=0.15, capacity=16,
        )
        res = sys_.run(poisson_arrivals(spike(horizon), horizon, seed=0,
                                        priority_frac=0.05),
                       until=horizon)
        rows.append({
            "experiment": "heterogeneous", "router": policy,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "slo_attainment": res["slo_attainment"],
            "pool_share": {n: p["completed"] for n, p in res["pools"].items()},
        })
    return rows


def cascade_rows(specs, horizon=55.0) -> list:
    """Ranking traffic: baseline-only vs distilled-filter -> baseline-rerank,
    same capacity budget, same admission, same SLO. Each ranking request is
    already a full candidate-set batch, so pools serve one request per call
    (max_batch=1: the calibrated regime — co-batching several 512-candidate
    queries would push service time into extrapolation territory where the
    CPU-calibrated variants converge). The spike is scaled to the CALIBRATED
    capacity of the baseline-only fleet (0.4x off-peak, 1.15x during the
    spike) so the experiment stresses the same relative operating point on
    any host: just past what baseline-only can sustain, inside what the
    cascade can."""
    from repro.core.serving.autoscaler import ScalerConfig

    budget = 8
    t_base = specs["baseline"].latency(CANDIDATES)  # s per ranking request
    cap_base = budget / t_base  # req/s of the baseline-only fleet
    spike_window = (0.2 * horizon, 0.72 * horizon)  # relative, horizon-proof
    rate = lambda t: (1.15 * cap_base
                      if spike_window[0] <= t < spike_window[1] else 0.4 * cap_base)
    tiers = lambda: {"tier0": TierPolicy(1e9, 1e9), "tier1": TierPolicy(1e9, 1e9)}
    pcfg = lambda n: PoolConfig(n_replicas=n, max_batch=1, priority_bypass=False)
    rows = []

    base_sys = ServingSystem(
        {"baseline": PoolSpec(specs["baseline"], pcfg(2))},
        tiers=tiers(), slo_p99_s=4 * t_base, capacity=budget,
    )
    res = base_sys.run(
        poisson_arrivals(rate, horizon, seed=0, cost=CANDIDATES, priority_frac=0.0),
        until=horizon)
    rows.append({"experiment": "cascade", "mode": "baseline_only",
                 "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                 "throughput": res["throughput"], "rejected": res["rejected"],
                 "slo_attainment": res["slo_attainment"]})

    casc_sys = ServingSystem(
        {
            # the filter stage does ~all the work: deploy it wide from the
            # start; the rerank stage sees RERANK_K items/query and needs a
            # small share of the budget, so it starts (and shrinks back) to 1
            "distilled": PoolSpec(specs["distilled"], pcfg(4)),
            "baseline": PoolSpec(specs["baseline"], pcfg(1),
                                 ScalerConfig(min_replicas=1)),
        },
        cascade=CascadeConfig("distilled", "baseline",
                              candidates=CANDIDATES, rerank_k=RERANK_K),
        tiers=tiers(), slo_p99_s=4 * t_base, capacity=budget,
    )
    res = casc_sys.run(
        poisson_arrivals(rate, horizon, seed=0, priority_frac=0.0),
        until=horizon)
    rows.append({"experiment": "cascade", "mode": "distilled_filter_baseline_rerank",
                 "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                 "throughput": res["throughput"], "rejected": res["rejected"],
                 "slo_attainment": res["slo_attainment"]})
    return rows


def mixed_batching_rows(specs, horizon=40.0) -> list:
    """Experiment 4 (cost-aware path): mixed pointwise + ranking traffic
    through the heterogeneous five-pool fleet — batches closed by request
    count alone vs by accumulated work items — for every router policy.
    One 256-candidate ranking query in a count-closed batch stalls the
    dozens of pointwise queries sharing it; the item budget keeps batch
    service time bounded, so the tail drops at the same sustained rate."""
    mix = ((1, 0.9), (256, 0.1))
    rate = lambda t: 112.0 if t < 0.2 * horizon else (
        280.0 if t < 0.65 * horizon else 140.0)
    rows = []
    for policy, kw in ROUTER_CFGS:
        for batching, cap in (("count", None), ("items", 256)):
            pools = {
                name: PoolSpec(spec, PoolConfig(n_replicas=2, max_batch=64,
                                                max_wait_s=0.02,
                                                max_batch_items=cap))
                for name, spec in specs.items()
            }
            sys_ = ServingSystem(
                pools, make_router(policy, **kw),
                tiers={"tier0": TierPolicy(1500, 300), "tier1": TierPolicy(1500, 300)},
                slo_p99_s=0.15, capacity=16,
            )
            res = sys_.run(
                poisson_arrivals(rate, horizon, seed=0, priority_frac=0.02,
                                 cost_mix=mix),
                until=horizon)
            rows.append({
                "experiment": "mixed_batching", "router": policy, "batching": batching,
                "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
                "throughput": res["throughput"], "rejected": res["rejected"],
                "slo_attainment": res["slo_attainment"],
            })
    return rows


def federation_rows(specs, horizon=30.0) -> list:
    """Experiment 5: one fleet split into 3 cells, sticky (home-cell)
    routing with skewed per-cell traffic — 60% of homes on the hot cell vs
    its 1/3 share of capacity — spillover off vs on. The fleet rate is
    scaled to ~80% of the CALIBRATED fleet capacity so the hot cell is
    overloaded (~1.4x its local capacity) while the fleet as a whole has
    headroom: exactly the regime where cross-cell spillover must win."""
    spec = specs["baseline"]
    # Sustainable cell rate under timeout batching: batches close every
    # max_wait w holding r*w requests, and R replicas keep up only while
    # latency(r*w) <= R*w — so r_cell = (R*w - b1) / (m*w) at the
    # calibrated base b1 and marginal per-item cost m. 80% of fleet
    # capacity keeps the fleet healthy while the 60%-skewed hot cell runs
    # ~1.4x its local share.
    replicas, wait = 2, 0.02
    b1 = spec.latency(1)
    marginal = (spec.latency(32) - b1) / 31.0
    r_cell = max((replicas * wait - b1) / (marginal * wait), 1.0)
    r_cell = min(r_cell, 32 / wait * replicas)  # max_batch-bound regime
    fleet_rate = 0.8 * 3 * r_cell
    skew = {"cell0": 0.60, "cell1": 0.25, "cell2": 0.15}
    rows = []
    for spillover in (False, True):
        cells = {
            name: CellSpec(
                pools={"baseline": PoolSpec(
                    spec, PoolConfig(n_replicas=replicas, autoscale=False,
                                     max_batch=32, max_wait_s=wait))},
                slo_p99_s=0.15,
            )
            for name in skew
        }
        fed = FederatedSystem(cells, policy="sticky", spillover=spillover,
                              rtt_s=0.005, slo_p99_s=0.15)
        arr = poisson_arrivals(lambda t: fleet_rate, horizon, seed=0,
                               priority_frac=0.0)
        assign_homes(arr, skew, seed=1)
        res = fed.run(arr, until=horizon)
        rows.append({
            "experiment": "federation", "spillover": spillover,
            "p50_ms": res["p50"] * 1e3, "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"], "rejected": res["rejected"],
            "spilled": res["spilled"],
            "slo_attainment": res["slo_attainment"],
            "cell_p99_ms": {n: c["p99"] * 1e3 for n, c in res["cells"].items()},
        })
    return rows


def run(smoke: bool = False) -> list:
    if smoke:
        specs = analytic_specs()
        return (single_pool_rows(specs, horizon=8.0)
                + heterogeneous_rows(specs, horizon=8.0)
                + cascade_rows(specs, horizon=15.0)
                + mixed_batching_rows(specs, horizon=10.0)
                + federation_rows(specs, horizon=12.0))
    specs = calibrated_specs()
    return (single_pool_rows(specs) + heterogeneous_rows(specs)
            + cascade_rows(specs) + mixed_batching_rows(specs)
            + federation_rows(specs))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="analytic latency models + tiny horizons (CI guard)")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    print("# 1. each variant alone under a 150->1000 QPS spike")
    print("variant,autoscale,p50_ms,p99_ms,throughput,rejected,max_replicas,"
          "svc_ms_b1,svc_ms_b512")
    for r in rows:
        if r["experiment"] != "single_pool":
            continue
        print(f"{r['variant']},{r['autoscale']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['max_replicas']},"
              f"{r['svc_ms_b1']:.2f},{r['svc_ms_b512']:.1f}")

    print("\n# 2. all five variant pools live at once (capacity budget 16)")
    print("router,p50_ms,p99_ms,throughput,rejected,slo_attainment,pool_share")
    for r in rows:
        if r["experiment"] != "heterogeneous":
            continue
        share = " ".join(f"{n}:{c}" for n, c in r["pool_share"].items())
        print(f"{r['router']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f},{share}")

    print(f"\n# 3. ranking spike ({CANDIDATES} candidates/query, capacity budget 8):"
          f" baseline-only vs cascade (top-{RERANK_K} rerank)")
    print("mode,p50_ms,p99_ms,throughput,rejected,slo_attainment")
    for r in rows:
        if r["experiment"] != "cascade":
            continue
        print(f"{r['mode']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f}")
    casc = {r["mode"]: r for r in rows if r["experiment"] == "cascade"}
    better = (casc["distilled_filter_baseline_rerank"]["throughput"]
              > casc["baseline_only"]["throughput"]
              and casc["distilled_filter_baseline_rerank"]["p99_ms"]
              <= casc["baseline_only"]["p99_ms"])
    print(f"cascade_beats_baseline_only={better}")

    print("\n# 4. mixed 90% pointwise / 10% ranking-256 traffic, five pools:"
          " count-closed vs item-closed batches")
    print("router,batching,p50_ms,p99_ms,throughput,rejected,slo_attainment")
    mixed = {}
    for r in rows:
        if r["experiment"] != "mixed_batching":
            continue
        mixed[(r["router"], r["batching"])] = r
        print(f"{r['router']},{r['batching']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['slo_attainment']:.3f}")
    wins = all(
        mixed[(p, "items")]["throughput"] > mixed[(p, "count")]["throughput"]
        or (mixed[(p, "items")]["throughput"] >= 0.999 * mixed[(p, "count")]["throughput"]
            and mixed[(p, "items")]["p99_ms"] < mixed[(p, "count")]["p99_ms"])
        for p, _ in ROUTER_CFGS
    )
    print(f"item_batching_wins_or_ties_every_router={wins}")

    print("\n# 5. 3-cell federation, sticky homes skewed 60/25/15, ~80% fleet"
          " load: cross-cell spillover off vs on (5ms inter-cell RTT)")
    print("spillover,p50_ms,p99_ms,throughput,rejected,spilled,slo_attainment,"
          "cell_p99_ms")
    fed = {}
    for r in rows:
        if r["experiment"] != "federation":
            continue
        fed[r["spillover"]] = r
        cell_p99 = " ".join(f"{n}:{p:.0f}" for n, p in r["cell_p99_ms"].items())
        print(f"{r['spillover']},{r['p50_ms']:.1f},{r['p99_ms']:.1f},"
              f"{r['throughput']:.0f},{r['rejected']},{r['spilled']},"
              f"{r['slo_attainment']:.3f},{cell_p99}")
    spill_wins = (fed[True]["p99_ms"] < fed[False]["p99_ms"]
                  and fed[True]["throughput"] >= 0.999 * fed[False]["throughput"])
    print(f"spillover_cuts_p99_at_equal_throughput={spill_wins}")
    return rows


if __name__ == "__main__":
    main()
