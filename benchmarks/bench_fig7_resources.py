"""Paper Fig 7: model size / parameter count / peak serving memory."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import VARIANTS, bench_world, serve_batch
from repro.core.compression_loop import variant_stats
from repro.models.recsys import api


def _peak_bytes(fn, *args) -> int:
    """Compiled peak (args + temps) from memory_analysis on this host."""
    lowered = jax.jit(fn).lower(*args)
    mem = lowered.compile().memory_analysis()
    return int(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
    )


def run() -> list:
    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    stats = variant_stats(ladder)
    batch = serve_batch(cfg, world, 512)
    rows = []
    base_mem = None
    for name in VARIANTS:
        v = ladder[name]
        peak = _peak_bytes(lambda p, b: api.serve(p, b, v["cfg"], rules), v["params"], batch)
        if name == "baseline":
            base_mem = peak
        rows.append({
            "variant": name,
            "params_m": stats[name]["params"] / 1e6,
            "size_mb": stats[name]["bytes"] / 2**20,
            "peak_mem_mb": peak / 2**20,
            "mem_vs_baseline": peak / base_mem,
            "sparsity": stats[name]["sparsity"],
        })
    return rows


def main():
    rows = run()
    print("# Fig 7: resource consumption")
    print("variant,params_m,size_mb,peak_mem_mb,mem_vs_baseline,sparsity")
    for r in rows:
        print(f"{r['variant']},{r['params_m']:.2f},{r['size_mb']:.2f},"
              f"{r['peak_mem_mb']:.1f},{r['mem_vs_baseline']:.3f},{r['sparsity']:.3f}")
    return rows


if __name__ == "__main__":
    main()
