"""Paper Table I: params / model size / latency / throughput for the five
variants. Latency = one candidate-set request (50 items, the paper's
setup); throughput = items/s at the batched serving size. Absolute numbers
are this host's CPU; the paper-faithful claim is the RATIO ladder, printed
against the paper's V100 ratios."""
from __future__ import annotations

import jax

from benchmarks.common import PAPER_TABLE1, VARIANTS, bench_world, serve_batch, time_call
from repro.core.compression_loop import variant_stats
from repro.models.recsys import api


def run() -> list:
    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    stats = variant_stats(ladder)

    rows = []
    req = serve_batch(cfg, world, 50)  # one request = 50 candidates
    bulk = serve_batch(cfg, world, 2048)
    base_lat = base_thpt = None
    for name in VARIANTS:
        v = ladder[name]
        fn = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))
        lat = time_call(fn, v["params"], req)
        t_bulk = time_call(fn, v["params"], bulk)
        thpt = 2048 / t_bulk / 50  # requests/s at 50 candidates each
        if name == "baseline":
            base_lat, base_thpt = lat, thpt
        p = PAPER_TABLE1[name]
        rows.append({
            "variant": name,
            "params_m": stats[name]["params"] / 1e6,
            "size_mb": stats[name]["bytes"] / 2**20,
            "latency_ms": lat * 1e3,
            "throughput_rps": thpt,
            "lat_ratio": lat / base_lat,
            "thpt_ratio": thpt / base_thpt,
            "paper_lat_ratio": p["lat_ms"] / PAPER_TABLE1["baseline"]["lat_ms"],
            "paper_thpt_ratio": p["thpt"] / PAPER_TABLE1["baseline"]["thpt"],
        })
    return rows


def main():
    rows = run()
    print("# Table I reproduction (CPU host; ratios vs paper V100 ratios)")
    hdr = ("variant", "params_m", "size_mb", "latency_ms", "throughput_rps",
           "lat_ratio", "paper_lat_ratio", "thpt_ratio", "paper_thpt_ratio")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[h]:.3f}" if isinstance(r[h], float) else str(r[h]) for h in hdr))
    return rows


if __name__ == "__main__":
    main()
