"""Paper Fig 5: latency & throughput vs batch size per variant (the
latency-model curves that drive the elastic-serving experiments)."""
from __future__ import annotations

import jax

from benchmarks.common import VARIANTS, bench_world, serve_batch, time_call
from repro.models.recsys import api

BATCHES = (1, 16, 64, 256, 1024)


def run() -> list:
    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    rows = []
    for name in VARIANTS:
        v = ladder[name]
        fn = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))
        for bs in BATCHES:
            b = serve_batch(cfg, world, bs)
            t = time_call(fn, v["params"], b, reps=3)
            rows.append({
                "variant": name, "batch": bs,
                "latency_ms": t * 1e3, "items_per_s": bs / t,
            })
    return rows


def main():
    rows = run()
    print("# Fig 5: latency/throughput vs batch")
    print("variant,batch,latency_ms,items_per_s")
    for r in rows:
        print(f"{r['variant']},{r['batch']},{r['latency_ms']:.3f},{r['items_per_s']:.0f}")
    return rows


if __name__ == "__main__":
    main()
