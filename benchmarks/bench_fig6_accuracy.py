"""Paper Fig 6: HitRate@50 / NDCG@50 / MRR retention across the ladder
(candidate set 50, as in the paper's Taobao setup)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import VARIANTS, bench_world
from repro.data.metrics import ranking_metrics
from repro.data.synthetic import taobao_eval_candidates
from repro.models.recsys import api


def run(n_queries: int = 256, n_cand: int = 50) -> list:
    w = bench_world()
    cfg, world, rules, ladder = w["cfg"], w["world"], w["rules"], w["ladder"]
    ev = taobao_eval_candidates(cfg, n_queries=n_queries, n_cand=n_cand, world=world)
    jb = {k: jnp.asarray(v) for k, v in ev["batch"].items()}

    rows = []
    base = None
    for name in VARIANTS:
        v = ladder[name]
        scores = np.asarray(api.serve(v["params"], jb, v["cfg"], rules))
        m = ranking_metrics(scores.reshape(n_queries, n_cand), ev["pos_idx"], k=50)
        m10 = ranking_metrics(scores.reshape(n_queries, n_cand), ev["pos_idx"], k=10)
        if name == "baseline":
            base = m
        rows.append({
            "variant": name,
            "hit_rate@50": m["hit_rate"], "ndcg@50": m["ndcg"], "mrr": m["mrr"],
            "hit_rate@10": m10["hit_rate"],
            "retention_ndcg": m["ndcg"] / max(base["ndcg"], 1e-9),
            "retention_mrr": m["mrr"] / max(base["mrr"], 1e-9),
        })
    return rows


def main():
    rows = run()
    print("# Fig 6: accuracy retention (paper: <1% loss for distilled)")
    print("variant,hit_rate@50,ndcg@50,mrr,hit_rate@10,retention_ndcg,retention_mrr")
    for r in rows:
        print(f"{r['variant']},{r['hit_rate@50']:.4f},{r['ndcg@50']:.4f},"
              f"{r['mrr']:.4f},{r['hit_rate@10']:.4f},{r['retention_ndcg']:.4f},{r['retention_mrr']:.4f}")
    return rows


if __name__ == "__main__":
    main()
