"""Benchmark driver — one module per paper table/figure.

`PYTHONPATH=src python -m benchmarks.run [--only table1,fig6,...]`

Prints each benchmark's own section plus a final ``name,us_per_call,derived``
CSV summary across all of them.
"""
# simlint: disable=SL001  (benchmarks time REAL work: the wall
# clock IS the measurement here, never the simulated clock)
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list: table1,fig5,fig6,fig7,kernels,roofline,serving,engine")
    ap.add_argument("--profile", action="store_true",
                    help="run each chosen benchmark under cProfile and print"
                         " the top-25 cumulative table after its section")
    args = ap.parse_args()

    from benchmarks import (  # noqa: E402 (import here: jax init)
        bench_engine, bench_fig5_perf, bench_fig6_accuracy,
        bench_fig7_resources, bench_kernels, bench_serving, bench_table1,
        roofline,
    )

    benches = {
        "table1": bench_table1.main,
        "fig5": bench_fig5_perf.main,
        "fig6": bench_fig6_accuracy.main,
        "fig7": bench_fig7_resources.main,
        "kernels": bench_kernels.main,
        # empty argv: don't let bench_serving's --smoke parser see --only
        "serving": lambda: bench_serving.main([]),
        "engine": lambda: bench_engine.main([]),
        "roofline": roofline.main,
    }
    chosen = args.only.split(",") if args.only else list(benches)

    if args.profile:
        from benchmarks.profiling import profiled

    summary = []
    failed = 0
    for name in chosen:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            if args.profile:
                profiled(benches[name])
            else:
                benches[name]()
            summary.append((name, (time.time() - t0) * 1e6, "ok"))
        except Exception:
            traceback.print_exc()
            failed += 1
            summary.append((name, (time.time() - t0) * 1e6, "FAILED"))

    print("\n===== summary =====")
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
