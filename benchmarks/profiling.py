"""cProfile helper shared by the benchmark CLIs (`--profile`).

Kept separate from benchmarks/common.py on purpose: common.py carries
the model-benchmark substrate, and the profiler is wanted by kernel-free
benches (bench_engine) too. No repro imports — this wraps any callable.
"""
from __future__ import annotations

import cProfile
import pstats
from typing import Optional


def profiled(fn, *args, top: int = 25, out: Optional[str] = None, **kwargs):
    """Run `fn(*args, **kwargs)` under cProfile, print the top-`top`
    functions by cumulative time, and return fn's result — so a bench
    behaves identically with and without `--profile`, just slower and
    chattier. Hot-loop regressions become diagnosable from the table
    without editing code. With `out`, the FULL (untruncated) table is
    also written to that path — CI uploads it next to the perf JSONs so
    a regression's profile can be diffed across runs."""
    prof = cProfile.Profile()
    try:
        result = prof.runcall(fn, *args, **kwargs)
    finally:
        print(f"\n# cProfile: top {top} by cumulative time")
        pstats.Stats(prof).sort_stats("cumulative").print_stats(top)
        if out is not None:
            with open(out, "w") as fh:
                pstats.Stats(prof, stream=fh).sort_stats(
                    "cumulative").print_stats()
            print(f"# wrote full cProfile table to {out}")
    return result
