"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh): the three terms
    T_compute    = flops_per_device / 197 TFLOP/s
    T_memory     = hbm_bytes_per_device / 819 GB/s
    T_collective = coll_link_bytes_per_device / 50 GB/s
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and the
roofline fraction (useful model flops vs the time the dominant term costs).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs.base import get_config
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def model_flops(cell: str, meta: Dict) -> Optional[float]:
    """Analytic useful flops (global) for the workload."""
    arch = cell.split(":")[0]
    cfg = get_config(arch)
    kind = meta.get("kind")
    if cfg.family == "lm":
        n = cfg.active_param_count()
        toks = meta.get("tokens", 0)
        if kind == "train":
            return 6.0 * n * toks
        if kind == "prefill":
            return 2.0 * n * toks
        return 2.0 * n * toks  # decode: tokens = batch
    if cfg.family == "recsys":
        return None  # embedding-dominated; flops not the useful metric
    return None


def load_rows(mesh: str = "single", include_tags: bool = False) -> List[Dict]:
    rows = []
    pattern = f"*__{mesh}*.json" if include_tags else f"*__{mesh}.json"
    for f in sorted(RESULTS.glob(pattern)):
        if f.name.endswith(".err.json"):
            continue
        rec = json.loads(f.read_text())
        n = rec["n_chips"]
        flops_dev = rec["flops"]
        bytes_dev = rec["bytes_accessed"]
        coll_dev = rec["collectives"]["total"]
        t_c = flops_dev / PEAK_FLOPS_BF16
        t_m = bytes_dev / HBM_BW
        t_x = coll_dev / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        mf = model_flops(rec["cell"], rec.get("meta", {}))
        useful = (mf / n / max(flops_dev, 1.0)) if mf else None
        # roofline fraction: useful-compute time / dominant-term time
        frac = None
        if mf:
            t_useful = mf / n / PEAK_FLOPS_BF16
            frac = t_useful / max(max(terms.values()), 1e-15)
        rows.append({
            "cell": rec["cell"], "mesh": rec["mesh"], "tag": rec.get("tag", ""),
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom, "useful_ratio": useful, "roofline_frac": frac,
            "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
            "arg_gib": rec["memory"]["argument_bytes"] / 2**30,
            "coll_counts": rec.get("collective_counts", {}),
            "top": rec.get("top_computations", [])[:3],
        })
    return rows


def markdown_table(rows: List[Dict]) -> str:
    out = [
        "| cell | T_comp (s) | T_mem (s) | T_coll (s) | bottleneck | useful/HLO | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        fr = f"{r['roofline_frac']:.2f}" if r["roofline_frac"] else "—"
        out.append(
            f"| {r['cell']}{('['+r['tag']+']') if r['tag'] else ''} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['bottleneck']} | {ur} | {fr} "
            f"| {r['temp_gib']:.1f} |"
        )
    return "\n".join(out)


def main():
    rows = load_rows("single")
    print(markdown_table(rows))
    n_bound = {}
    for r in rows:
        n_bound[r["bottleneck"]] = n_bound.get(r["bottleneck"], 0) + 1
    print(f"\nbottleneck histogram: {n_bound}")
    return rows


if __name__ == "__main__":
    main()
