"""Kernel microbenchmarks: oracle (XLA-fused jnp) timings on this CPU host
plus analytic TPU-v5e projections for the Pallas path.

The Pallas kernels run interpret=True here (Python per grid step — not a
speed path); their performance claim is structural: bytes/flops per tile
are computed from the BlockSpecs and projected against v5e peaks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels.block_pruned_matmul.ref import block_pruned_matmul_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.fm_interaction.ref import fm_interaction_ref
from repro.kernels.int8_matmul.ref import int8_matmul_ref, quantize_activations
from repro.kernels.local_attention.ref import local_attention_ref
from repro.launch.analysis import HBM_BW, PEAK_FLOPS_BF16, PEAK_FLOPS_INT8


def _proj(flops: float, bytes_: float, int8: bool = False) -> dict:
    peak = PEAK_FLOPS_INT8 if int8 else PEAK_FLOPS_BF16
    return {
        "t_compute_us": flops / peak * 1e6,
        "t_memory_us": bytes_ / HBM_BW * 1e6,
        "bound": "compute" if flops / peak > bytes_ / HBM_BW else "memory",
    }


def run() -> list:
    rows = []
    key = jax.random.key(0)

    # int8 matmul: 4096x4096x4096
    M = K = N = 512
    a = jax.random.normal(key, (M, K))
    wq, ws = quantize_activations(jax.random.normal(jax.random.key(1), (N, K)))
    aq, as_ = quantize_activations(a)
    t = time_call(jax.jit(int8_matmul_ref), aq, wq.T, as_, ws, reps=3)
    p = _proj(2 * M * K * N, (M * K + K * N) * 1 + M * N * 4, int8=True)
    rows.append(("int8_matmul_ref_512", t * 1e6, f"v5e_proj={p['t_compute_us']:.1f}us/{p['bound']}"))

    # block-pruned matmul at 40% block sparsity
    x = jax.random.normal(key, (512, 512))
    w = jax.random.normal(jax.random.key(2), (512, 512))
    mask = (jax.random.uniform(jax.random.key(3), (4, 4)) > 0.4).astype(jnp.float32)
    t = time_call(jax.jit(lambda x, w, m: block_pruned_matmul_ref(x, w, m, block=128)), x, w, mask, reps=3)
    dens = float(mask.mean())
    p = _proj(2 * 512**3 * dens, (512 * 512 * dens + 512 * 512) * 4)
    rows.append(("block_pruned_ref_512_d%.2f" % dens, t * 1e6, f"v5e_proj={p['t_compute_us']:.1f}us"))

    # windowed attention 2048 seq, w=256
    BH, L, dh, win = 8, 2048, 64, 256
    q, k, v = (jax.random.normal(jax.random.key(i), (BH, L, dh)) for i in range(3))
    t = time_call(jax.jit(lambda q, k, v: local_attention_ref(q, k, v, window=win)), q, k, v, reps=3)
    sparse_flops = 4 * BH * L * win * dh
    dense_flops = 4 * BH * L * L * dh
    p = _proj(sparse_flops, BH * L * dh * 3 * 4)
    rows.append(("local_attn_ref_2048w256", t * 1e6,
                 f"flops_saved={1-sparse_flops/dense_flops:.2f};v5e={max(p['t_compute_us'],p['t_memory_us']):.1f}us"))

    # embedding bag 1M-row table
    V, d, B, nnz = 1_000_000, 32, 4096, 20
    table = jax.random.normal(key, (V, d))
    idx = jax.random.randint(jax.random.key(4), (B, nnz), 0, V)
    t = time_call(jax.jit(embedding_bag_ref), table, idx, reps=3)
    p = _proj(B * nnz * d, B * nnz * (d * 4 + 4))
    rows.append(("embedding_bag_1M_4096x20", t * 1e6, f"v5e_mem={p['t_memory_us']:.1f}us/memory"))

    # FM interaction
    e = jax.random.normal(key, (65536, 39, 10))
    t = time_call(jax.jit(fm_interaction_ref), e, reps=3)
    p = _proj(65536 * 39 * 10 * 4, 65536 * 39 * 10 * 4)
    rows.append(("fm_interaction_65536", t * 1e6, f"v5e_mem={p['t_memory_us']:.1f}us/memory"))

    # AUGRU recurrence (DIEN): B=4096, T=100, g=108
    from repro.kernels.augru.ref import augru_ref

    B, T, g = 4096, 100, 108
    zx = jax.random.normal(key, (B, T, 3 * g))
    wh = jax.random.normal(jax.random.key(5), (g, 3 * g)) * 0.3
    h0 = jnp.zeros((B, g))
    att = jax.random.uniform(jax.random.key(6), (B, T))
    mask = jnp.ones((B, T), bool)
    t = time_call(jax.jit(augru_ref), zx, wh, h0, att, mask, reps=3)
    p = _proj(2 * B * T * g * 3 * g, B * T * (3 * g) * 4)
    rows.append(("augru_4096x100", t * 1e6,
                 f"v5e={max(p['t_compute_us'], p['t_memory_us']):.1f}us/{p['bound']}"))
    return rows


def main():
    rows = run()
    print("# kernel microbenches (CPU oracle timing; v5e projection derived)")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
