"""Shared benchmark substrate: one trained teacher + compression ladder,
reused by every paper-table benchmark (built lazily, cached in-process)."""
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.data.synthetic import TaobaoWorld, taobao_batches, taobao_eval_candidates
from repro.distributed.sharding import RECSYS_RULES, adapt_rules
from repro.models.common import init_params
from repro.models.recsys import api
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step

VARIANTS = ("baseline", "quantized", "pruned", "pruned_quantized", "distilled")

# Paper Table I reference numbers (V100 ms / req/s) for side-by-side ratios.
PAPER_TABLE1 = {
    "baseline": dict(params_m=32.0, size_mb=128.0, lat_ms=52.4, thpt=190),
    "quantized": dict(params_m=32.0, size_mb=32.0, lat_ms=44.1, thpt=225),
    "pruned": dict(params_m=19.2, size_mb=76.8, lat_ms=36.7, thpt=260),
    "pruned_quantized": dict(params_m=19.2, size_mb=19.2, lat_ms=29.8, thpt=325),
    "distilled": dict(params_m=6.4, size_mb=12.8, lat_ms=21.5, thpt=460),
}


@lru_cache(maxsize=1)
def bench_world():
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh()
    rules = adapt_rules(RECSYS_RULES, mesh)
    cfg = get_config("taobao_ssa")
    fields = tuple(
        dataclasses.replace(f, vocab=min(f.vocab, 20_000)) for f in cfg.fields
    )
    cfg = dataclasses.replace(cfg, fields=fields)
    world = TaobaoWorld(20_000, 20_000, 10_000)

    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 3e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)
    gen = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in taobao_batches(cfg, 512, 10_000, world=world, seed=1)
    )
    for i, b in zip(range(200), gen):
        params, state, _ = step(params, state, b)

    def batch_fn():
        for b in taobao_batches(cfg, 512, 10_000, world=world, seed=3):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ladder = run_ladder(
        params, cfg, rules, batch_fn,
        LadderConfig(finetune_steps=15, qat_steps=15, distill_steps=30),
    )
    return {"cfg": cfg, "world": world, "rules": rules, "ladder": ladder}


def time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of a blocking call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def serve_batch(cfg, world, batch: int, seed: int = 11) -> Dict:
    gen = taobao_batches(cfg, batch, 1, world=world, seed=seed)
    b = next(iter(gen))
    return {k: jnp.asarray(v) for k, v in b.items() if k != "label"}
