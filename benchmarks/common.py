"""Shared benchmark substrate: one trained teacher + compression ladder,
reused by every paper-table benchmark (built lazily, cached in-process),
plus the --json artifact schema every bench main writes through.

jax and the model stack import INSIDE the functions that need them, so
`from benchmarks.common import bench_payload` stays cheap — the event-
kernel bench (bench_engine.py) must keep its worker subprocesses and its
aggregation path free of jax for attributable RSS numbers."""
# simlint: disable=SL001  (benchmarks time REAL work: the wall
# clock IS the measurement here, never the simulated clock)
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache
from typing import Dict, Sequence

import numpy as np

VARIANTS = ("baseline", "quantized", "pruned", "pruned_quantized", "distilled")

# --json artifact schema, shared by every bench main. Bump when the
# top-level payload shape changes so downstream diff tooling can refuse
# mixed-version comparisons instead of silently misreading fields.
# v2: optional top-level "breakdown" — latency-attribution waterfall rows
# (core/serving/tracing.py taxonomy), one per (label, component).
BENCH_SCHEMA_VERSION = 2

# the keys every breakdown row must carry: which run it describes, which
# latency component, the summed seconds attributed to it, and its share
# of the run's summed end-to-end latency
BREAKDOWN_ROW_KEYS = ("label", "component", "seconds", "share")


def _check_rows(bench: str, what: str, rows, keys: Sequence[str]) -> list:
    rows = list(rows)
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise TypeError(f"{bench} {what} {i} is not a dict: {row!r}")
        missing = [k for k in keys if k not in row]
        if missing:
            raise ValueError(
                f"{bench} {what} {i} is missing required keys {missing}"
                f" (has {sorted(row)})")
    return rows


def bench_payload(bench: str, rows: Sequence[dict], *, smoke: bool,
                  row_keys: Sequence[str] = (),
                  breakdown: Sequence[dict] = None, **extra) -> dict:
    """The validated payload a bench --json run writes: a stable
    top-level shape {bench, schema_version, smoke, rows, ...} so
    BENCH_*.json artifacts diff across PRs without per-bench parsers.
    `row_keys` are the keys this bench promises on EVERY row; a missing
    one raises here, before a malformed artifact hits disk. `breakdown`
    (schema v2) optionally attaches latency-attribution rows — each must
    carry BREAKDOWN_ROW_KEYS, so waterfall diffs stay parseable too."""
    rows = _check_rows(bench, "row", rows, row_keys)
    payload = {"bench": bench, "schema_version": BENCH_SCHEMA_VERSION,
               "smoke": bool(smoke), "rows": rows, **extra}
    if breakdown is not None:
        payload["breakdown"] = _check_rows(
            bench, "breakdown row", breakdown, BREAKDOWN_ROW_KEYS)
    return payload

# Paper Table I reference numbers (V100 ms / req/s) for side-by-side ratios.
PAPER_TABLE1 = {
    "baseline": dict(params_m=32.0, size_mb=128.0, lat_ms=52.4, thpt=190),
    "quantized": dict(params_m=32.0, size_mb=32.0, lat_ms=44.1, thpt=225),
    "pruned": dict(params_m=19.2, size_mb=76.8, lat_ms=36.7, thpt=260),
    "pruned_quantized": dict(params_m=19.2, size_mb=19.2, lat_ms=29.8, thpt=325),
    "distilled": dict(params_m=6.4, size_mb=12.8, lat_ms=21.5, thpt=460),
}


@lru_cache(maxsize=1)
def bench_world():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.core.compression_loop import LadderConfig, run_ladder
    from repro.data.synthetic import TaobaoWorld, taobao_batches
    from repro.distributed.sharding import RECSYS_RULES, adapt_rules
    from repro.launch.mesh import make_test_mesh
    from repro.models.common import init_params
    from repro.models.recsys import api
    from repro.training.optimizer import get_optimizer
    from repro.training.train_loop import make_train_step

    mesh = make_test_mesh()
    rules = adapt_rules(RECSYS_RULES, mesh)
    cfg = get_config("taobao_ssa")
    fields = tuple(
        dataclasses.replace(f, vocab=min(f.vocab, 20_000)) for f in cfg.fields
    )
    cfg = dataclasses.replace(cfg, fields=fields)
    world = TaobaoWorld(20_000, 20_000, 10_000)

    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 3e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)
    gen = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in taobao_batches(cfg, 512, 10_000, world=world, seed=1)
    )
    for i, b in zip(range(200), gen):
        params, state, _ = step(params, state, b)

    def batch_fn():
        for b in taobao_batches(cfg, 512, 10_000, world=world, seed=3):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ladder = run_ladder(
        params, cfg, rules, batch_fn,
        LadderConfig(finetune_steps=15, qat_steps=15, distill_steps=30),
    )
    return {"cfg": cfg, "world": world, "rules": rules, "ladder": ladder}


def time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of a blocking call."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def serve_batch(cfg, world, batch: int, seed: int = 11) -> Dict:
    import jax.numpy as jnp

    from repro.data.synthetic import taobao_batches

    gen = taobao_batches(cfg, batch, 1, world=world, seed=seed)
    b = next(iter(gen))
    return {k: jnp.asarray(v) for k, v in b.items() if k != "label"}
