"""Multi-cell serving federation demo (serving/federation.py): three cells
— each its own ServingSystem with cell-local budget and SLO monitor — on
one shared event loop, sticky (home-cell) traffic skewed 60/25/15 so the
hot cell runs past its local capacity while the fleet has headroom.

Three scenarios:
  1. spillover off: the hot cell queues and sheds while the other two
     cells idle — fleet p99 is the hot cell's p99;
  2. spillover on: requests past the hot cell's SLO headroom take one hop
     (paying a 5ms inter-cell RTT) to the best remote cell — fleet p99
     recovers at equal-or-better fleet throughput;
  3. cascade rerank spillover: the hot cell's heavy rerank pool is
     undersized, so stage 2 of the cascade spills to the cold cell's
     rerank pool while stage 1 stays home (stage timeline stamps survive
     the hop).

    PYTHONPATH=src python examples/multi_cell.py
"""
from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.engine import PoolSpec, poisson_arrivals
from repro.core.serving.federation import CellSpec, FederatedSystem, assign_homes
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec

BASELINE = lambda: ReplicaSpec("baseline", LatencyModel.analytic(0.018, 0.0008),
                               cold_start_s=5.0, warm_start_s=0.2)
DISTILLED = lambda: ReplicaSpec("distilled", LatencyModel.analytic(0.004, 0.0001),
                                cold_start_s=2.0, warm_start_s=0.2)

SKEW = {"us": 0.60, "eu": 0.25, "ap": 0.15}


def report(name, res):
    print(f"{name:34s} p50={res['p50']*1e3:7.1f}ms p99={res['p99']*1e3:7.1f}ms "
          f"thpt={res['throughput']:6.0f}/s rej={res['rejected']:5d} "
          f"spilled={res['spilled']:5d} slo={res['slo_attainment']:.3f}")
    for cname, c in res["cells"].items():
        sp = c["spill"]
        print(f"  {cname}: arrived={c['arrived']:6d} completed={c['completed']:6d} "
              f"p99={c['p99']*1e3:7.1f}ms spill_out={sp['spilled_out']:5d} "
              f"spill_in={sp['spilled_in']:5d}")
    return res


def skewed_fleet(spillover):
    cells = {
        name: CellSpec(
            pools={"baseline": PoolSpec(
                BASELINE(),
                PoolConfig(n_replicas=2, autoscale=False, max_batch=32,
                           max_wait_s=0.02))},
            slo_p99_s=0.15,
        )
        for name in SKEW
    }
    fed = FederatedSystem(cells, policy="sticky", spillover=spillover,
                          rtt_s=0.005, slo_p99_s=0.15)
    arr = poisson_arrivals(lambda t: 2400.0, 20.0, seed=0, priority_frac=0.0)
    assign_homes(arr, SKEW, seed=1)
    label = "spillover on" if spillover else "spillover off"
    report(f"3 cells, 60/25/15 skew [{label}]", fed.run(arr, until=20.0))


def cascade_rerank_spill():
    """Ranking traffic through per-cell cascades: the hot cell's rerank
    pool has 1 replica (undersized), the cold cell's has 4 — under load
    the rerank stage spills cross-cell while the filter stage stays home."""
    def cell(n_rerank):
        return CellSpec(
            pools={
                "distilled": PoolSpec(DISTILLED(), PoolConfig(
                    n_replicas=4, autoscale=False, max_batch=4,
                    priority_bypass=False)),
                "baseline": PoolSpec(BASELINE(), PoolConfig(
                    n_replicas=n_rerank, autoscale=False, max_batch=4,
                    priority_bypass=False)),
            },
            cascade=CascadeConfig("distilled", "baseline",
                                  candidates=256, rerank_k=16),
            tiers={"tier0": TierPolicy(1e9, 1e9), "tier1": TierPolicy(1e9, 1e9)},
            slo_p99_s=0.3,
        )

    fed = FederatedSystem({"hot": cell(1), "cold": cell(4)}, policy="sticky",
                          spillover=True, rtt_s=0.005, slo_p99_s=0.3)
    arr = poisson_arrivals(lambda t: 120.0, 15.0, seed=3, priority_frac=0.0)
    assign_homes(arr, {"hot": 0.9, "cold": 0.1}, seed=4)
    res = report("cascade, undersized hot rerank", fed.run(arr, until=15.0))
    print(f"  rerank stages spilled cross-cell: {res['cascade_spilled']}")
    spilled = [r for r in arr
               if "s2_enqueue" in r.timeline
               and r.timeline["s2_enqueue"] - r.timeline["s1_done"] > 1e-9]
    if spilled:
        r = spilled[0]
        tl = r.timeline
        print(f"  example spilled request {r.rid}: s1_done={tl['s1_done']:.4f} "
              f"-> +5ms RTT -> s2_enqueue={tl['s2_enqueue']:.4f} "
              f"s2_done={tl['s2_done']:.4f} (stage stamps survive the hop)")


def main():
    print("fleet: 3 cells x 2 baseline replicas; 2400 QPS, homes skewed "
          f"{SKEW}; SLO p99 = 150ms, inter-cell RTT = 5ms")
    skewed_fleet(spillover=False)
    skewed_fleet(spillover=True)
    print("\ncascade rerank spillover (2 cells, 90/10 skew, SLO p99 = 300ms):")
    cascade_rerank_spill()


if __name__ == "__main__":
    main()
