"""Elastic-scheduling study (paper §IV.B): the same traffic spike served
with (a) fixed replicas, (b) autoscaling, (c) autoscaling + warm pool +
priority bypass — demonstrating each mechanism's contribution.

    PYTHONPATH=src python examples/elastic_scaling.py
"""
from repro.core.serving.autoscaler import ScalerConfig
from repro.core.serving.engine import ElasticEngine, EngineConfig, poisson_arrivals
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec

SPIKE = lambda t: 120.0 if t < 15 else (1100.0 if t < 40 else 150.0)


def scenario(name, *, autoscale, warm_pool, bypass, cold=5.0):
    spec = ReplicaSpec(
        "model", LatencyModel.analytic(0.018, 0.0008),
        cold_start_s=cold, warm_start_s=0.2,
    )
    eng = ElasticEngine(
        spec,
        EngineConfig(n_replicas=2, autoscale=autoscale, slo_p99_s=0.15,
                     max_batch=32, priority_bypass=bypass),
        tiers={"tier0": TierPolicy(1500, 120), "tier1": TierPolicy(1500, 120)},
        scaler_cfg=ScalerConfig(min_replicas=2, warm_pool_size=4 if warm_pool else 0),
    )
    arrivals = poisson_arrivals(SPIKE, 60.0, seed=0, priority_frac=0.03)
    res = eng.run(arrivals, until=60.0)
    tr = res["trace"]
    print(f"{name:34s} p50={res['p50']*1e3:8.1f}ms p99={res['p99']*1e3:8.1f}ms "
          f"thpt={res['throughput']:6.0f}/s shed={res['rejected']:6d} "
          f"max_repl={max(tr['replicas']) if tr['replicas'] else 2}")
    return res


def main():
    print("traffic: 120 QPS -> 1100 QPS spike -> 150 QPS; SLO p99 = 150ms")
    scenario("fixed 2 replicas", autoscale=False, warm_pool=False, bypass=False)
    scenario("autoscale (cold starts)", autoscale=True, warm_pool=False, bypass=False)
    scenario("autoscale + warm pool", autoscale=True, warm_pool=True, bypass=False)
    scenario("autoscale + warm pool + bypass", autoscale=True, warm_pool=True, bypass=True)


if __name__ == "__main__":
    main()
