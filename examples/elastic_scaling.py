"""Elastic-scheduling study (paper §IV.B) on the multi-pool engine: the
same traffic spike served by (a) a fixed single pool, (b) an autoscaled
pool, (c) autoscaling + warm pool + priority bypass, then the refactor's
new scenarios — (d) a heterogeneous baseline+distilled fleet behind each
router policy (including the recommended cost_model), (e) ranking traffic
as a RecPipe-style cascade vs the baseline pool alone under one shared
capacity budget, and the cost-aware serving path — (f) mixed pointwise +
ranking traffic with count-closed vs item-closed batches, (g) a
per-pool cost-weighted rate limiter protecting the heavy pool while the
cheap pool keeps absorbing tail traffic, and (h) the adaptive control
plane — a pool whose offline calibration is 2x off its true service
times misroutes under cost-model routing until an OnlineLatencyModel
learns the correction from observed batches, and SLO-aware batch sizing
narrows a too-wide item cap on breach (serving/control.py).

    PYTHONPATH=src python examples/elastic_scaling.py
"""
from repro.core.serving.autoscaler import ScalerConfig
from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.control import ControlConfig
from repro.core.serving.engine import (
    ElasticEngine, EngineConfig, PoolSpec, ServingSystem, poisson_arrivals,
)
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.core.serving.router import make_router

SPIKE = lambda t: 120.0 if t < 15 else (1100.0 if t < 40 else 150.0)
RANK_SPIKE = lambda t: 25.0 if t < 15 else (110.0 if t < 40 else 35.0)

BASELINE = lambda: ReplicaSpec("baseline", LatencyModel.analytic(0.018, 0.0008),
                               cold_start_s=5.0, warm_start_s=0.2)
DISTILLED = lambda: ReplicaSpec("distilled", LatencyModel.analytic(0.004, 0.0001),
                                cold_start_s=2.0, warm_start_s=0.2)


def report(name, res):
    tr = res["trace"]
    print(f"{name:38s} p50={res['p50']*1e3:8.1f}ms p99={res['p99']*1e3:8.1f}ms "
          f"thpt={res['throughput']:6.0f}/s shed={res['rejected']:6d} "
          f"slo={res['slo_attainment']:.3f} "
          f"max_repl={max(tr['replicas'], default=0)}")
    return res


def single_pool(name, *, autoscale, warm_pool, bypass, cold=5.0):
    spec = ReplicaSpec("model", LatencyModel.analytic(0.018, 0.0008),
                       cold_start_s=cold, warm_start_s=0.2)
    eng = ElasticEngine(
        spec,
        EngineConfig(n_replicas=2, autoscale=autoscale, slo_p99_s=0.15,
                     max_batch=32, priority_bypass=bypass),
        tiers={"tier0": TierPolicy(1500, 120), "tier1": TierPolicy(1500, 120)},
        scaler_cfg=ScalerConfig(min_replicas=2, warm_pool_size=4 if warm_pool else 0),
    )
    arrivals = poisson_arrivals(SPIKE, 60.0, seed=0, priority_frac=0.03)
    return report(name, eng.run(arrivals, until=60.0))


def heterogeneous(policy, **kw):
    pools = {
        "baseline": PoolSpec(BASELINE(), PoolConfig(n_replicas=2, max_batch=32)),
        "distilled": PoolSpec(DISTILLED(), PoolConfig(n_replicas=2, max_batch=32)),
    }
    sys_ = ServingSystem(
        pools, make_router(policy, **kw),
        tiers={"tier0": TierPolicy(1500, 120), "tier1": TierPolicy(1500, 120)},
        slo_p99_s=0.15, capacity=12,
    )
    arrivals = poisson_arrivals(SPIKE, 60.0, seed=0, priority_frac=0.03)
    res = report(f"hetero 2-pool [{policy}]", sys_.run(arrivals, until=60.0))
    share = ", ".join(f"{n}={p['completed']}" for n, p in res["pools"].items())
    print(f"{'':38s} pool share: {share}")


def ranking(mode):
    candidates, k = 512, 32
    tiers = {"tier0": TierPolicy(200, 40), "tier1": TierPolicy(200, 40)}
    pcfg = lambda: PoolConfig(n_replicas=2, max_batch=4, priority_bypass=False)
    if mode == "baseline_only":
        sys_ = ServingSystem({"baseline": PoolSpec(BASELINE(), pcfg())},
                             tiers=tiers, slo_p99_s=0.3, capacity=8)
        arrivals = poisson_arrivals(RANK_SPIKE, 60.0, seed=0, cost=candidates,
                                    priority_frac=0.0)
    else:
        sys_ = ServingSystem(
            {"distilled": PoolSpec(DISTILLED(), pcfg()),
             "baseline": PoolSpec(BASELINE(), pcfg())},
            cascade=CascadeConfig("distilled", "baseline",
                                  candidates=candidates, rerank_k=k),
            tiers=tiers, slo_p99_s=0.3, capacity=8)
        arrivals = poisson_arrivals(RANK_SPIKE, 60.0, seed=0, priority_frac=0.0)
    report(f"ranking 512-cand [{mode}]", sys_.run(arrivals, until=60.0))


def mixed_batching(batching):
    """90% pointwise + 10% ranking traffic: a 256-candidate query in a
    count-closed batch stalls every pointwise query sharing it; the item
    budget keeps per-batch service time bounded."""
    cap = 256 if batching == "items" else None
    pools = {
        "baseline": PoolSpec(BASELINE(), PoolConfig(
            n_replicas=2, max_batch=64, max_wait_s=0.02, max_batch_items=cap)),
        "distilled": PoolSpec(DISTILLED(), PoolConfig(
            n_replicas=2, max_batch=64, max_wait_s=0.02, max_batch_items=cap)),
    }
    sys_ = ServingSystem(
        pools, make_router("cost_model"),
        tiers={"tier0": TierPolicy(1500, 300), "tier1": TierPolicy(1500, 300)},
        slo_p99_s=0.15, capacity=12,
    )
    arrivals = poisson_arrivals(lambda t: 250.0, 40.0, seed=0, priority_frac=0.02,
                                cost_mix=((1, 0.9), (256, 0.1)))
    report(f"mixed traffic [{batching}-closed batches]", sys_.run(arrivals, until=40.0))


def per_pool_admission(protected):
    """Overload the bulk-scoring pool with ranking traffic (the cost-model
    router sends ranking there — its latency curve is flattest at large
    batch): the pool's own cost-weighted limiter sheds work it cannot
    serve inside the SLO, while the pointwise pool keeps serving every
    request it is routed. Without the pool limiter the bulk queue grows
    without bound and its stage p99 explodes."""
    bulk_tiers = (
        {"tier0": TierPolicy(6400, 2600), "tier1": TierPolicy(6400, 2600)}
        if protected else None)
    pools = {
        "bulk": PoolSpec(
            ReplicaSpec("bulk", LatencyModel.analytic(0.030, 2e-5),
                        cold_start_s=5.0, warm_start_s=0.2),
            PoolConfig(n_replicas=2, autoscale=False, max_batch=4,
                       max_batch_items=512, priority_bypass=False),
            tiers=bulk_tiers),
        "point": PoolSpec(
            ReplicaSpec("point", LatencyModel.analytic(0.002, 1e-3),
                        cold_start_s=2.0, warm_start_s=0.2),
            PoolConfig(n_replicas=2, autoscale=False)),
    }
    sys_ = ServingSystem(pools, make_router("cost_model"), slo_p99_s=0.25,
                         adaptive_shedding=False)
    arrivals = poisson_arrivals(lambda t: 250.0, 30.0, seed=0, priority_frac=0.0,
                                cost_mix=((1, 0.7), (256, 0.3)))
    label = "per-pool limiter" if protected else "fleet limiter only"
    res = report(f"bulk-pool overload [{label}]", sys_.run(arrivals, until=30.0))
    for name, p in res["pools"].items():
        print(f"{'':38s} {name}: completed={p['completed']} shed={p['shed']} "
              f"stage_p99={p['p99']*1e3:.0f}ms")


def adaptive_control(mode):
    """The control plane (serving/control.py) closing the feedback loop:
    the "drifted" pool's offline calibration claims it is 2x faster than
    it really is, so the cost-model router floods it. Static: the stale
    calibration stands for the whole run. Adaptive: every completed
    batch's measured service time EWMA-corrects the predicted curve
    (watch the learned correction converge to ~2.0), and the router
    recovers the oracle split."""
    truth = LatencyModel.analytic(0.020, 0.001)
    claims_2x_faster = LatencyModel.analytic(0.010, 0.0005)
    ctl = ControlConfig(online_latency=True, adapt_batch=False)
    pcfg = lambda: PoolConfig(n_replicas=2, autoscale=False, max_batch=4,
                              max_wait_s=0.02, priority_bypass=False)
    pools = {
        "accurate": PoolSpec(
            ReplicaSpec("accurate", truth, cold_start_s=5.0, warm_start_s=0.2),
            pcfg(), control=ctl if mode == "adaptive" else None),
        "drifted": PoolSpec(
            ReplicaSpec("drifted", claims_2x_faster, cold_start_s=5.0,
                        warm_start_s=0.2, true_latency=truth),
            pcfg(), control=ctl if mode == "adaptive" else None),
    }
    sys_ = ServingSystem(pools, make_router("cost_model"), slo_p99_s=1.0,
                         adaptive_shedding=False)
    arrivals = poisson_arrivals(lambda t: 45.0, 30.0, seed=0, cost=64,
                                priority_frac=0.0)
    res = report(f"2x mis-calibrated pool [{mode}]", sys_.run(arrivals, until=30.0))
    corr = ", ".join(f"{n}: corr={p['control']['latency_correction']:.2f}"
                     for n, p in res["pools"].items())
    print(f"{'':38s} learned {corr}")


def adaptive_batch_sizing(mode):
    """SLO-aware batch sizing: ranking traffic in the item-capped
    batching regime, where a static 1024-item cap makes every request
    eat the wide batch's fill + service time. The BatchSizeController
    narrows the effective cap on SLO breach and widens it under
    headroom, per scale tick, from the pool's own windowed p99."""
    ctl = ControlConfig(online_latency=False, adapt_batch=True,
                        min_batch_items=128, max_batch_items=1024)
    pools = {"bulk": PoolSpec(
        BASELINE(),
        PoolConfig(n_replicas=2, autoscale=False, max_batch=256,
                   max_wait_s=1.0, max_batch_items=1024,
                   priority_bypass=False),
        control=ctl if mode == "adaptive" else None)}
    sys_ = ServingSystem(pools, slo_p99_s=0.6, adaptive_shedding=False)
    arrivals = poisson_arrivals(lambda t: 90.0, 30.0, seed=0, cost=16,
                                priority_frac=0.0)
    res = report(f"1024-item cap vs SLO [{mode}]", sys_.run(arrivals, until=30.0))
    cap = res["pools"]["bulk"]["control"]["max_batch_items"]
    print(f"{'':38s} effective max_batch_items at end: {cap}")


def main():
    print("traffic: 120 QPS -> 1100 QPS spike -> 150 QPS; SLO p99 = 150ms")
    single_pool("fixed 2 replicas", autoscale=False, warm_pool=False, bypass=False)
    single_pool("autoscale (cold starts)", autoscale=True, warm_pool=False, bypass=False)
    single_pool("autoscale + warm pool", autoscale=True, warm_pool=True, bypass=False)
    single_pool("autoscale + warm pool + bypass", autoscale=True, warm_pool=True, bypass=True)
    print("\nheterogeneous fleet (baseline + distilled), capacity budget 12:")
    heterogeneous("least_loaded")
    heterogeneous("power_of_two", seed=0)
    heterogeneous("slo_aware", slo_p99_s=0.15, quality_order=("baseline", "distilled"))
    heterogeneous("cost_model")
    print("\nranking traffic (512 candidates/query), capacity budget 8, SLO p99 = 300ms:")
    ranking("baseline_only")
    ranking("cascade")
    print("\nmixed 90% pointwise / 10% ranking-256 traffic (cost_model router):")
    mixed_batching("count")
    mixed_batching("items")
    print("\nper-pool cost-weighted admission under a ranking overload:")
    per_pool_admission(protected=False)
    per_pool_admission(protected=True)
    print("\nadaptive control plane (serving/control.py):")
    adaptive_control("static")
    adaptive_control("adaptive")
    adaptive_batch_sizing("static")
    adaptive_batch_sizing("adaptive")


if __name__ == "__main__":
    main()
