"""End-to-end driver (deliverable b): train ~few-hundred steps, run the
paper's compression ladder, SERVE batched requests through the elastic
engine — the full paper pipeline: model-level (C1-C5) + system-level
(C7) — then show the caching layer end-to-end: a resident hot-row tier
built from the TRAINED item embedding table (exact against the uncached
lookup), and a warm-cache vs cold/no-cache serving comparison on the
calibrated baseline variant under Zipf id traffic.

    PYTHONPATH=src python examples/compress_and_serve.py [--steps 300]
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.caching import (
    build_resident_table, cached_embedding_bag, hot_ids, residency_mask,
)
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.core.serving.cache import CacheConfig
from repro.core.serving.engine import (
    ElasticEngine, EngineConfig, PoolSpec, ServingSystem, attach_zipf_ids,
    poisson_arrivals,
)
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec, sustainable_rate
from repro.data.synthetic import zipf_id_stream
from repro.models.recsys.embedding import embedding_bag
from repro.data.synthetic import TaobaoWorld, taobao_batches
from repro.distributed.sharding import RECSYS_RULES, adapt_rules
from repro.launch.mesh import make_test_mesh
from repro.models.common import init_params
from repro.models.recsys import api
from repro.training.fault_tolerance import FTConfig, ResilientTrainer
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    args = ap.parse_args()

    mesh = make_test_mesh()
    rules = adapt_rules(RECSYS_RULES, mesh)
    cfg = get_config("taobao_ssa")
    cfg = dataclasses.replace(
        cfg, fields=tuple(dataclasses.replace(f, vocab=min(f.vocab, 50_000)) for f in cfg.fields)
    )
    world = TaobaoWorld(50_000, 50_000, 10_000)

    # ---- stage 1: fault-tolerant training (checkpoints + resume path) ----
    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 2e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)

    def mk_batches(start):
        return ({k: jnp.asarray(v) for k, v in b.items()}
                for b in taobao_batches(cfg, args.batch, 10**9, world=world, seed=100 + start))

    trainer = ResilientTrainer(
        step, FTConfig(ckpt_dir="/tmp/repro_e2e_ckpt", ckpt_every=100), make_batches=mk_batches
    )
    t0 = time.time()
    params, state, restarts, last = trainer.run(params, state, args.steps)
    print(f"trained {last} steps in {time.time()-t0:.0f}s (restarts={restarts})")

    # ---- stage 2: the paper's ladder ----
    ladder = run_ladder(
        params, cfg, rules, lambda: mk_batches(777),
        LadderConfig(finetune_steps=20, qat_steps=20, distill_steps=40),
    )
    print(json.dumps(variant_stats(ladder), indent=2, default=str))

    # ---- stage 3: serve every variant through the elastic engine ----
    def batch_of(n, seed=5):
        b = next(iter(taobao_batches(cfg, n, 1, world=world, seed=seed)))
        return {k: jnp.asarray(v) for k, v in b.items() if k != "label"}

    fixed = {b: batch_of(b) for b in (1, 8, 32, 128, 512)}
    spike = lambda t: 150.0 if t < 10 else (900.0 if t < 30 else 200.0)
    arrivals = poisson_arrivals(spike, 45.0, seed=0)

    print(f"{'variant':18s} {'svc@1':>8s} {'svc@512':>8s} {'p50':>8s} {'p99':>8s} {'thpt':>8s}")
    for name, v in ladder.items():
        jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))

        def call(bs):
            jax.block_until_ready(jitted(v["params"], fixed[bs]))

        lat = LatencyModel.calibrate(call, reps=2)
        eng = ElasticEngine(
            ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2),
            EngineConfig(n_replicas=2, autoscale=True, slo_p99_s=0.15),
            tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
        )
        res = eng.run(arrivals, until=45.0)
        print(f"{name:18s} {lat(1)*1e3:7.2f}ms {lat(512)*1e3:7.1f}ms "
              f"{res['p50']*1e3:7.1f}ms {res['p99']*1e3:7.1f}ms {res['throughput']:7.0f}/s")
        if name == "baseline":
            baseline_lat = lat

    # ---- stage 4: the caching layer, end to end ----
    # (a) real arrays: a resident tier of the TRAINED item table's hot
    # rows; the cached lookup must match the uncached one exactly
    item_table = ladder["baseline"]["params"]["tables"]["item"]
    vocab = int(item_table.shape[0])  # padded rows included — all gatherable
    stream = zipf_id_stream(40_000, vocab, 1.1, seed=11)
    resident = build_resident_table(item_table, hot_ids(stream, 4096))
    idx = jnp.asarray(stream[:4096].reshape(256, 16))
    cached = cached_embedding_bag(item_table, resident, idx)
    uncached = embedding_bag(item_table, idx)
    exact = bool(jnp.array_equal(cached, uncached))
    res_frac = float(residency_mask(resident, idx).mean())
    print(f"\ncached embedding_bag == uncached: {exact} "
          f"(resident lookups: {res_frac:.0%} of {idx.size})")

    # (b) simulation: the calibrated baseline under Zipf id traffic —
    # every missed row pays an embedding-fetch cost, so a warm hot-ID
    # cache beats the no-cache fleet on tail latency AND throughput at
    # the same offered load (bench_serving experiment 6, here with the
    # latency model calibrated moments ago)
    ids_per_req = 16
    spec = ReplicaSpec("baseline", baseline_lat, cold_start_s=5.0, warm_start_s=0.2,
                       embed_fetch_s=2.0 * baseline_lat(32) / (32 * ids_per_req))
    wait, horizon = 0.02, 20.0
    r_cold = sustainable_rate(spec, 2, wait, ids_per_req, hit_rate=0.0)
    r_warm = sustainable_rate(spec, 2, wait, ids_per_req, hit_rate=0.85)
    rate = min(1.2 * r_cold, 0.9 * r_warm)
    print(f"offered {rate:.0f} q/s (cold sustains ~{r_cold:.0f}, warm ~{r_warm:.0f})")
    print(f"{'cache':10s} {'hit_rate':>8s} {'p50':>8s} {'p99':>8s} {'thpt':>8s}")
    for label, cache in (("none", None), ("lru_warm", CacheConfig(4096, "lru"))):
        sys_ = ServingSystem(
            {"baseline": PoolSpec(
                spec, PoolConfig(n_replicas=2, autoscale=False,
                                 max_batch=32, max_wait_s=wait),
                cache=cache)},
            slo_p99_s=0.15, adaptive_shedding=False)
        if cache is not None:
            sys_.pools["baseline"].embed_cache.warm(stream)
        arr = poisson_arrivals(lambda t: rate, horizon, seed=12, priority_frac=0.0)
        attach_zipf_ids(arr, vocab, ids_per_req, alpha=1.1, seed=13)
        res = sys_.run(arr, until=horizon)
        print(f"{label:10s} {res['cache']['hit_rate']:8.3f} "
              f"{res['p50']*1e3:7.1f}ms {res['p99']*1e3:7.1f}ms "
              f"{res['throughput']:7.0f}/s")


if __name__ == "__main__":
    main()
