"""End-to-end driver (deliverable b): train ~few-hundred steps, run the
paper's compression ladder, then SERVE batched requests through the elastic
engine — the full paper pipeline: model-level (C1-C5) + system-level (C7).

    PYTHONPATH=src python examples/compress_and_serve.py [--steps 300]
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.core.serving.engine import ElasticEngine, EngineConfig, poisson_arrivals
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.data.synthetic import TaobaoWorld, taobao_batches
from repro.distributed.sharding import RECSYS_RULES, adapt_rules
from repro.launch.mesh import make_test_mesh
from repro.models.common import init_params
from repro.models.recsys import api
from repro.training.fault_tolerance import FTConfig, ResilientTrainer
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    args = ap.parse_args()

    mesh = make_test_mesh()
    rules = adapt_rules(RECSYS_RULES, mesh)
    cfg = get_config("taobao_ssa")
    cfg = dataclasses.replace(
        cfg, fields=tuple(dataclasses.replace(f, vocab=min(f.vocab, 50_000)) for f in cfg.fields)
    )
    world = TaobaoWorld(50_000, 50_000, 10_000)

    # ---- stage 1: fault-tolerant training (checkpoints + resume path) ----
    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 2e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)

    def mk_batches(start):
        return ({k: jnp.asarray(v) for k, v in b.items()}
                for b in taobao_batches(cfg, args.batch, 10**9, world=world, seed=100 + start))

    trainer = ResilientTrainer(
        step, FTConfig(ckpt_dir="/tmp/repro_e2e_ckpt", ckpt_every=100), make_batches=mk_batches
    )
    t0 = time.time()
    params, state, restarts, last = trainer.run(params, state, args.steps)
    print(f"trained {last} steps in {time.time()-t0:.0f}s (restarts={restarts})")

    # ---- stage 2: the paper's ladder ----
    ladder = run_ladder(
        params, cfg, rules, lambda: mk_batches(777),
        LadderConfig(finetune_steps=20, qat_steps=20, distill_steps=40),
    )
    print(json.dumps(variant_stats(ladder), indent=2, default=str))

    # ---- stage 3: serve every variant through the elastic engine ----
    def batch_of(n, seed=5):
        b = next(iter(taobao_batches(cfg, n, 1, world=world, seed=seed)))
        return {k: jnp.asarray(v) for k, v in b.items() if k != "label"}

    fixed = {b: batch_of(b) for b in (1, 8, 32, 128, 512)}
    spike = lambda t: 150.0 if t < 10 else (900.0 if t < 30 else 200.0)
    arrivals = poisson_arrivals(spike, 45.0, seed=0)

    print(f"{'variant':18s} {'svc@1':>8s} {'svc@512':>8s} {'p50':>8s} {'p99':>8s} {'thpt':>8s}")
    for name, v in ladder.items():
        jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rules))

        def call(bs):
            jax.block_until_ready(jitted(v["params"], fixed[bs]))

        lat = LatencyModel.calibrate(call, reps=2)
        eng = ElasticEngine(
            ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2),
            EngineConfig(n_replicas=2, autoscale=True, slo_p99_s=0.15),
            tiers={"tier0": TierPolicy(1500, 150), "tier1": TierPolicy(1500, 150)},
        )
        res = eng.run(arrivals, until=45.0)
        print(f"{name:18s} {lat(1)*1e3:7.2f}ms {lat(512)*1e3:7.1f}ms "
              f"{res['p50']*1e3:7.1f}ms {res['p99']*1e3:7.1f}ms {res['throughput']:7.0f}/s")


if __name__ == "__main__":
    main()
