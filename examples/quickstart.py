"""Quickstart: train the paper's Baseline ranker on synthetic Taobao logs,
apply the paper's full compression ladder, compare accuracy + size.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.data.metrics import ranking_metrics
from repro.data.synthetic import TaobaoWorld, taobao_batches, taobao_eval_candidates
from repro.distributed.sharding import RECSYS_RULES, adapt_rules
from repro.launch.mesh import make_test_mesh
from repro.models.common import init_params
from repro.models.recsys import api
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


def main():
    mesh = make_test_mesh()
    rules = adapt_rules(RECSYS_RULES, mesh)

    # The paper's Baseline (taobao_ssa) at laptop vocab scale
    cfg = get_config("taobao_ssa")
    cfg = dataclasses.replace(
        cfg, fields=tuple(dataclasses.replace(f, vocab=min(f.vocab, 10_000)) for f in cfg.fields)
    )
    world = TaobaoWorld(10_000, 10_000, 5_000)

    print("== 1. train the teacher ==")
    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 2e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)
    gen = ({k: jnp.asarray(v) for k, v in b.items()}
           for b in taobao_batches(cfg, 512, 10**6, world=world, seed=1))
    for i, b in zip(range(100), gen):
        params, state, m = step(params, state, b)
        if i % 25 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}")

    print("== 2. compression ladder (prune -> finetune -> quantize -> QAT, + distill) ==")
    def batch_fn():
        for b in taobao_batches(cfg, 512, 10**6, world=world, seed=3):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ladder = run_ladder(params, cfg, rules, batch_fn,
                        LadderConfig(finetune_steps=15, qat_steps=15, distill_steps=30))

    print("== 3. evaluate (candidate set 50, as in the paper) ==")
    ev = taobao_eval_candidates(cfg, n_queries=256, n_cand=50, world=world)
    jb = {k: jnp.asarray(v) for k, v in ev["batch"].items()}
    stats = variant_stats(ladder)
    print(f"{'variant':18s} {'params':>10s} {'size':>10s} {'HR@10':>7s} {'NDCG@50':>8s} {'MRR':>7s}")
    for name, v in ladder.items():
        scores = np.asarray(api.serve(v["params"], jb, v["cfg"], rules))
        m = ranking_metrics(scores.reshape(256, 50), ev["pos_idx"], k=50)
        m10 = ranking_metrics(scores.reshape(256, 50), ev["pos_idx"], k=10)
        s = stats[name]
        print(f"{name:18s} {s['params']/1e6:9.2f}M {s['bytes']/2**20:9.2f}M "
              f"{m10['hit_rate']:7.3f} {m['ndcg']:8.3f} {m['mrr']:7.3f}")


if __name__ == "__main__":
    main()
