"""NequIP substrate tests: CG-path equivariance (property-based over random
rotations), model invariance, sampler correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, settings, strategies as st
from scipy.spatial.transform import Rotation

from repro.configs.base import get_config
from repro.models.common import init_params
from repro.models.gnn import nequip
from repro.models.gnn.irreps import (
    DIM, path_list, rotate_features, spherical_harmonics, to_matrix, to_vec5,
)
from repro.models.gnn.sampler import CSRGraph, sample_subgraph, subgraph_sizes


def test_vec5_matrix_roundtrip():
    t = jax.random.normal(jax.random.key(0), (10, 5))
    np.testing.assert_allclose(to_vec5(to_matrix(t)), t, rtol=1e-5, atol=1e-6)
    m = to_matrix(t)
    np.testing.assert_allclose(m, jnp.swapaxes(m, -1, -2), atol=1e-6)  # symmetric
    np.testing.assert_allclose(jnp.trace(m, axis1=-2, axis2=-1), 0.0, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 100_000))
def test_all_cg_paths_equivariant(seed):
    """Every coupling path commutes with rotations (the NequIP invariant)."""
    R = jnp.asarray(Rotation.random(random_state=seed).as_matrix(), jnp.float32)
    feats = {
        l: jax.random.normal(jax.random.key(seed + l), (3, 2, DIM[l])) for l in (0, 1, 2)
    }
    vecs = jax.random.normal(jax.random.key(seed + 7), (3, 3))
    sh = spherical_harmonics(vecs)
    shR = spherical_harmonics(vecs @ R.T)
    featsR = rotate_features(feats, R)
    for lf, ls, lo, fn in path_list():
        a = fn(feats[lf], sh[ls][:, None, :])
        b = fn(featsR[lf], shR[ls][:, None, :])
        aR = rotate_features({lo: a}, R)[lo]
        np.testing.assert_allclose(
            aR, b, rtol=2e-4, atol=2e-4,
            err_msg=f"path ({lf},{ls})->{lo} not equivariant",
        )


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 1000))
def test_model_rotation_invariance(seed, gnn_rules):
    cfg = get_config("nequip")
    N, E = 16, 40
    pos = jax.random.normal(jax.random.key(seed), (N, 3)) * 2
    src = jax.random.randint(jax.random.key(seed + 1), (E,), 0, N)
    dst = jax.random.randint(jax.random.key(seed + 2), (E,), 0, N)
    species = jax.random.randint(jax.random.key(seed + 3), (N,), 0, 8)
    params = init_params(nequip.param_defs(cfg, n_classes=3), jax.random.key(0))
    g = {"positions": pos, "edge_src": src, "edge_dst": dst, "species": species}
    out1 = nequip.forward(params, g, cfg, gnn_rules)
    R = jnp.asarray(Rotation.random(random_state=seed).as_matrix(), jnp.float32)
    out2 = nequip.forward(params, dict(g, positions=pos @ R.T), cfg, gnn_rules)
    np.testing.assert_allclose(out1, out2, rtol=5e-4, atol=5e-4)


def test_translation_invariance(gnn_rules):
    cfg = get_config("nequip")
    N, E = 12, 30
    pos = jax.random.normal(jax.random.key(0), (N, 3))
    src = jax.random.randint(jax.random.key(1), (E,), 0, N)
    dst = jax.random.randint(jax.random.key(2), (E,), 0, N)
    species = jax.random.randint(jax.random.key(3), (N,), 0, 8)
    params = init_params(nequip.param_defs(cfg, n_classes=2), jax.random.key(0))
    g = {"positions": pos, "edge_src": src, "edge_dst": dst, "species": species}
    out1 = nequip.forward(params, g, cfg, gnn_rules)
    out2 = nequip.forward(params, dict(g, positions=pos + 5.0), cfg, gnn_rules)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-5)


def test_cutoff_kills_long_edges(gnn_rules):
    """Messages through edges beyond the cutoff radius vanish."""
    cfg = get_config("nequip")
    pos = jnp.array([[0.0, 0, 0], [100.0, 0, 0], [1.0, 0, 0]])
    params = init_params(nequip.param_defs(cfg, n_classes=2), jax.random.key(0))
    g1 = {
        "positions": pos,
        "edge_src": jnp.array([1], jnp.int32),  # far node -> node 0
        "edge_dst": jnp.array([0], jnp.int32),
        "species": jnp.array([1, 2, 3], jnp.int32),
    }
    g2 = dict(g1, edge_src=jnp.array([1], jnp.int32) * 0 + 1,
              edge_dst=jnp.array([0], jnp.int32))
    out_far = nequip.forward(params, g1, cfg, gnn_rules)
    # same graph but with NO edges at all (mask the only edge)
    g3 = dict(g1, edge_mask=jnp.array([False]))
    out_none = nequip.forward(params, g3, cfg, gnn_rules)
    np.testing.assert_allclose(out_far, out_none, rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(
    n_seeds=st.integers(2, 16),
    f1=st.integers(1, 6),
    f2=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_sampler_shapes_and_bounds(n_seeds, f1, f2, seed):
    rng = np.random.default_rng(seed)
    N = 100
    src = rng.integers(0, N, 400)
    dst = rng.integers(0, N, 400)
    g = CSRGraph.from_edges(src, dst, N)
    sub = sample_subgraph(g, rng.integers(0, N, n_seeds), (f1, f2), rng)
    nn, ne = subgraph_sizes(n_seeds, (f1, f2))
    assert sub["node_ids"].shape == (nn,)
    assert sub["edge_src"].shape == (ne,)
    assert sub["edge_src"].max() < nn and sub["edge_dst"].max() < nn
    assert sub["seed_mask"].sum() == n_seeds


def test_sampled_neighbors_are_real_neighbors():
    rng = np.random.default_rng(0)
    N = 50
    src = rng.integers(0, N, 300)
    dst = rng.integers(0, N, 300)
    g = CSRGraph.from_edges(src, dst, N)
    in_nbrs = {i: set(src[dst == i]) for i in range(N)}
    nodes = rng.integers(0, N, 20)
    samp = g.sample_neighbors(nodes, 5, rng)
    for node, row in zip(nodes, samp):
        allowed = in_nbrs[node] | {node}  # isolated nodes self-loop
        assert set(row.tolist()) <= allowed
