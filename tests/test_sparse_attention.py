"""C2 sparse attention: mask properties, equivalences, Formula-4 accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, settings, strategies as st

from repro.core.sparse_attention import (
    attention_flops, hybrid_sparse_attention, local_global_mask,
    masked_attention, windowed_attention,
)
from repro.models.layers import (
    decode_attention, dense_attention, flash_attention, sparse_decode_attention,
)


@settings(deadline=None, max_examples=20)
@given(
    L=st.integers(8, 64),
    w=st.integers(1, 64),
    ng=st.integers(0, 8),
    causal=st.booleans(),
)
def test_mask_properties(L, w, ng, causal):
    m = np.asarray(local_global_mask(L, w, ng, causal=causal))
    # diagonal always attendable
    assert m.diagonal().all()
    if causal:
        assert not np.triu(m, 1).any()
    elif ng == 0:
        # pure window is symmetric; global COLUMNS (BigBird-style) are not
        np.testing.assert_array_equal(m, m.T)
    # every row has at least one key
    assert m.any(axis=1).all()
    # window rows: position j within |i-j|<w attendable (causal: j<=i)
    i, j = L // 2, max(0, L // 2 - min(w - 1, L // 2))
    assert m[i, j]


def test_window_ge_L_equals_dense():
    B, H, L, dh = 2, 2, 32, 16
    q, k, v = (jax.random.normal(jax.random.key(i), (B, H, L, dh)) for i in range(3))
    out_w = windowed_attention(q, k, v, window=L)
    s = jnp.einsum("bhld,bhmd->bhlm", q, k) / np.sqrt(dh)
    p = jax.nn.softmax(s, -1)
    dense = jnp.einsum("bhlm,bhmd->bhld", p, v)
    np.testing.assert_allclose(out_w, dense, rtol=1e-5, atol=1e-5)


def test_flash_equals_dense_gqa():
    B, S, K, G, hd = 2, 64, 2, 2, 16
    q = jax.random.normal(jax.random.key(0), (B, S, K, G, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, K, hd))
    o1 = dense_attention(q, k, v, causal=True)
    o2 = flash_attention(q, k, v, causal=True, kv_chunk=16)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_sparse_decode_covers_window():
    """With window >= pos+1 and no dedup issues, sparse decode == dense decode."""
    B, T, K, G, hd = 2, 32, 2, 2, 8
    q = jax.random.normal(jax.random.key(0), (B, 1, K, G, hd))
    kc = jax.random.normal(jax.random.key(1), (B, T, K, hd))
    vc = jax.random.normal(jax.random.key(2), (B, T, K, hd))
    pos = jnp.array([10, 31])
    dense = decode_attention(q, kc, vc, pos)
    sparse = sparse_decode_attention(q, kc, vc, pos, window=T, n_global=4)
    np.testing.assert_allclose(dense, sparse, rtol=1e-4, atol=1e-5)


def test_hybrid_includes_global_columns():
    L = 32
    m_local = np.asarray(local_global_mask(L, 4, 0))
    m_hybrid = np.asarray(local_global_mask(L, 4, 8))
    assert m_hybrid.sum() > m_local.sum()
    gained = m_hybrid & ~m_local
    cols = np.unique(np.where(gained)[1])
    assert len(cols) <= 8  # only the sampled global columns


def test_formula4_accounting():
    acc = attention_flops(L=32768, d=64, window=4096, n_global=1024)
    assert acc["sparse"] / acc["dense"] == pytest.approx((4096 + 1024) / 32768)
    assert acc["ratio"] < 0.16  # paper: 'cuts overall compute'
