"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_pruned_matmul.block_pruned_matmul import block_pruned_matmul
from repro.kernels.block_pruned_matmul.ref import block_pruned_matmul_ref
from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.fm_interaction.fm_interaction import fm_interaction_kernel
from repro.kernels.fm_interaction.ref import fm_interaction_ref
from repro.kernels.int8_matmul.int8_matmul import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref, quantize_activations
from repro.kernels.local_attention.local_attention import local_attention
from repro.kernels.local_attention.ref import local_attention_ref


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 128), (384, 256, 512)])
@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128)])
def test_int8_matmul_shapes(M, K, N, bm, bn, bk):
    key = jax.random.key(M + K + N)
    a = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.key(1), (K, N))
    a_q, a_s = quantize_activations(a)
    w_q, w_s = quantize_activations(w.T)
    w_q = w_q.T
    out = int8_matmul(a_q, w_q, a_s, w_s, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = int8_matmul_ref(a_q, w_q, a_s, w_s)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-4)
    # quantized matmul approximates the f32 one to ~1-2%
    rel = float(jnp.abs(out - a @ w).max() / jnp.abs(a @ w).max())
    assert rel < 0.05


@pytest.mark.parametrize("density", [0.0, 0.3, 0.7, 1.0])
def test_block_pruned_matmul(density):
    M = K = N = 256
    x = jax.random.normal(jax.random.key(0), (M, K))
    w = jax.random.normal(jax.random.key(1), (K, N))
    mask = (jax.random.uniform(jax.random.key(2), (K // 128, N // 128)) < density)
    out = block_pruned_matmul(x, w, mask.astype(jnp.int32), interpret=True)
    ref = block_pruned_matmul_ref(x, w, mask, block=128)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("L,window", [(256, 64), (512, 128), (512, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_local_attention(causal, L, window, dtype):
    BH, dh = 2, 32
    q = jax.random.normal(jax.random.key(0), (BH, L, dh)).astype(dtype)
    k = jax.random.normal(jax.random.key(1), (BH, L, dh)).astype(dtype)
    v = jax.random.normal(jax.random.key(2), (BH, L, dh)).astype(dtype)
    out = local_attention(q, k, v, window=window, causal=causal, interpret=True)
    ref = local_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        window=window, causal=causal,
    )
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("B,nnz,d", [(4, 5, 16), (16, 10, 32), (8, 1, 64)])
def test_embedding_bag(B, nnz, d):
    V = 500
    table = jax.random.normal(jax.random.key(0), (V, d))
    idx = jax.random.randint(jax.random.key(1), (B, nnz), 0, V)
    w = jax.random.uniform(jax.random.key(2), (B, nnz))
    out = embedding_bag(table, idx, w, interpret=True)
    ref = embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_cached_embedding_bag_matches_ref_exactly(weighted, combiner):
    """The hot-row resident tier (core/caching.py) must be invisible to
    correctness: resident-only, miss-only and mixed lookups all match the
    embedding_bag oracle BITWISE — resident rows are exact copies and the
    reduce path is identical."""
    from repro.core.caching import build_resident_table, cached_embedding_bag, hot_ids
    from repro.data.synthetic import zipf_id_stream

    V, d, B, nnz = 400, 32, 16, 6
    table = jax.random.normal(jax.random.key(3), (V, d))
    stream = zipf_id_stream(4000, V, 1.2, seed=7)
    resident = build_resident_table(table, hot_ids(stream, 64))
    w = jax.random.uniform(jax.random.key(4), (B, nnz)) if weighted else None
    res_ids = np.asarray(hot_ids(stream, 64))
    cases = {
        "resident_only": np.random.default_rng(0).choice(res_ids, (B, nnz)),
        "mixed": np.asarray(stream[: B * nnz]).reshape(B, nnz),
        "miss_only": np.setdiff1d(np.arange(V), res_ids)[: B * nnz].reshape(B, nnz),
    }
    for name, idx in cases.items():
        idx = jnp.asarray(idx.astype(np.int32))
        out = cached_embedding_bag(table, resident, idx, mask=w, combiner=combiner)
        ref = _bag_oracle(table, idx, w, combiner)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref), err_msg=name)


def _bag_oracle(table, idx, w, combiner):
    from repro.models.recsys.embedding import embedding_bag as bag

    return bag(table, idx, mask=w, combiner=combiner)


def test_cached_embedding_bag_int8_table():
    """The int8-quantized table layout dequantizes identically through
    the resident tier (rows cached dequantized) and the fallback path."""
    from repro.core.caching import build_resident_table, cached_embedding_bag, residency_mask

    V, d, B, nnz = 200, 16, 8, 5
    q = jax.random.randint(jax.random.key(5), (V, d), -127, 128, dtype=jnp.int8)
    s = jax.random.uniform(jax.random.key(6), (V,), minval=0.01, maxval=0.1)
    table = {"q": q, "s": s}
    resident = build_resident_table(table, np.arange(32, dtype=np.int64))
    idx = jax.random.randint(jax.random.key(7), (B, nnz), 0, V)
    out = cached_embedding_bag(table, resident, idx)
    ref = _bag_oracle(table, idx, None, "sum")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    hits = residency_mask(resident, idx)
    assert 0 < int(hits.sum()) < idx.size  # genuinely mixed hit/miss


@pytest.mark.parametrize("B,F,k", [(256, 39, 10), (512, 8, 16)])
def test_fm_interaction(B, F, k):
    e = jax.random.normal(jax.random.key(0), (B, F, k))
    out = fm_interaction_kernel(e, bb=min(B, 256), interpret=True)
    ref = fm_interaction_ref(e)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,T,g", [(128, 20, 16), (256, 50, 32)])
def test_augru_kernel(B, T, g):
    from repro.kernels.augru.augru import augru
    from repro.kernels.augru.ref import augru_ref

    zx = jax.random.normal(jax.random.key(0), (B, T, 3 * g))
    wh = jax.random.normal(jax.random.key(1), (g, 3 * g)) * 0.3
    h0 = jax.random.normal(jax.random.key(2), (B, g)) * 0.1
    att = jax.random.uniform(jax.random.key(3), (B, T))
    mask = jax.random.uniform(jax.random.key(4), (B, T)) > 0.2
    out = augru(zx, wh, h0, att, mask, bb=128, interpret=True)
    ref = augru_ref(zx, wh, h0, att, mask)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_augru_matches_dien_model_cell():
    """The kernel recurrence equals the model's _gru_cell-based scan."""
    from repro.kernels.augru.ref import augru_ref
    from repro.models.recsys import dien as dien_mod

    B, T, g, du = 4, 10, 8, 6
    params = {
        "augru_wx": jax.random.normal(jax.random.key(0), (du, 3 * g)) * 0.3,
        "augru_wh": jax.random.normal(jax.random.key(1), (g, 3 * g)) * 0.3,
        "augru_b": jnp.zeros((3 * g,)),
    }
    xs = jax.random.normal(jax.random.key(2), (B, T, du))
    mask = jnp.ones((B, T), bool)
    att = jax.random.uniform(jax.random.key(3), (B, T))
    h_model, _ = dien_mod._run_gru(params, "augru", xs, mask, g, att=att)
    zx = xs @ params["augru_wx"] + params["augru_b"]
    h_kernel = augru_ref(zx, params["augru_wh"], jnp.zeros((B, g)), att, mask)
    np.testing.assert_allclose(h_model, h_kernel, rtol=1e-5, atol=1e-5)
