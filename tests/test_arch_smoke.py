"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_lm, reduced_recsys
from repro.configs.base import ARCH_NAMES, get_config
from repro.models.common import abstract_params, init_params, param_pspecs

LM_ARCHS = ["command_r_35b", "chatglm3_6b", "yi_6b", "olmoe_1b_7b",
            "llama4_maverick_400b_a17b"]
REC_ARCHS = ["fm", "din", "autoint", "dien", "taobao_ssa"]


def _lm_batch(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.key(0), (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch, lm_rules):
    from repro.models import transformer as tf

    cfg = reduced_lm(arch)
    params = init_params(tf.param_defs(cfg), jax.random.key(0))
    batch = _lm_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: tf.loss(p, b, cfg, lm_rules))(params, batch)
    assert loss.shape == () and not jnp.isnan(loss)

    logits, (k, v) = jax.jit(lambda p, t: tf.prefill(p, t, cfg, lm_rules))(
        params, batch["tokens"]
    )
    assert logits.shape == (2, cfg.vocab_size)
    assert k.shape[0] == cfg.n_layers and not jnp.isnan(logits).any()

    # one decode step continuing the prefix
    T = 48
    kc = jnp.zeros(tf.cache_shape(cfg, 2, T), k.dtype).at[:, :, :32].set(k)
    vc = jnp.zeros(tf.cache_shape(cfg, 2, T), v.dtype).at[:, :, :32].set(v)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg, (kc2, vc2) = jax.jit(lambda p, c, t, q: tf.decode(p, c, t, q, cfg, lm_rules))(
        params, (kc, vc), tok, jnp.full((2,), 32, jnp.int32)
    )
    assert lg.shape == (2, cfg.vocab_size) and not jnp.isnan(lg).any()


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step_decreases_loss(arch, lm_rules):
    from repro.models import transformer as tf
    from repro.training.optimizer import get_optimizer
    from repro.training.train_loop import make_train_step

    cfg = reduced_lm(arch)
    params = init_params(tf.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 3e-3)
    step = jax.jit(make_train_step(lambda p, b: tf.loss(p, b, cfg, lm_rules), opt))
    state = opt.init(params)
    batch = _lm_batch(cfg, B=4, S=32)  # overfit one batch
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def _rec_batch(cfg, B=8):
    key = jax.random.key(0)
    if cfg.interaction in ("fm", "self_attn"):
        return {
            "sparse_idx": jax.random.randint(key, (B, len(cfg.fields)), 0, 100),
            "label": jax.random.bernoulli(key, 0.4, (B,)).astype(jnp.float32),
        }
    L = cfg.seq_len
    return {
        "user": jax.random.randint(key, (B,), 0, 100),
        "item": jax.random.randint(key, (B,), 0, 100),
        "category": jax.random.randint(key, (B,), 0, 100),
        "hist_item": jax.random.randint(key, (B, L), 0, 100),
        "hist_category": jax.random.randint(key, (B, L), 0, 100),
        "hist_len": jax.random.randint(key, (B,), 1, L),
        "label": jax.random.bernoulli(key, 0.4, (B,)).astype(jnp.float32),
    }


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke(arch, rec_rules):
    from repro.models.recsys import api

    cfg = reduced_recsys(arch)
    params = init_params(api.param_defs(cfg), jax.random.key(0))
    batch = _rec_batch(cfg)
    loss, _ = jax.jit(lambda p, b: api.loss(p, b, cfg, rec_rules))(params, batch)
    assert not jnp.isnan(loss)
    probs = jax.jit(lambda p, b: api.serve(p, b, cfg, rec_rules))(params, batch)
    assert probs.shape == (8,) and ((probs >= 0) & (probs <= 1)).all()

    # retrieval scoring path
    q = _rec_batch(cfg, 1)
    q.pop("label")
    cand = jax.random.randint(jax.random.key(3), (64,), 0, 100)
    if cfg.interaction not in ("fm", "self_attn"):
        q["cand_category"] = jax.random.randint(jax.random.key(4), (64,), 0, 100)
    scores = jax.jit(lambda p, qq, c: api.retrieval(p, qq, c, cfg, rec_rules))(
        params, q, cand
    )
    assert scores.shape == (64,) and not jnp.isnan(scores).any()


def test_nequip_smoke(gnn_rules):
    from repro.data.synthetic import molecule_batch, random_graph
    from repro.models.gnn import nequip

    cfg = get_config("nequip")
    g = random_graph(64, 6, d_feat=33, n_classes=7, seed=0)
    g = {k: jnp.asarray(v) for k, v in g.items()}
    params = init_params(nequip.param_defs(cfg, d_feat=33, n_classes=7), jax.random.key(0))
    loss, _ = jax.jit(lambda p, b: nequip.node_class_loss(p, b, cfg, gnn_rules))(params, g)
    assert not jnp.isnan(loss)

    mb = {k: jnp.asarray(v) for k, v in molecule_batch(8).items()}
    params_e = init_params(nequip.param_defs(cfg, n_classes=1), jax.random.key(1))
    le, _ = jax.jit(lambda p, b: nequip.energy_loss(p, b, cfg, gnn_rules))(params_e, mb)
    assert not jnp.isnan(le)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_configs_resolve(arch):
    """The FULL assigned configs instantiate (abstract only — no allocation)
    and match the assignment's parameter scales."""
    cfg = get_config(arch)
    if cfg.family == "lm":
        from repro.models import transformer as tf

        defs = tf.param_defs(cfg)
        n = sum(np.prod(d.shape) for d in jax.tree.leaves(defs, is_leaf=lambda x: hasattr(x, "shape")))
        expected = {
            "command_r_35b": 35e9, "chatglm3_6b": 6e9, "yi_6b": 6e9,
            "olmoe_1b_7b": 7e9, "llama4_maverick_400b_a17b": 400e9,
        }[arch]
        assert 0.5 * expected < n < 1.7 * expected, f"{arch}: {n:.2e}"
        abstract_params(defs)  # no allocation
    elif cfg.family == "recsys":
        from repro.models.recsys import api

        abstract_params(api.param_defs(cfg))
