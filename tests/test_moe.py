"""MoE dispatch: EP shard_map path and GSPMD path vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.distributed.expert_parallel import moe_ffn_ep
from repro.models.common import init_params
from repro.models.moe import capacity, moe_ffn, moe_param_defs


def _setup(E=8, K=2, cf=8.0, D=32, F=64):
    cfg = LMConfig(
        name="m", family="lm", n_layers=2, d_model=D, n_heads=4, n_kv_heads=2,
        d_ff=F, vocab_size=64, n_experts=E, top_k=K, capacity_factor=cf,
        dtype="float32",
    )
    defs = moe_param_defs(cfg, 1, jnp.float32)
    params = init_params(defs, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], params)
    x = jax.random.normal(jax.random.key(1), (2, 16, D))
    return cfg, lp, x


def _oracle(x, lp, K):
    T, D = x.shape[0] * x.shape[1], x.shape[2]
    xt = x.reshape(T, D)
    probs = jax.nn.softmax(xt @ lp["router"], -1)
    gate, eidx = jax.lax.top_k(probs, K)
    gate = gate / gate.sum(-1, keepdims=True)

    def ffn_e(xr, e):
        g = xr @ lp["w_gate"][e]
        u = xr @ lp["w_up"][e]
        return (jax.nn.silu(g) * u) @ lp["w_down"][e]

    out = jnp.zeros_like(xt)
    for k in range(K):
        out = out + jax.vmap(ffn_e)(xt, eidx[:, k]) * gate[:, k : k + 1]
    return out.reshape(x.shape)


@pytest.mark.parametrize("E,K", [(8, 1), (8, 2), (4, 4)])
def test_ep_matches_oracle(E, K, lm_rules):
    cfg, lp, x = _setup(E=E, K=K)
    out, aux = jax.jit(lambda x, lp: moe_ffn_ep(x, lp, cfg, lm_rules))(x, lp)
    np.testing.assert_allclose(out, _oracle(x, lp, K), rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_gspmd_matches_oracle():
    cfg, lp, x = _setup()
    out, aux = jax.jit(lambda x, lp: moe_ffn(x, lp, cfg))(x, lp)
    np.testing.assert_allclose(out, _oracle(x, lp, 2), rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_ep_differentiable(lm_rules):
    cfg, lp, x = _setup()
    g = jax.grad(lambda lp: jnp.sum(moe_ffn_ep(x, lp, cfg, lm_rules)[0] ** 2))(lp)
    total = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


def test_capacity_drops_bound_output():
    """With a tiny capacity factor, dropped tokens contribute zero (never
    NaN/garbage)."""
    import dataclasses

    cfg, lp, x = _setup(cf=0.25)
    out, _ = jax.jit(lambda x, lp: moe_ffn(x, lp, cfg))(x, lp)
    assert not jnp.isnan(out).any()
    cfg_full = dataclasses.replace(cfg, capacity_factor=8.0)
    out_full, _ = jax.jit(lambda x, lp: moe_ffn(x, lp, cfg_full))(x, lp)
    # dropped rows are exactly zero-contribution: norm can only shrink
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(out_full)) + 1e-3


def test_capacity_formula():
    assert capacity(1024, 8, 2, 1.25) == 320
    assert capacity(8, 64, 1, 1.0) >= 4  # floor
