"""Synthetic data generators + ranking metrics."""
import numpy as np
import pytest

from conftest import reduced_recsys
from repro.data.metrics import auc, ranking_metrics
from repro.data.synthetic import (
    TaobaoWorld, criteo_batches, lm_token_batches, molecule_batch,
    random_graph, taobao_batches, taobao_eval_candidates, zipf_id_stream,
)


def test_zipf_id_stream_deterministic_replay_and_skew():
    """The caching layer's workload generator: bit-identical under the
    same seed (bench_serving experiment 6 and the cache tests replay it),
    in range, and genuinely Zipf-skewed (hot head far above uniform)."""
    a = zipf_id_stream(20_000, 5000, 1.2, seed=9)
    b = zipf_id_stream(20_000, 5000, 1.2, seed=9)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int64 and a.shape == (20_000,)
    assert a.min() >= 0 and a.max() < 5000
    # the 1% hottest ids (the smallest, by construction) carry way more
    # than their uniform 1% share
    assert np.mean(a < 50) > 0.2
    assert not np.array_equal(a, zipf_id_stream(20_000, 5000, 1.2, seed=10))
    # flatter alpha spreads mass down the tail
    flat = zipf_id_stream(20_000, 5000, 0.6, seed=9)
    assert np.mean(flat < 50) < np.mean(a < 50)


def test_ranking_metrics_known():
    scores = np.array([[3.0, 2.0, 1.0], [1.0, 2.0, 3.0]])
    pos = np.array([0, 0])  # q0: rank 0; q1: rank 2
    m = ranking_metrics(scores, pos, k=2)
    assert m["hit_rate"] == 0.5
    assert m["mrr"] == pytest.approx((1.0 + 1 / 3) / 2)
    assert m["ndcg"] == pytest.approx((1.0 + 0.0) / 2)


def test_auc_known():
    assert auc(np.array([0.9, 0.8, 0.2, 0.1]), np.array([1, 1, 0, 0])) == 1.0
    assert auc(np.array([0.1, 0.2, 0.8, 0.9]), np.array([1, 1, 0, 0])) == 0.0
    assert 0.4 < auc(np.random.default_rng(0).random(500),
                     np.random.default_rng(1).integers(0, 2, 500)) < 0.6


def test_taobao_batches_shapes_and_determinism():
    cfg = reduced_recsys("taobao_ssa")
    w = TaobaoWorld(1000, 1000, 1000)
    b1 = next(taobao_batches(cfg, 32, 1, world=w, seed=5))
    b2 = next(taobao_batches(cfg, 32, 1, world=w, seed=5))
    assert b1["hist_item"].shape == (32, cfg.seq_len)
    np.testing.assert_array_equal(b1["user"], b2["user"])
    assert set(np.unique(b1["label"])) <= {0.0, 1.0}
    # labels balanced by construction
    assert 0.3 < b1["label"].mean() < 0.7


def test_taobao_labels_learnable_signal():
    """Affinity-aligned candidates are labeled positive more often."""
    cfg = reduced_recsys("taobao_ssa")
    w = TaobaoWorld(1000, 1000, 1000)
    b = next(taobao_batches(cfg, 4096, 1, world=w, seed=2))
    aff = w.affinity(b["user"], b["item"])
    pos_aff = aff[b["label"] > 0.5].mean()
    neg_aff = aff[b["label"] < 0.5].mean()
    assert pos_aff > neg_aff + 0.1


def test_eval_candidates():
    cfg = reduced_recsys("taobao_ssa")
    ev = taobao_eval_candidates(cfg, n_queries=8, n_cand=10)
    assert ev["batch"]["item"].shape == (80,)
    assert ev["pos_idx"].shape == (8,) and (ev["pos_idx"] < 10).all()


def test_criteo_batches():
    cfg = reduced_recsys("fm")
    b = next(criteo_batches(cfg, 64, 1))
    assert b["sparse_idx"].shape == (64, 39)
    vocabs = np.array([f.vocab for f in cfg.fields])
    assert (b["sparse_idx"] < vocabs[None, :]).all()


def test_graph_generators():
    g = random_graph(100, 4, d_feat=16)
    assert g["features"].shape == (100, 16)
    assert g["edge_src"].max() < 100
    mb = molecule_batch(4, n_nodes=10, n_edges=20)
    assert mb["positions"].shape == (40, 3)
    assert mb["graph_ids"].max() == 3
    assert mb["edge_src"].min() >= 0 and mb["edge_src"].max() < 40


def test_lm_token_batches():
    b = next(lm_token_batches(128, 4, 16, 1))
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
