"""Shared --json artifact schema (benchmarks/common.py): every bench's
perf artifact goes through `bench_payload`, which stamps the schema
version and refuses rows missing the keys downstream tooling reads.
common.py keeps its model imports lazy so this (and bench_engine's RSS
workers) can import it without pulling in jax."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import (  # noqa: E402
    BENCH_SCHEMA_VERSION, BREAKDOWN_ROW_KEYS, bench_payload,
)


def test_bench_payload_stamps_schema_and_passes_rows_through():
    rows = [{"experiment": "a", "p99_ms": 1.0, "throughput": 2.0, "extra": 1}]
    out = bench_payload("serving", rows, smoke=True,
                        row_keys=("experiment", "p99_ms", "throughput"))
    assert out["bench"] == "serving"
    assert out["schema_version"] == BENCH_SCHEMA_VERSION
    assert out["smoke"] is True
    assert out["rows"] == rows  # extra per-row keys survive untouched
    # top-level extras (bench_engine attaches its speedups dict) ride along
    tagged = bench_payload("engine", [], smoke=False, speedups={"k": 2.0})
    assert tagged["speedups"] == {"k": 2.0} and tagged["smoke"] is False


def test_bench_payload_rejects_incomplete_rows():
    good = {"experiment": "a", "p99_ms": 1.0}
    with pytest.raises(ValueError, match=r"row 1 is missing.*throughput"):
        bench_payload("serving", [dict(good, throughput=0.0), good],
                      smoke=True, row_keys=("experiment", "p99_ms",
                                            "throughput"))
    with pytest.raises(TypeError, match="row 0 is not a dict"):
        bench_payload("serving", [("tuple", "row")], smoke=True)
    # no required keys declared -> any dict row is acceptable
    assert bench_payload("x", [{}], smoke=False)["rows"] == [{}]


def test_bench_payload_carries_validated_breakdown_rows():
    """Schema v2: the optional breakdown block (latency-attribution
    waterfall rows) is validated against BREAKDOWN_ROW_KEYS, absent when
    not provided, and passed through untouched when well-formed."""
    assert BENCH_SCHEMA_VERSION >= 2
    wf = [{"label": "size_aware", "component": "queue_wait",
           "seconds": 1.5, "share": 0.4, "mean_ms": 0.7}]
    out = bench_payload("serving", [], smoke=True, breakdown=wf)
    assert out["breakdown"] == wf  # extra per-row keys survive untouched
    assert "breakdown" not in bench_payload("serving", [], smoke=True)
    with pytest.raises(ValueError, match=r"breakdown row 0 is missing.*share"):
        bench_payload("serving", [], smoke=True,
                      breakdown=[{"label": "x", "component": "queue_wait",
                                  "seconds": 1.0}])
    with pytest.raises(TypeError, match="breakdown row 0 is not a dict"):
        bench_payload("serving", [], smoke=True, breakdown=[("bad",)])


def test_common_imports_without_jax():
    """The schema helper must stay importable from jax-free processes
    (bench_engine's per-cell RSS workers). Guard the lazy-import
    contract: importing benchmarks.common never imports jax."""
    import importlib
    import subprocess

    importlib.import_module("benchmarks.common")
    code = ("import sys; sys.path.insert(0, {root!r}); "
            "import benchmarks.common; "
            "sys.exit(1 if 'jax' in sys.modules else 0)").format(
        root=str(Path(__file__).resolve().parents[1]))
    proc = subprocess.run([sys.executable, "-c", code])
    assert proc.returncode == 0, "importing benchmarks.common pulled in jax"
