"""Sharding rule resolution + trip-count-aware HLO statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import LM_RULES, adapt_rules, pspec
from repro.launch.hlo_stats import analyze


def test_pspec_dedup_axes():
    rules = {"batch": ("pod", "data"), "seq": "model", "kv": ("data", "model")}
    # duplicate mesh axes must appear at most once per spec
    s = pspec(("batch", "kv"), rules)
    assert s == P(("pod", "data"), "model")
    s2 = pspec(("seq", "kv"), rules)
    assert s2 == P("model", ("data",))


def test_pspec_trailing_none_trimmed():
    rules = {"batch": "data"}
    assert pspec(("batch", None, None), rules) == P("data")


def test_adapt_rules_drops_missing_axes(test_mesh):
    adapted = adapt_rules(LM_RULES, test_mesh)
    assert adapted["batch"] == ("data",)  # 'pod' dropped
    assert adapted["fsdp"] == ("data", "model")
    assert adapted["__mesh__"] is test_mesh


def test_unknown_logical_axis_raises():
    with pytest.raises(KeyError):
        pspec(("nope",), {"batch": "data"})


# ---------------------------------------------------------------------------
# HLO stats: the loop-body undercounting fix
# ---------------------------------------------------------------------------


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    st = analyze(_compile(f, x, w).as_text())
    expected = 2 * 64 * 128 * 128 * 8
    assert st["flops"] == pytest.approx(expected, rel=0.01)
    # raw cost_analysis would report expected/8 — we must beat that
    assert st["flops"] > 4 * (expected / 8)


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), ()
            return jax.lax.scan(inner, c, None, length=4)[0], ()
        return jax.lax.scan(outer, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    st = analyze(_compile(f, x, w).as_text())
    assert st["flops"] == pytest.approx(2 * 64 * 128 * 128 * 8 * 4, rel=0.01)


def test_grad_through_scan_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        return jax.lax.scan(body, x, w)[0]

    def train(x, w):
        return jax.grad(lambda w_: jnp.sum(f(x, w_)))(w)

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    st = analyze(_compile(train, x, w).as_text())
    fwd = 2 * 64 * 128 * 128 * 8
    assert st["flops"] == pytest.approx(3 * fwd, rel=0.05)  # fwd + 2x bwd


def test_bytes_and_top_computations_present():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    st = analyze(_compile(f, x, w).as_text())
    assert st["bytes_hbm"] > 8 * (64 * 128 + 128 * 128) * 4 * 0.5
    assert st["top_computations"][0][1] == st["flops"]
