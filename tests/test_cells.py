"""Cell-builder regression tests: all 40 assigned cells BUILD (abstract
shapes + sharding specs; no compilation) on the 1-device test mesh, and the
ParamDef machinery keeps abstract/real/spec trees consistent."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.launch.cells import all_cells, build_cell
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh()


def test_exactly_40_cells():
    cells = all_cells()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10


@pytest.mark.parametrize("arch,shape", all_cells())
def test_cell_builds(arch, shape, mesh):
    cell = build_cell(arch, shape, mesh)
    # args are abstract (no device allocation happened)
    leaves = jax.tree.leaves(cell.args)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # sharding trees align with args trees
    flat_args = jax.tree.structure(cell.args)
    assert cell.name == f"{get_config(arch).name}:{shape}"


def test_variant_cells_build(mesh):
    for overrides in (
        {"quantized": True, "serve_full_mesh": True},
        {"pad_vocab": True},
        {"flash_remat": True, "capacity_factor": 1.0},
        {"full_mesh_graph": True, "hoist_gathers": True},
    ):
        arch = {
            "quantized": "autoint", "pad_vocab": "llama4_maverick_400b_a17b",
            "flash_remat": "llama4_maverick_400b_a17b", "full_mesh_graph": "nequip",
        }[next(iter(overrides))]
        shape = {"autoint": "serve_bulk", "llama4_maverick_400b_a17b": "train_4k",
                 "nequip": "ogb_products"}[arch]
        cell = build_cell(arch, shape, mesh, overrides=overrides)
        assert cell.args


def test_param_def_three_views_consistent(mesh):
    """abstract / initialized / pspec trees share one structure."""
    from repro.distributed.sharding import FAMILY_RULES, adapt_rules
    from repro.models import transformer as tf
    from repro.models.common import abstract_params, init_params, param_pspecs

    from conftest import reduced_lm

    cfg = reduced_lm("yi_6b")
    defs = tf.param_defs(cfg)
    rules = adapt_rules(FAMILY_RULES["lm"], mesh)
    abstract = abstract_params(defs)
    real = init_params(defs, jax.random.key(0))
    specs = param_pspecs(defs, rules)
    assert jax.tree.structure(abstract) == jax.tree.structure(real)
    from jax.sharding import PartitionSpec as P

    assert jax.tree.structure(abstract) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    for a, r in zip(jax.tree.leaves(abstract), jax.tree.leaves(real)):
        assert a.shape == r.shape and a.dtype == r.dtype
