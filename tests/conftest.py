import os

# Tests run on the real 1-device CPU platform — the 512-device dry-run env
# is confined to launch/dryrun.py (never imported here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses

import jax
import pytest


@pytest.fixture(scope="session")
def test_mesh():
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh()


@pytest.fixture(scope="session")
def lm_rules(test_mesh):
    from repro.distributed.sharding import LM_RULES, adapt_rules

    return adapt_rules(LM_RULES, test_mesh)


@pytest.fixture(scope="session")
def rec_rules(test_mesh):
    from repro.distributed.sharding import RECSYS_RULES, adapt_rules

    return adapt_rules(RECSYS_RULES, test_mesh)


@pytest.fixture(scope="session")
def gnn_rules(test_mesh):
    from repro.distributed.sharding import GNN_RULES, adapt_rules

    return adapt_rules(GNN_RULES, test_mesh)


def reduced_lm(name: str, **over):
    """Tiny config of the same family as an assigned LM arch."""
    from repro.configs.base import get_config

    cfg = get_config(name)
    return dataclasses.replace(
        cfg, n_layers=2 if cfg.n_experts == 0 or cfg.moe_interleave == 1 else 2,
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), d_ff=96,
        vocab_size=256, head_dim=16,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        **over,
    )


def reduced_recsys(name: str):
    from repro.configs.base import get_config

    cfg = get_config(name)
    fields = tuple(
        dataclasses.replace(f, vocab=min(f.vocab, 1000)) for f in cfg.fields
    )
    return dataclasses.replace(cfg, fields=fields)
