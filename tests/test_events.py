"""Event-kernel fast-path tests: calendar-queue vs binary-heap ordering
(bit-exact, fuzzed), lazy arrival-stream merge semantics, collision-heavy
replay determinism at scale, dropped-event accounting / strict mode,
vectorized SLOMonitor equivalence, TraceBuffer round-trips, and the
default_horizon unsorted-arrivals regression."""
import heapq
import random

import pytest

from repro.core.serving.engine import (
    PoolSpec, ServingSystem, default_horizon, poisson_arrivals,
)
from repro.core.serving.events import (
    CalendarScheduler, EventLoop, HeapScheduler, SCHEDULERS,
)
from repro.core.serving.metrics import SLOMonitor, TraceBuffer
from repro.core.serving.pool import PoolConfig, Request
from repro.core.serving.replica import LatencyModel, ReplicaSpec


def _spec(name="m", base=0.02, per=0.001):
    return ReplicaSpec(name, LatencyModel.analytic(base, per),
                       cold_start_s=5.0, warm_start_s=0.2)


# ---------------------------------------------------------------------------
# schedulers: the calendar queue reproduces heap order exactly
# ---------------------------------------------------------------------------


def test_calendar_matches_heap_fuzzed():
    """Interleaved pushes/pops with timestamp collisions, out-of-band and
    past pushes, across widths spanning 6 orders of magnitude: the
    calendar queue's pop sequence must equal the binary heap's exactly."""
    rng = random.Random(0)
    for trial in range(40):
        width = rng.choice([1e-4, 1e-2, 0.05, 1.0, 100.0])
        cal = CalendarScheduler(width=width)
        ref = []
        seq = 0
        t_base = 0.0
        popped = []
        expect = []
        for _ in range(600):
            if ref and rng.random() < 0.4:
                expect.append(heapq.heappop(ref))
                popped.append(cal.pop())
            else:
                t_base += rng.choice([0.0, 0.0, 1e-4, 0.3, 7.0])
                # sometimes schedule before already-buffered times
                t = max(0.0, t_base - rng.choice([0.0, 0.0, 0.5, 5.0]))
                entry = (t, seq, "k", seq)
                seq += 1
                heapq.heappush(ref, entry)
                cal.push(entry)
        while ref:
            expect.append(heapq.heappop(ref))
            popped.append(cal.pop())
        assert popped == expect, f"trial {trial} (width {width})"
        assert len(cal) == 0


def test_calendar_width_shrink_keeps_order():
    """> MAX_BUCKET events landing in one bucket trigger the
    deterministic width shrink; order must survive the rebucketing."""
    cal = CalendarScheduler(width=1000.0)
    ref = []
    for i in range(3 * CalendarScheduler.MAX_BUCKET):
        t = 1000.0 + (i % 997) * 1e-3  # heavy collisions inside one bucket
        entry = (t, i, "k", i)
        cal.push(entry)
        heapq.heappush(ref, entry)
    out = [cal.pop() for _ in range(len(ref))]
    assert out == [heapq.heappop(ref) for _ in range(len(ref))]


def test_scheduler_pop_order_property():
    """Hypothesis fuzz of the ordering contract: arbitrary push/pop
    interleavings — duplicate timestamps, zero and NEGATIVE time gaps
    (pushes scheduled before already-buffered times), pops mid-stream —
    against the heapq reference, across widths. MAX_BUCKET is dropped to
    8 so width-shrink bursts (promote -> rebucket) fire constantly
    instead of needing 4096-event pile-ups. The @given is applied inside
    the test so the module's other tests run without hypothesis (the
    optional [test] extra)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    class TinyCalendar(CalendarScheduler):
        MAX_BUCKET = 8  # shrink on a handful of clustered events

    op_st = st.one_of(
        st.tuples(st.just("push"),
                  st.sampled_from([0.0, 0.0, 1e-6, 1e-3, 0.04, 1.0, 30.0]),
                  st.sampled_from([0.0, 0.0, 0.0, 0.5, 10.0])),
        st.just("pop"),
    )

    @given(ops=st.lists(op_st, max_size=250),
           width=st.sampled_from([1e-3, 0.05, 2.0, 500.0]))
    @settings(max_examples=80, deadline=None, derandomize=True,
              print_blob=True)
    def check(ops, width):
        cal = TinyCalendar(width=width)
        ref = []
        t_base, seq = 0.0, 0
        for op in ops:
            if op == "pop":
                if ref:
                    assert cal.pop() == heapq.heappop(ref)
            else:
                _, advance, back_jump = op
                t_base += advance
                entry = (max(0.0, t_base - back_jump), seq, "k", seq)
                seq += 1
                cal.push(entry)
                heapq.heappush(ref, entry)
        while ref:
            assert cal.pop() == heapq.heappop(ref)
        assert len(cal) == 0

    check()


def test_stream_merge_property():
    """Hypothesis fuzz of the lazy stream merge: several interleaved
    add_stream iterators (duplicate times within AND across streams)
    plus handler-scheduled queue events at ZERO gap from the current
    event — the fast path must replay the seed kernel's push-everything-
    upfront order exactly. Inner @given: see above."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    gaps_st = st.lists(st.sampled_from([0.0, 0.0, 0.01, 0.05, 0.4]),
                       max_size=40)

    @given(streams=st.lists(gaps_st, min_size=1, max_size=3),
           echo_mod=st.integers(2, 7))
    @settings(max_examples=60, deadline=None, derandomize=True,
              print_blob=True)
    def check(streams, echo_mod):
        times = []
        for gaps in streams:
            ts, t = [], 0.0
            for g in gaps:
                t += g
                ts.append(t)
            times.append(ts)

        def drive(loop, use_streams):
            seen = []
            for k in range(len(times)):
                def on_ev(t, p, k=k):
                    seen.append((f"s{k}", t, p))
                    if p % echo_mod == 0:
                        loop.push(t, "echo", p)  # zero-gap follow-up
                loop.on(f"s{k}", on_ev)
            loop.on("echo", lambda t, p: seen.append(("echo", t, p)))
            for k, ts in enumerate(times):
                if use_streams:
                    loop.add_stream(f"s{k}", zip(ts, range(len(ts))))
                else:
                    for i, tt in enumerate(ts):
                        loop.push(tt, f"s{k}", i)
            loop.run()
            return seen

        ref = drive(EventLoop(scheduler="heap"), use_streams=False)
        fast = drive(EventLoop(), use_streams=True)
        assert ref == fast

    check()


def test_scheduler_registry_and_unknown_name():
    assert set(SCHEDULERS) == {"heap", "calendar"}
    assert isinstance(EventLoop(scheduler="heap")._sched, HeapScheduler)
    assert isinstance(EventLoop()._sched, CalendarScheduler)
    with pytest.raises(ValueError):
        EventLoop(scheduler="wheel")


# ---------------------------------------------------------------------------
# arrival streams
# ---------------------------------------------------------------------------


def test_stream_beats_queue_at_equal_timestamps():
    """Seed semantics: arrivals were pushed before periodic events, so at
    equal timestamps they fired first. The stream must reproduce that."""
    loop = EventLoop()
    seen = []
    loop.on("arr", lambda t, p: seen.append(("arr", t, p)))
    loop.on("q", lambda t, p: seen.append(("q", t, p)))
    loop.push(1.0, "q", "x")
    loop.add_stream("arr", [(0.5, 0), (1.0, 1), (2.0, 2)])
    loop.run()
    assert seen == [("arr", 0.5, 0), ("arr", 1.0, 1), ("q", 1.0, "x"),
                    ("arr", 2.0, 2)]
    assert loop.processed == 4


def test_multi_stream_merge_matches_seed_push_order():
    """Several streams + handler-scheduled queue events, fuzzed: the
    merged order must equal the seed kernel's (every stream pushed
    upfront in add order, then the queue pushes)."""
    rng = random.Random(7)
    for trial in range(25):
        streams = []
        for _ in range(rng.randint(1, 3)):
            ts, t = [], 0.0
            for _ in range(rng.randint(0, 80)):
                t += rng.choice([0.0, 0.01, 0.1])
                ts.append(round(t, 3))
            streams.append(ts)

        def build(loop):
            seen = []
            for k in range(len(streams)):
                loop.on(f"s{k}",
                        lambda t, p, k=k: (
                            seen.append((f"s{k}", t, p)),
                            loop.push(t + 0.005, "echo", p) if p % 5 == 0
                            else None))
            loop.on("echo", lambda t, p: seen.append(("echo", t, p)))
            return seen

        ref_loop = EventLoop(scheduler="heap")
        ref = build(ref_loop)
        for k, ts in enumerate(streams):
            for i, tt in enumerate(ts):
                ref_loop.push(tt, f"s{k}", i)
        ref_loop.run()

        fast_loop = EventLoop()
        fast = build(fast_loop)
        for k, ts in enumerate(streams):
            fast_loop.add_stream(f"s{k}", zip(ts, range(len(ts))))
        fast_loop.run()
        assert ref == fast, f"trial {trial}"


def test_stream_rejects_backwards_time():
    loop = EventLoop()
    loop.on("a", lambda t, p: None)
    loop.add_stream("a", [(1.0, 0), (0.5, 1)])
    with pytest.raises(ValueError, match="not time-sorted"):
        loop.run()


def test_empty_stream_is_noop():
    loop = EventLoop()
    loop.on("a", lambda t, p: None)
    loop.add_stream("a", [])
    assert loop.run() == 0.0
    assert loop.processed == 0


# ---------------------------------------------------------------------------
# collision-heavy replay determinism at scale (the tentpole's contract)
# ---------------------------------------------------------------------------


def test_10k_collision_replay_bit_identical():
    """10k events over ~50 distinct timestamps (heavy collisions), with
    handlers scheduling follow-ups AT the current time (worst case for
    FIFO ties): the seed path (heap scheduler, arrivals pushed upfront)
    and the fast path (calendar + stream) must produce bit-identical
    event sequences — payload identity, times, and order."""
    rng = random.Random(42)
    times = sorted(rng.choice(range(50)) * 0.1 for _ in range(10_000))

    def drive(loop, use_stream):
        seen = []

        def on_arrive(t, p):
            seen.append(("arrive", t, p))
            if p % 3 == 0:
                loop.push(t, "follow", p)  # same-timestamp follow-up
            if p % 17 == 0:
                loop.push(t + 0.25, "late", p)

        loop.on("arrive", on_arrive)
        loop.on("follow", lambda t, p: seen.append(("follow", t, p)))
        loop.on("late", lambda t, p: seen.append(("late", t, p)))
        if use_stream:
            loop.add_stream("arrive", zip(times, range(len(times))))
        else:
            for i, t in enumerate(times):
                loop.push(t, "arrive", i)
        loop.run()
        return seen

    seed_path = drive(EventLoop(scheduler="heap"), use_stream=False)
    fast_path = drive(EventLoop(), use_stream=True)
    assert len(seed_path) == len(fast_path) > 10_000
    assert seed_path == fast_path


def test_full_system_replay_heap_vs_calendar():
    """A real ServingSystem run end to end on both schedulers, arrivals
    via the seed's upfront pushes vs the shipped stream path: identical
    summaries (percentiles, counts, traces) — the replay contract the
    rest of the repo's determinism tests rely on."""
    arrivals = poisson_arrivals(lambda t: 300.0, 8.0, seed=3)

    def system():
        return ServingSystem(
            {"m": PoolSpec(_spec(), PoolConfig(n_replicas=2, max_batch=16))},
            slo_p99_s=0.15,
        )

    fast = system().run(arrivals, until=8.0)

    legacy = ServingSystem(
        {"m": PoolSpec(_spec(), PoolConfig(n_replicas=2, max_batch=16))},
        slo_p99_s=0.15, scheduler="heap",
    )
    for r in sorted(arrivals, key=lambda r: r.t_arrive):
        legacy.loop.push(r.t_arrive, "arrive", r)
    legacy.start(8.0)
    legacy.loop.run()
    res = legacy.summary()

    assert res["p50"] == fast["p50"] and res["p99"] == fast["p99"]
    assert res["completed"] == fast["completed"]
    assert res["rejected"] == fast["rejected"]
    assert res["trace"] == fast["trace"]
    assert res["pools"]["m"]["trace"] == fast["pools"]["m"]["trace"]


# ---------------------------------------------------------------------------
# dropped events / strict mode
# ---------------------------------------------------------------------------


def test_dropped_events_counted_not_silent():
    loop = EventLoop()
    loop.on("known", lambda t, p: None)
    loop.push(1.0, "known")
    loop.push(2.0, "ghost")
    loop.push(3.0, "ghost")
    loop.push(4.0, "phantom")
    loop.run()
    assert loop.processed == 4
    assert loop.dropped_events == 3
    assert loop.dropped_kinds == {"ghost": 2, "phantom": 1}


def test_strict_loop_raises_on_unhandled_kind():
    loop = EventLoop(strict=True)
    loop.on("known", lambda t, p: None)
    loop.push(1.0, "ghost")
    with pytest.raises(KeyError, match="ghost"):
        loop.run()


def test_dropped_events_in_system_summary():
    sys_ = ServingSystem(
        {"m": PoolSpec(_spec(), PoolConfig(n_replicas=1))}, slo_p99_s=0.15)
    sys_.loop.push(0.5, "not_a_real_event")
    res = sys_.run(poisson_arrivals(lambda t: 50.0, 2.0, seed=0), until=2.0)
    assert res["dropped_events"] == 1
    clean = ServingSystem(
        {"m": PoolSpec(_spec(), PoolConfig(n_replicas=1))}, slo_p99_s=0.15)
    assert clean.run(poisson_arrivals(lambda t: 50.0, 2.0, seed=0),
                     until=2.0)["dropped_events"] == 0


def test_strict_events_plumbed_through_system():
    sys_ = ServingSystem(
        {"m": PoolSpec(_spec(), PoolConfig(n_replicas=1))},
        slo_p99_s=0.15, strict_events=True)
    sys_.loop.push(0.5, "not_a_real_event")
    with pytest.raises(KeyError, match="not_a_real_event"):
        sys_.run(poisson_arrivals(lambda t: 50.0, 2.0, seed=0), until=2.0)


# ---------------------------------------------------------------------------
# default_horizon regression (satellite: unsorted arrivals)
# ---------------------------------------------------------------------------


def test_default_horizon_uses_true_max_not_last():
    unsorted = [Request(0, 9.0, "tier0"), Request(1, 3.0, "tier0"),
                Request(2, 6.0, "tier0")]
    assert default_horizon(unsorted) == 9.0 + 5.0  # was 6.0 + 5.0 pre-fix
    assert default_horizon([]) == 5.0


def test_run_with_unsorted_arrivals_matches_sorted():
    arrivals = poisson_arrivals(lambda t: 200.0, 6.0, seed=5)
    shuffled = list(arrivals)
    random.Random(1).shuffle(shuffled)

    def system():
        return ServingSystem(
            {"m": PoolSpec(_spec(), PoolConfig(n_replicas=2))}, slo_p99_s=0.15)

    a = system().run(arrivals, until=6.0)
    b = system().run(shuffled, until=6.0)
    assert (a["p50"], a["p99"], a["completed"]) == \
        (b["p50"], b["p99"], b["completed"])


# ---------------------------------------------------------------------------
# vectorized SLOMonitor / TraceBuffer
# ---------------------------------------------------------------------------


def test_slomonitor_matches_deque_reference():
    """The numpy SLOMonitor against a straightforward deque+list replay
    of the seed implementation, under interleaved record/percentile
    calls with a moving window."""
    from collections import deque

    import numpy as np

    mon = SLOMonitor(window_s=2.0, slo_s=0.5)
    ref_lat = deque()
    ref_hist = []
    rng = random.Random(9)
    now = 0.0
    for _ in range(2000):
        now += rng.random() * 0.05
        lat = rng.random()
        mon.record(now, lat)
        ref_lat.append((now, lat))
        ref_hist.append(lat)
        if rng.random() < 0.3:
            while ref_lat and ref_lat[0][0] < now - 2.0:
                ref_lat.popleft()
            got = mon.percentiles(now)
            if ref_lat:
                arr = np.array([l for _, l in ref_lat])
                elapsed = max(min(now, 2.0), 1e-9)
                assert got["p50"] == float(np.percentile(arr, 50))
                assert got["p99"] == float(np.percentile(arr, 99))
                assert got["qps"] == len(arr) / elapsed
            else:
                assert got == {"p50": 0.0, "p99": 0.0, "qps": 0.0}
    tot = mon.totals()
    arr = np.asarray(ref_hist)
    assert tot["p50"] == float(np.percentile(arr, 50))
    assert tot["p99"] == float(np.percentile(arr, 99))
    assert tot["mean"] == float(arr.mean())
    assert tot["completed"] == len(ref_hist)
    assert mon.attainment() == sum(1 for l in ref_hist if l <= 0.5) / len(ref_hist)


def test_slomonitor_empty_window_after_idle_gap():
    mon = SLOMonitor(window_s=1.0)
    mon.record(0.5, 0.1)
    assert mon.percentiles(0.6)["qps"] > 0
    # a long idle gap empties the window but not the totals
    assert mon.percentiles(100.0) == {"p50": 0.0, "p99": 0.0, "qps": 0.0}
    assert mon.totals()["completed"] == 1


def test_tracebuffer_roundtrip_types_and_growth():
    import numpy as np

    buf = TraceBuffer(["t", ("n", np.int64)])
    for i in range(100):  # past the initial capacity: growth path
        buf.append(i * 0.5, i)
    out = buf.as_dict()
    assert out["t"] == [i * 0.5 for i in range(100)]
    assert out["n"] == list(range(100))
    assert isinstance(out["n"][0], int) and isinstance(out["t"][0], float)
    assert len(buf) == 100
    assert buf.column("n").max() == 99
    with pytest.raises(ValueError):
        buf.append(1.0)  # arity is checked


def test_tracebuffer_column_survives_growth():
    """column() returns a snapshot COPY, not a live view. The old
    contract handed out a numpy view that silently detached at the next
    amortised-doubling growth — a caller holding it across appends kept
    reading the pre-growth buffer with no error. Pin the fix exactly at
    the growth boundary (initial capacity is 16)."""
    import numpy as np

    buf = TraceBuffer([("n", np.int64)])
    for i in range(16):  # fill to exactly the initial capacity
        buf.append(i)
    held = buf.column("n")
    assert held.tolist() == list(range(16))
    buf.append(16)  # triggers the doubling reallocation
    # the held snapshot is immutable history, not a window into the
    # abandoned old buffer...
    assert held.tolist() == list(range(16))
    # ...and is genuinely detached: writing through it cannot corrupt
    # the buffer, and fresh reads see all rows
    held[0] = 999
    assert buf.column("n").tolist() == list(range(17))
    assert buf.as_dict()["n"] == list(range(17))
    # a snapshot taken after growth reflects post-growth contents
    assert buf.column("n")[16] == 16
