"""The CI guard scripts guarded: tools/check_docs.py (markdown link +
executable-fence validation) and tools/check_trace.py (Chrome-trace
structural validation) get their rejection paths pinned down — a
malformed/broken python fence, unbalanced sync and async span pairs,
unsorted timestamps, unnamed tracks, and unreadable documents — plus
the happy paths CI relies on staying green.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from tools import check_docs, check_trace  # noqa: E402


# ---- check_docs: links ----

def test_check_links_accepts_resolving_and_external(tmp_path):
    (tmp_path / "exists.md").write_text("target")
    doc = tmp_path / "doc.md"
    text = ("[ok](exists.md) [web](https://example.com/x) "
            "[anchor](#section) [mail](mailto:a@b.c)")
    assert check_docs.check_links(doc, text) == []


def test_check_links_rejects_missing_target(tmp_path):
    doc = tmp_path / "doc.md"
    errors = check_docs.check_links(doc, "[broken](missing.md)")
    assert len(errors) == 1
    assert "missing.md" in errors[0]


def test_check_links_repo_absolute_paths_resolve_from_root():
    # "/docs/architecture.md" means repo-root-relative on GitHub
    doc = check_docs.ROOT / "README.md"
    assert check_docs.check_links(doc, "[a](/docs/architecture.md)") == []
    errors = check_docs.check_links(doc, "[a](/docs/nope.md)")
    assert len(errors) == 1


# ---- check_docs: executable fences ----

def test_run_blocks_share_one_namespace(tmp_path):
    doc = tmp_path / "doc.md"
    text = ("```python\nx = 21\n```\n"
            "prose between blocks\n"
            "```python\nassert x * 2 == 42\n```\n")
    assert check_docs.run_blocks(doc, text) == []


def test_run_blocks_reports_failing_fence(tmp_path):
    doc = tmp_path / "doc.md"
    text = "```python\nraise RuntimeError('doc drifted')\n```\n"
    errors = check_docs.run_blocks(doc, text)
    assert len(errors) == 1
    assert "python block 0 failed" in errors[0]
    assert "doc drifted" in errors[0]


def test_run_blocks_rejects_malformed_fence_code(tmp_path):
    # an unterminated string inside the fence must fail the doc check,
    # not crash the checker
    doc = tmp_path / "doc.md"
    text = "```python\nvalue = 'unterminated\n```\n"
    errors = check_docs.run_blocks(doc, text)
    assert len(errors) == 1
    assert "SyntaxError" in errors[0]


def test_unclosed_fence_is_not_executed(tmp_path):
    # FENCE requires a closing ``` — a dangling open fence yields no
    # blocks instead of executing the rest of the document as code
    text = "```python\nraise RuntimeError('never runs')\n"
    assert check_docs.FENCE.findall(text) == []
    assert check_docs.run_blocks(tmp_path / "doc.md", text) == []


# ---- check_trace ----

def _meta(pid=1, tid=1):
    return [
        {"ph": "M", "name": "process_name", "pid": pid,
         "args": {"name": "proc"}},
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
         "args": {"name": "thread"}},
    ]


def _span(ph, ts, name="span", pid=1, tid=1, **extra):
    return {"ph": ph, "ts": ts, "name": name, "pid": pid, "tid": tid,
            **extra}


def _doc(events):
    return {"traceEvents": events}


def test_valid_trace_passes():
    events = _meta() + [
        _span("B", 0), _span("E", 10),
        _span("b", 10, name="req", cat="request", id="r1"),
        _span("e", 20, name="req", cat="request", id="r1"),
        _span("X", 30, dur=5),
    ]
    assert check_trace.check_trace(_doc(events)) == []


def test_missing_trace_events_array():
    assert check_trace.check_trace({"other": 1}) == \
        ["document has no traceEvents array"]


def test_unsorted_timestamps_rejected():
    events = _meta() + [_span("B", 10), _span("E", 5)]
    errors = check_trace.check_trace(_doc(events))
    assert any("not sorted" in e for e in errors)


def test_unbalanced_sync_spans_rejected():
    dangling = _meta() + [_span("B", 0)]
    errors = check_trace.check_trace(_doc(dangling))
    assert any("unclosed B span" in e for e in errors)

    orphan_close = _meta() + [_span("E", 0)]
    errors = check_trace.check_trace(_doc(orphan_close))
    assert any("E with empty stack" in e for e in errors)

    mismatched = _meta() + [_span("B", 0, name="outer"),
                            _span("E", 1, name="inner")]
    errors = check_trace.check_trace(_doc(mismatched))
    assert any("mismatched nesting" in e for e in errors)


def test_unbalanced_async_pairs_rejected():
    never_closed = _meta() + [
        _span("b", 0, name="req", cat="request", id="r1")]
    errors = check_trace.check_trace(_doc(never_closed))
    assert any("unbalanced async span" in e for e in errors)

    e_before_b = _meta() + [
        _span("e", 0, name="req", cat="request", id="r1")]
    errors = check_trace.check_trace(_doc(e_before_b))
    assert any("async e before its b" in e for e in errors)

    missing_id = _meta() + [_span("b", 0, name="req", cat="request")]
    errors = check_trace.check_trace(_doc(missing_id))
    assert any("missing cat/id/name" in e for e in errors)


def test_unnamed_pid_tid_rejected():
    events = _meta(pid=1, tid=1) + [
        _span("X", 0, pid=2, tid=9, dur=1)]
    errors = check_trace.check_trace(_doc(events))
    assert any("no process_name" in e for e in errors)
    assert any("no thread_name" in e for e in errors)


def test_metadata_only_trace_rejected():
    errors = check_trace.check_trace(_doc(_meta()))
    assert any("zero spans" in e for e in errors)


def test_unknown_phase_rejected():
    events = _meta() + [_span("Z", 0)]
    errors = check_trace.check_trace(_doc(events))
    assert any("unknown phase" in e for e in errors)


# ---- check_trace CLI ----

def test_main_ok_and_failing_paths(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_doc(_meta() + [_span("X", 0, dur=1)])))
    assert check_trace.main([str(good)]) == 0

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_doc(_meta() + [_span("B", 0)])))
    assert check_trace.main([str(bad)]) == 1

    unreadable = tmp_path / "nope.json"
    assert check_trace.main([str(unreadable)]) == 1

    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    assert check_trace.main([str(garbage)]) == 1
    capsys.readouterr()  # keep the pytest output clean


def test_main_usage_error():
    assert check_trace.main([]) == 2
