"""Property-based serving invariants (hypothesis): whatever fleet the
strategies assemble — pool counts, platform-class mixes (generic /
cpu_like / accelerator_like), router policies, cell policies, admission
tiers, arrival size mixes, horizons — three contracts must hold after
every run:

    conservation   injected == completed + rejected + in_flight, with
                   in_flight == 0 once the loop drains (no admitted
                   request is ever lost, none is counted twice)
    accounting     every counter, queue length, budget and trace sample
                   is non-negative; queues and queued_cost end empty;
                   shared replica budgets are never exceeded
    timelines      per-request stamps are monotone:
                   t_arrive <= s*_enqueue <= s*_start <= s*_done

plus bit-exact determinism: the same fleet + seed replayed from scratch
produces the identical summary, and two observability contracts from the
tracing layer:

    attribution    every completed request's latency decomposes into
                   named components whose left-to-right sum equals the
                   end-to-end latency bit-exactly (closure term, not
                   estimate)
    transparency   attaching a sampling Tracer leaves the run's summary
                   bit-identical — observation never perturbs replay

The suite auto-skips when hypothesis is absent (optional [test] extra,
same pattern as test_gnn.py); settings are derandomized so CI failures
reproduce locally."""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, settings, strategies as st

from repro.core.serving.cache import CacheConfig
from repro.core.serving.engine import (
    PoolSpec, ServingSystem, attach_zipf_ids, poisson_arrivals,
)
from repro.core.serving.federation import (
    CELL_POLICIES, CellSpec, FederatedSystem, assign_homes,
)
from repro.core.serving.pool import PoolConfig
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.core.serving.router import ROUTERS, make_router
from repro.core.serving.tracing import COMPONENTS, Tracer, decompose
from repro.data.synthetic import bimodal_cost_mix

# one run per example keeps the whole suite inside a few seconds while
# still covering hundreds of distinct fleet shapes across the tests
COMMON = dict(deadline=None, derandomize=True, print_blob=True)


def _spec(platform: str, variant: str = "m") -> ReplicaSpec:
    if platform == "cpu":
        return ReplicaSpec.cpu_like(variant, cold_start_s=0.5)
    if platform == "accelerator":
        return ReplicaSpec.accelerator_like(variant, warm_start_s=0.1,
                                            cold_start_s=0.5)
    return ReplicaSpec(variant, LatencyModel.analytic(0.01, 5e-4),
                       cold_start_s=0.5, warm_start_s=0.05)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

pool_st = st.fixed_dictionaries({
    "platform": st.sampled_from(["generic", "cpu", "accelerator"]),
    "n_replicas": st.integers(1, 3),
    "autoscale": st.booleans(),
    "max_batch": st.sampled_from([1, 4, 16]),
    "max_batch_items": st.sampled_from([None, 64, 512]),
    "max_wait_s": st.sampled_from([0.001, 0.005, 0.02]),
    "cache_rows": st.sampled_from([0, 128]),
})

fleet_st = st.lists(pool_st, min_size=1, max_size=3)

traffic_st = st.fixed_dictionaries({
    "rate": st.sampled_from([40.0, 150.0, 400.0]),
    "horizon": st.sampled_from([0.6, 1.5, 3.0]),
    "seed": st.integers(0, 999),
    "priority_frac": st.sampled_from([0.0, 0.05, 0.3]),
    "rank_frac": st.sampled_from([0.0, 0.1, 0.5]),
    "rank_cost": st.sampled_from([32, 512]),
    "ids": st.booleans(),
})

system_st = st.fixed_dictionaries({
    "router": st.sampled_from(sorted(ROUTERS)),
    "tier_rate": st.sampled_from([None, 60.0, 1000.0]),
    "adaptive_shedding": st.booleans(),
})


def _build(fleet, sys_cfg, tracer=None):
    pools = {}
    for i, p in enumerate(fleet):
        pools[f"p{i}_{p['platform']}"] = PoolSpec(
            _spec(p["platform"], variant=f"v{i}"),
            PoolConfig.for_platform(
                p["platform"], n_replicas=p["n_replicas"],
                autoscale=p["autoscale"], max_batch=p["max_batch"],
                max_batch_items=p["max_batch_items"],
                max_wait_s=p["max_wait_s"]),
            cache=CacheConfig(p["cache_rows"]) if p["cache_rows"] else None,
        )
    tiers = None
    if sys_cfg["tier_rate"] is not None:
        tiers = {t: TierPolicy(sys_cfg["tier_rate"], sys_cfg["tier_rate"] / 5)
                 for t in ("tier0", "tier1")}
    return ServingSystem(
        pools, make_router(sys_cfg["router"]), tiers=tiers, slo_p99_s=0.1,
        adaptive_shedding=sys_cfg["adaptive_shedding"], tracer=tracer)


def _arrivals(traffic):
    mix = None
    if traffic["rank_frac"] > 0.0:
        mix = bimodal_cost_mix(rank_cost=traffic["rank_cost"],
                               rank_frac=traffic["rank_frac"])
    arr = poisson_arrivals(
        lambda t: traffic["rate"], traffic["horizon"], seed=traffic["seed"],
        priority_frac=traffic["priority_frac"], cost_mix=mix)
    if traffic["ids"]:
        attach_zipf_ids(arr, 2000, 4, alpha=1.1, seed=traffic["seed"])
    return arr


def _check_invariants(arrivals, res, pools):
    injected = len(arrivals)
    stamped = [r for r in arrivals if f"s{r.stage}_enqueue" in r.timeline]
    # conservation: every arrival is exactly one of completed/rejected,
    # and nothing is left queued or in flight once the loop drains
    assert res["arrived"] == injected
    assert res["rejected"] == injected - len(stamped)
    assert res["completed"] == len(stamped)
    assert res["completed"] + res["rejected"] == injected
    assert res["in_queue"] == 0
    assert 0 <= res["completed_in_horizon"] <= res["completed"]
    # non-negative accounting, empty end-state queues, sane percentiles
    assert res["rejected"] >= 0 and res["throughput"] >= 0.0
    assert 0.0 <= res["p50"] <= res["p99"]
    assert res["mean_latency"] >= 0.0
    for pool in pools.values():
        assert not pool.queue and pool.queued_cost == 0
        assert pool.shed >= 0
        assert len(pool.replicas) >= 1
    trace = res["trace"]
    assert all(q >= 0 for q in trace["queue"])
    assert all(n >= 1 for n in trace["replicas"])
    # per-request timeline monotonicity (every admitted request carries
    # the full enqueue -> start -> done chain of its final stage)
    for r in stamped:
        tl = r.timeline
        pre = f"s{r.stage}_"
        assert r.t_arrive <= tl[pre + "enqueue"]
        assert tl[pre + "enqueue"] <= tl[pre + "start"] <= tl[pre + "done"]


@given(fleet=fleet_st, sys_cfg=system_st, traffic=traffic_st)
@settings(max_examples=40, **COMMON)
def test_system_invariants_hold_for_any_fleet(fleet, sys_cfg, traffic):
    arrivals = _arrivals(traffic)
    sys_ = _build(fleet, sys_cfg)
    res = sys_.run(arrivals, until=traffic["horizon"])
    _check_invariants(arrivals, res, sys_.pools)


@given(fleet=fleet_st, sys_cfg=system_st, traffic=traffic_st)
@settings(max_examples=10, **COMMON)
def test_replay_is_bit_exact_for_any_fleet(fleet, sys_cfg, traffic):
    """The determinism contract, fuzzed: rebuilding the same fleet and
    replaying the same seed gives the identical summary — percentiles,
    counters and traces — including heterogeneous platform mixes."""
    def once():
        arr = _arrivals(traffic)
        return _build(fleet, sys_cfg).run(arr, until=traffic["horizon"])

    a, b = once(), once()
    assert (a["p50"], a["p99"], a["mean_latency"]) == \
        (b["p50"], b["p99"], b["mean_latency"])
    assert a["completed"] == b["completed"]
    assert a["rejected"] == b["rejected"]
    assert a["trace"] == b["trace"]
    assert {n: p["completed"] for n, p in a["pools"].items()} == \
        {n: p["completed"] for n, p in b["pools"].items()}


cell_st = st.fixed_dictionaries({
    "platforms": st.lists(
        st.sampled_from(["generic", "cpu", "accelerator"]),
        min_size=1, max_size=2),
    "n_replicas": st.integers(1, 2),
})

federation_st = st.fixed_dictionaries({
    "cells": st.lists(cell_st, min_size=2, max_size=3),
    "policy": st.sampled_from(sorted(CELL_POLICIES)),
    "spillover": st.booleans(),
    "hot_frac": st.sampled_from([0.5, 0.8]),
})


@given(fed_cfg=federation_st, traffic=traffic_st)
@settings(max_examples=25, **COMMON)
def test_federation_invariants_hold_for_any_cell_mix(fed_cfg, traffic):
    """The same contracts one layer up: heterogeneous CELL class mixes
    (each cell's pool set drawn independently, so fleets mix pure-CPU
    cells with accelerator and mixed cells), every cell policy, spill
    on/off. The federation's own summary documents the conservation
    identity — this pins it."""
    cells = {}
    for ci, c in enumerate(fed_cfg["cells"]):
        pools = {
            f"p{pi}_{plat}": PoolSpec(
                _spec(plat, variant=f"c{ci}v{pi}"),
                PoolConfig.for_platform(plat, n_replicas=c["n_replicas"],
                                        autoscale=False))
            for pi, plat in enumerate(c["platforms"])
        }
        cells[f"cell{ci}"] = CellSpec(pools=pools, slo_p99_s=0.1,
                                      adaptive_shedding=False)
    fed = FederatedSystem(cells, policy=fed_cfg["policy"],
                          spillover=fed_cfg["spillover"], rtt_s=0.002,
                          slo_p99_s=0.1)
    arrivals = _arrivals(traffic)
    rest = (1.0 - fed_cfg["hot_frac"]) / (len(cells) - 1)
    skew = {name: (fed_cfg["hot_frac"] if i == 0 else rest)
            for i, name in enumerate(cells)}
    assign_homes(arrivals, skew, seed=traffic["seed"])
    res = fed.run(arrivals, until=traffic["horizon"])

    injected = len(arrivals)
    assert res["injected"] == injected
    assert res["completed"] + res["rejected"] + res["in_flight"] == injected
    assert res["in_flight"] == 0 and res["in_transit"] == 0
    assert res["spilled"] >= 0 and res["spilled_in"] >= 0
    assert 0 <= res["completed_in_horizon"] <= res["completed"]
    assert 0.0 <= res["p50"] <= res["p99"]
    for cell in fed.cells.values():
        for pool in cell.system.pools.values():
            assert not pool.queue and pool.queued_cost == 0
    assert all(s >= 0 for s in res["trace"]["spilled"])
    assert all(n >= 0 for n in res["trace"]["in_transit"])
    for r in arrivals:
        pre = f"s{r.stage}_"
        if pre + "enqueue" not in r.timeline:
            continue
        tl = r.timeline
        # a spilled request re-stamps enqueue at the serving cell after
        # transit; the final chain must still be monotone from arrival
        assert r.t_arrive <= tl[pre + "enqueue"]
        assert tl[pre + "enqueue"] <= tl[pre + "start"] <= tl[pre + "done"]


@given(traffic=traffic_st, threshold=st.sampled_from([None, 8, 64]))
@settings(max_examples=15, **COMMON)
def test_size_aware_class_affinity_property(traffic, threshold):
    """SizeAwareRouter's structural guarantee on a two-class fleet: with
    an explicit threshold, NO request at or above it is ever served by a
    CPU-class pool and none below it by an accelerator-class pool
    (admission-time affinity is absolute, not a preference); class
    totals always add up to the fleet's completed count."""
    pools = {
        "cpu": PoolSpec(_spec("cpu"),
                        PoolConfig.for_platform("cpu", n_replicas=2,
                                                autoscale=False)),
        "acc": PoolSpec(_spec("accelerator"),
                        PoolConfig.for_platform("accelerator", n_replicas=2,
                                                autoscale=False)),
    }
    sys_ = ServingSystem(pools, make_router("size_aware",
                                            size_threshold=threshold),
                         slo_p99_s=0.1, adaptive_shedding=False)
    arrivals = _arrivals(traffic)
    res = sys_.run(arrivals, until=traffic["horizon"])
    by_pool = {n: p["completed"] for n, p in res["pools"].items()}
    assert sum(by_pool.values()) == res["completed"]
    assert res["rejected"] == 0  # unlimited tiers, shedding off
    if threshold is not None:
        n_large = sum(1 for r in arrivals if r.cost >= threshold)
        assert by_pool["acc"] == n_large
        assert by_pool["cpu"] == len(arrivals) - n_large


@given(fleet=fleet_st, sys_cfg=system_st, traffic=traffic_st)
@settings(max_examples=25, **COMMON)
def test_breakdown_sums_to_latency_bit_exact(fleet, sys_cfg, traffic):
    """The attribution invariant, fuzzed: for EVERY completed request in
    any fleet the per-request component decomposition, summed left to
    right in COMPONENTS order, reproduces the end-to-end latency with no
    float error at all (== on binary64, not approx). The summary's
    latency_breakdown must account for exactly the completed requests."""
    arrivals = _arrivals(traffic)
    sys_ = _build(fleet, sys_cfg)
    res = sys_.run(arrivals, until=traffic["horizon"])
    checked = 0
    for r in arrivals:
        done = r.timeline.get(f"s{r.stage}_done")
        if done is None:
            continue
        comps = decompose(r, done)
        assert set(comps) == set(COMPONENTS)
        acc = 0.0
        for name in COMPONENTS:
            assert comps[name] >= 0.0 or name in ("transit", "closure")
            acc += comps[name]
        assert acc == done - r.t_arrive  # bit-exact, no tolerance
        checked += 1
    assert checked == res["completed"]
    bd = res["latency_breakdown"]
    assert bd["count"] == res["completed"]
    assert set(bd["components"]) == set(COMPONENTS)
    assert all(v >= 0.0 for k, v in bd["components"].items()
               if k not in ("transit", "closure"))
    if bd["count"]:
        assert bd["end_to_end_s"] == pytest.approx(bd["component_sum_s"])


@given(fleet=fleet_st, sys_cfg=system_st, traffic=traffic_st,
       sample_every=st.sampled_from([1, 4, 32]))
@settings(max_examples=15, **COMMON)
def test_tracer_does_not_perturb_replay(fleet, sys_cfg, traffic,
                                        sample_every):
    """The transparency contract, fuzzed: the same fleet + seed run bare
    and run under a sampling Tracer produce byte-identical summaries —
    sampling density included, because tracer state must never leak into
    system accounting. (json round-trip flattens tuples so the compare
    is structural, not object-identity.)"""
    import json

    def once(tracer):
        arr = _arrivals(traffic)
        sys_ = _build(fleet, sys_cfg, tracer=tracer)
        return sys_.run(arr, until=traffic["horizon"])

    bare = once(None)
    traced = once(Tracer(sample_every=sample_every, seed=traffic["seed"]))
    assert json.dumps(bare, sort_keys=True, default=float) == \
        json.dumps(traced, sort_keys=True, default=float)


@given(fed_cfg=federation_st, traffic=traffic_st)
@settings(max_examples=8, **COMMON)
def test_federation_breakdown_and_tracer_transparency(fed_cfg, traffic):
    """Both observability contracts one layer up, across cell policies
    and spill on/off: the fleet latency_breakdown rollup accounts for
    every completed request, and a Tracer on the federation leaves the
    summary bit-identical."""
    import json

    def build():
        cells = {}
        for ci, c in enumerate(fed_cfg["cells"]):
            pools = {
                f"p{pi}_{plat}": PoolSpec(
                    _spec(plat, variant=f"c{ci}v{pi}"),
                    PoolConfig.for_platform(plat, n_replicas=c["n_replicas"],
                                            autoscale=False))
                for pi, plat in enumerate(c["platforms"])
            }
            cells[f"cell{ci}"] = CellSpec(pools=pools, slo_p99_s=0.1,
                                          adaptive_shedding=False)
        return cells

    def once(tracer):
        fed = FederatedSystem(build(), policy=fed_cfg["policy"],
                              spillover=fed_cfg["spillover"], rtt_s=0.002,
                              slo_p99_s=0.1, tracer=tracer)
        arrivals = _arrivals(traffic)
        rest = (1.0 - fed_cfg["hot_frac"]) / (len(fed.cells) - 1)
        skew = {name: (fed_cfg["hot_frac"] if i == 0 else rest)
                for i, name in enumerate(fed.cells)}
        assign_homes(arrivals, skew, seed=traffic["seed"])
        return fed.run(arrivals, until=traffic["horizon"])

    bare = once(None)
    assert bare["latency_breakdown"]["count"] == bare["completed"]
    traced = once(Tracer(sample_every=4, seed=traffic["seed"]))
    assert json.dumps(bare, sort_keys=True, default=float) == \
        json.dumps(traced, sort_keys=True, default=float)
