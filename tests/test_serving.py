"""Serving-stack tests (paper §IV.B behaviours) against the multi-pool API:
event kernel, replica pools, router policies, shared capacity budget,
cascade inference, rate limiting, autoscaling, the multi-cell federation
(cross-cell routing + spillover), the hot-ID caching layer
(eviction policies, miss-cost service times, result cache, conservation
with caching, per-cell-pair RTT matrix), and the adaptive control plane
(online-learned latency corrections, SLO-aware batch sizing, control-
loop regressions)."""
import dataclasses

import numpy as np
import pytest

from repro.core.serving.autoscaler import AutoScaler, CapacityBudget, ScalerConfig
from repro.core.serving.cache import (
    CACHE_POLICIES, CacheConfig, EmbeddingCache, ResultCache, make_cache_policy,
)
from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.control import (
    BatchSizeController, ControlConfig, Ewma, OnlineLatencyModel,
)
from repro.core.serving.engine import (
    ElasticEngine, EngineConfig, PoolSpec, Request, ServingSystem,
    attach_zipf_ids, poisson_arrivals,
)
from repro.core.serving.events import EventLoop
from repro.core.serving.federation import (
    CELL_POLICIES, CellSpec, FederatedSystem, RttMatrix, assign_homes,
    make_cell_policy,
)
from repro.core.serving.metrics import (
    SLOMonitor, federated_rollup, fleet_cache_rollup, fleet_control_rollup,
)
from repro.core.serving.pool import PoolConfig, ReplicaPool
from repro.core.serving.rate_limiter import HybridRateLimiter, TierPolicy
from repro.core.serving.replica import (
    LatencyModel, MissProfile, Replica, ReplicaSpec, sustainable_rate,
)
from repro.core.serving.router import CostModelRouter, ROUTERS, Router, make_router
from repro.data.synthetic import zipf_id_stream


def _spec(name="m", base=0.02, per=0.001):
    return ReplicaSpec(name, LatencyModel.analytic(base, per),
                       cold_start_s=5.0, warm_start_s=0.2)


SPIKE = lambda t: 100.0 if t < 15 else (900.0 if t < 45 else 150.0)


def _hetero_system(router, **kw):
    """Two variant pools live at once: a heavy baseline and a cheap distilled."""
    pools = {
        "baseline": PoolSpec(_spec("baseline", 0.02, 1e-3), PoolConfig(n_replicas=2)),
        "distilled": PoolSpec(_spec("distilled", 0.004, 5e-5), PoolConfig(n_replicas=2)),
    }
    return ServingSystem(pools, router, **kw)


# ---------------------------------------------------------------------------
# event kernel
# ---------------------------------------------------------------------------


def test_event_kernel_time_ordering():
    loop = EventLoop()
    seen = []
    loop.on("a", lambda t, p: seen.append((t, p)))
    loop.push(2.0, "a", "late")
    loop.push(1.0, "a", "early")
    loop.push(1.0, "a", "early2")  # FIFO within equal timestamps
    loop.run()
    assert seen == [(1.0, "early"), (1.0, "early2"), (2.0, "late")]
    assert loop.now == 2.0


def test_event_kernel_rejects_duplicate_handler():
    loop = EventLoop()
    loop.on("a", lambda t, p: None)
    with pytest.raises(ValueError):
        loop.on("a", lambda t, p: None)


# ---------------------------------------------------------------------------
# single pool (ElasticEngine compatibility surface)
# ---------------------------------------------------------------------------


def test_all_served_under_capacity():
    eng = ElasticEngine(_spec("m", 0.002, 1e-5), EngineConfig(n_replicas=2, autoscale=False))
    arr = poisson_arrivals(lambda t: 100.0, 10.0, seed=1)
    res = eng.run(arr, until=12.0)
    assert res["rejected"] == 0
    assert res["completed"] == len(arr)
    assert res["p99"] < 0.05


def test_autoscaler_rescues_overload():
    arr = poisson_arrivals(SPIKE, 70.0, seed=0)
    res = {}
    for auto in (False, True):
        eng = ElasticEngine(
            _spec(), EngineConfig(n_replicas=2, autoscale=auto, slo_p99_s=0.2, max_batch=32),
            tiers={"tier0": TierPolicy(1200, 100), "tier1": TierPolicy(1200, 100)},
        )
        res[auto] = eng.run(arr, until=70.0)
    assert res[True]["p50"] < 0.1 * res[False]["p50"]  # collapse vs elastic
    assert max(res[True]["trace"]["replicas"]) > 2  # actually scaled up
    assert res[True]["final_replicas"] <= 3  # and back down after the spike


def test_priority_bypass_beats_batching():
    arr = poisson_arrivals(lambda t: 400.0, 20.0, seed=2, priority_frac=0.05)
    eng = ElasticEngine(_spec("m", 0.02, 0.001),
                        EngineConfig(n_replicas=8, autoscale=False,
                                     max_batch=64, max_wait_s=0.02))
    res = eng.run(arr, until=20.0)
    assert res["completed"] == len(arr) - res["rejected"]
    # bypass requests never wait max_wait: p50 stays below batch wait + service
    assert res["p50"] < 0.06


def test_simulation_deterministic():
    arr = poisson_arrivals(SPIKE, 30.0, seed=7)
    runs = []
    for _ in range(2):
        eng = ElasticEngine(_spec(), EngineConfig(n_replicas=2, autoscale=True))
        runs.append(eng.run(arr, until=30.0))
    assert runs[0]["p99"] == runs[1]["p99"]
    assert runs[0]["completed"] == runs[1]["completed"]


# ---------------------------------------------------------------------------
# router policies (all three through the same event kernel)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_router_policy_deterministic_under_seed(policy):
    kw = {"seed": 11} if policy == "power_of_two" else (
        {"slo_p99_s": 0.1, "quality_order": ("baseline", "distilled")}
        if policy == "slo_aware" else {})
    arr = poisson_arrivals(lambda t: 400.0, 12.0, seed=3)
    runs = []
    for _ in range(2):
        sys_ = _hetero_system(make_router(policy, **kw))
        runs.append(sys_.run(arr, until=14.0))
    assert runs[0]["p99"] == runs[1]["p99"]
    assert runs[0]["completed"] == runs[1]["completed"]
    for name in ("baseline", "distilled"):
        assert runs[0]["pools"][name]["completed"] == runs[1]["pools"][name]["completed"]
    assert runs[0]["completed"] > 0


@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_request_conservation(policy):
    kw = {"seed": 5} if policy == "power_of_two" else {}
    sys_ = _hetero_system(
        make_router(policy, **kw),
        tiers={"tier0": TierPolicy(300, 30), "tier1": TierPolicy(300, 30)},
    )
    arr = poisson_arrivals(SPIKE, 30.0, seed=4)
    res = sys_.run(arr, until=30.0)
    assert res["arrived"] == len(arr)
    assert res["arrived"] == res["completed"] + res["rejected"] + res["in_queue"]
    assert res["in_queue"] == 0  # queues fully drain once traffic stops
    # per-pool stage completions account for every admitted request
    assert sum(p["completed"] for p in res["pools"].values()) == res["completed"]


def test_slo_aware_router_prefers_quality_for_priority_traffic():
    sys_ = _hetero_system(
        make_router("slo_aware", slo_p99_s=0.5, quality_order=("baseline", "distilled")))
    arr = poisson_arrivals(lambda t: 50.0, 10.0, seed=6, priority_frac=0.2)
    res = sys_.run(arr, until=12.0)
    n_priority = sum(r.priority for r in arr)
    # light load: every pool meets the SLO, so head traffic lands on baseline
    assert res["pools"]["baseline"]["completed"] >= n_priority > 0
    assert res["pools"]["distilled"]["completed"] > 0  # tail goes to the cheap pool


def test_unknown_router_raises():
    with pytest.raises(KeyError):
        make_router("round_robin_nope")


# ---------------------------------------------------------------------------
# per-pool autoscaling under a shared capacity budget
# ---------------------------------------------------------------------------


def test_capacity_budget_grant_and_release():
    b = CapacityBudget(total=4)
    assert b.acquire(3) == 3
    assert b.acquire(3) == 1  # clamped to what's left
    assert b.available == 0
    b.release(2)
    assert b.acquire(5) == 2


def test_pool_scaling_never_exceeds_shared_budget():
    budget_total = 6
    pools = {
        "baseline": PoolSpec(_spec("baseline", 0.02, 1e-3),
                             PoolConfig(n_replicas=1, max_batch=16)),
        "distilled": PoolSpec(_spec("distilled", 0.01, 5e-4),
                              PoolConfig(n_replicas=1, max_batch=16)),
    }
    sys_ = ServingSystem(pools, make_router("least_loaded"),
                         capacity=budget_total, slo_p99_s=0.2)
    arr = poisson_arrivals(SPIKE, 60.0, seed=8)
    res = sys_.run(arr, until=60.0)
    per_pool = [res["pools"][n]["trace"]["replicas"] for n in pools]
    # at every scale tick the fleet total stays within the budget
    for totals in zip(*per_pool):
        assert sum(totals) <= budget_total
    assert max(res["trace"]["replicas"]) <= budget_total
    assert max(res["trace"]["replicas"]) > 2  # budget was actually contended


def test_budget_too_small_for_initial_replicas():
    pools = {
        "a": PoolSpec(_spec("a"), PoolConfig(n_replicas=2)),
        "b": PoolSpec(_spec("b"), PoolConfig(n_replicas=2)),
    }
    with pytest.raises(ValueError):
        ServingSystem(pools, capacity=3)


def test_warm_pool_faster_than_cold():
    sc = AutoScaler(ScalerConfig(warm_pool_size=1))
    assert sc.take_start_delay(0.2, 5.0) == 0.2  # first from warm pool
    assert sc.take_start_delay(0.2, 5.0) == 5.0  # pool exhausted -> cold


# ---------------------------------------------------------------------------
# cascade inference (RecPipe-style two-stage)
# ---------------------------------------------------------------------------


def _cascade_system(candidates=256, rerank_k=16, **kw):
    pools = {
        "baseline": PoolSpec(_spec("baseline", 0.02, 1e-3),
                             PoolConfig(n_replicas=2, max_batch=4, priority_bypass=False)),
        "distilled": PoolSpec(_spec("distilled", 0.004, 5e-5),
                              PoolConfig(n_replicas=2, max_batch=4, priority_bypass=False)),
    }
    return ServingSystem(
        pools, cascade=CascadeConfig("distilled", "baseline",
                                     candidates=candidates, rerank_k=rerank_k), **kw)


def test_cascade_latency_decomposition():
    # generous SLO so the adaptive limiter never sheds — every arrival
    # must traverse both stages for the decomposition to be checkable
    sys_ = _cascade_system(slo_p99_s=5.0)
    arr = poisson_arrivals(lambda t: 40.0, 8.0, seed=9, priority_frac=0.0)
    res = sys_.run(arr, until=12.0)
    assert res["completed"] == len(arr)
    for r in arr:
        tl = r.timeline
        stage1 = tl["s1_done"] - tl["s1_enqueue"]  # queue + service in pool 1
        stage2 = tl["s2_done"] - tl["s2_enqueue"]  # queue + service in pool 2
        e2e = tl["s2_done"] - r.t_arrive
        # end-to-end latency decomposes exactly into the chained stages
        assert e2e == pytest.approx(stage1 + stage2, abs=1e-12)
        # each stage is queueing then service, in order
        assert tl["s1_enqueue"] <= tl["s1_start"] <= tl["s1_done"]
        assert tl["s1_done"] == pytest.approx(tl["s2_enqueue"], abs=1e-12)
        assert tl["s2_enqueue"] <= tl["s2_start"] <= tl["s2_done"]


def test_cascade_stage_costs():
    sys_ = _cascade_system(candidates=256, rerank_k=16, slo_p99_s=5.0)
    arr = poisson_arrivals(lambda t: 30.0, 5.0, seed=10, priority_frac=0.0)
    res = sys_.run(arr, until=10.0)
    # the heavy pool saw rerank_k items per request, not the full set
    items1 = res["pools"]["distilled"]["served_items"]
    items2 = res["pools"]["baseline"]["served_items"]
    assert items1 == 256 * len(arr)
    assert items2 == 16 * len(arr)
    assert res["completed"] == len(arr)


def test_cascade_beats_baseline_only_ranking():
    """The headline experiment in analytic form: under the SAME capacity
    budget and SLO-protected admission, distilled-filter -> baseline-rerank
    sustains more ranking traffic at better tail latency than scoring every
    candidate on the baseline pool."""
    candidates, k = 256, 16
    rate = lambda t: 30.0 if t < 5 else (120.0 if t < 20 else 40.0)
    tiers = lambda: {"tier0": TierPolicy(200, 50), "tier1": TierPolicy(200, 50)}

    pools = {"baseline": PoolSpec(
        _spec("baseline", 0.02, 1e-3),
        PoolConfig(n_replicas=2, max_batch=4, priority_bypass=False))}
    res_base = ServingSystem(
        pools, make_router("least_loaded"),
        tiers=tiers(), slo_p99_s=0.3, capacity=8,
    ).run(poisson_arrivals(rate, 30.0, seed=12, cost=candidates, priority_frac=0.0),
          until=40.0)
    res_casc = _cascade_system(
        candidates, k, tiers=tiers(), slo_p99_s=0.3, capacity=8,
    ).run(poisson_arrivals(rate, 30.0, seed=12, priority_frac=0.0), until=40.0)
    assert res_casc["throughput"] > res_base["throughput"]
    assert res_casc["p99"] <= res_base["p99"]
    assert res_casc["slo_attainment"] > res_base["slo_attainment"]


def test_cascade_requires_configured_pools():
    with pytest.raises(KeyError):
        ServingSystem({"only": PoolSpec(_spec("only"))},
                      cascade=CascadeConfig("distilled", "baseline"))


# ---------------------------------------------------------------------------
# rate limiter + latency model units
# ---------------------------------------------------------------------------


def test_rate_limiter_sheds_low_tier_first():
    rl = HybridRateLimiter({"tier0": TierPolicy(100, 10), "tier1": TierPolicy(100, 10)})
    rl.adapt(p99=1.0, slo=0.1)  # breach -> shed one level
    assert rl.shed_level == 1
    assert rl.admit(0.1, "tier0") is True
    assert rl.admit(0.1, "tier1") is False  # lowest tier shed
    rl.adapt(p99=0.01, slo=0.1)
    assert rl.shed_level == 0


def test_token_bucket_rate():
    rl = HybridRateLimiter({"tier0": TierPolicy(rate=10.0, burst=5.0)})
    admitted = sum(rl.admit(0.0, "tier0") for _ in range(10))
    assert admitted == 5  # burst only
    admitted_later = sum(rl.admit(2.0, "tier0") for _ in range(10))
    assert admitted_later == 5  # refilled to burst cap


def test_latency_model_interpolation():
    lm = LatencyModel(np.array([1.0, 100.0]), np.array([0.01, 0.1]))
    assert abs(lm(1) - 0.01) < 1e-9
    assert 0.01 < lm(50) < 0.1


def test_latency_model_extrapolates_beyond_calibration():
    lm = LatencyModel(np.array([1.0, 100.0]), np.array([0.01, 0.1]))
    slope = (0.1 - 0.01) / 99.0
    assert lm(1000) == pytest.approx(0.1 + slope * 900.0)
    assert lm(1000) > lm(100)  # big ranking batches are never free


def test_shed_order_numeric_not_lexical():
    """Regression: lexical sort put tier10 between tier1 and tier2, so the
    "lowest tier" shed order was wrong past 10 tiers."""
    rl = HybridRateLimiter({f"tier{i}": TierPolicy(100, 10) for i in range(12)})
    rl.adapt(p99=1.0, slo=0.1)
    rl.adapt(p99=1.0, slo=0.1)
    assert rl.shed_level == 2
    # numeric order: the two highest-numbered tiers shed first...
    assert rl.admit(0.0, "tier11") is False
    assert rl.admit(0.0, "tier10") is False
    # ...while tier9 and tier2 stay admitted (lexical order would have shed
    # tier9/tier8 here and kept tier10/tier11)
    assert rl.admit(0.0, "tier9") is True
    assert rl.admit(0.0, "tier2") is True


def test_shed_order_explicit():
    tiers = {t: TierPolicy(100, 10) for t in ("free", "paid", "batch")}
    rl = HybridRateLimiter(tiers, shed_order=("batch", "free", "paid"))
    rl.adapt(p99=1.0, slo=0.1)
    assert rl.admit(0.0, "batch") is False
    assert rl.admit(0.0, "free") is True
    rl.adapt(p99=1.0, slo=0.1)
    assert rl.admit(0.0, "free") is False
    assert rl.admit(0.0, "paid") is True  # highest priority never shed


def test_shed_order_must_cover_all_tiers():
    with pytest.raises(ValueError):
        HybridRateLimiter({"a": TierPolicy(1, 1), "b": TierPolicy(1, 1)},
                          shed_order=("a",))


def test_cost_weighted_token_draws():
    rl = HybridRateLimiter({"tier0": TierPolicy(rate=1.0, burst=10.0)})
    assert rl.admit(0.0, "tier0", cost=8) is True
    assert rl.admit(0.0, "tier0", cost=8) is False  # only 2 tokens left
    assert rl.admit(0.0, "tier0", cost=2) is True


def test_qps_uses_elapsed_time_before_window_fills():
    m = SLOMonitor(window_s=10.0)
    m.record(1.0, 0.01)
    m.record(2.0, 0.01)
    # 2 completions in the first 2 seconds is 1 qps, not 2/window = 0.2
    assert m.percentiles(2.0)["qps"] == pytest.approx(1.0)
    m2 = SLOMonitor(window_s=10.0)
    for i in range(20):
        m2.record(11.0 + i * 0.1, 0.01)
    assert m2.percentiles(13.0)["qps"] == pytest.approx(2.0)  # window again


# ---------------------------------------------------------------------------
# cost-aware serving path: item batching, per-pool admission, cost router
# ---------------------------------------------------------------------------


def _driven_pool(cfg, spec=None):
    """A ReplicaPool driven directly off an EventLoop, with every dispatched
    batch's per-request costs recorded."""
    loop = EventLoop()
    pool = ReplicaPool("p", spec or _spec("m", 0.005, 1e-4), cfg, loop)
    batches = []
    orig = pool._dispatch

    def tap(now, take):
        batches.append([r.cost for r in take])
        orig(now, take)

    pool._dispatch = tap
    loop.on("arrive", lambda now, r: pool.submit(now, r))
    return loop, pool, batches


def test_item_batching_caps_batch_work():
    cfg = PoolConfig(max_batch=8, max_batch_items=128, max_wait_s=0.005,
                     n_replicas=2, autoscale=False, priority_bypass=False)
    loop, pool, batches = _driven_pool(cfg)
    costs = [1, 7, 64, 3, 130, 1, 64, 64, 2, 1]
    reqs = [Request(i, 0.001 * i, "tier0", cost=costs[i % len(costs)])
            for i in range(60)]
    for r in reqs:
        loop.push(r.t_arrive, "arrive", r)
    loop.run()
    assert sum(len(b) for b in batches) == len(reqs)  # nothing lost
    for b in batches:
        assert len(b) <= cfg.max_batch
        # item budget holds for every multi-request batch; a single request
        # larger than the budget still dispatches, alone
        assert sum(b) <= cfg.max_batch_items or len(b) == 1
    assert [130] in batches  # the oversized request went out by itself


def test_count_fallback_still_closes_batches():
    cfg = PoolConfig(max_batch=4, max_batch_items=10_000, max_wait_s=1.0,
                     n_replicas=1, autoscale=False, priority_bypass=False)
    loop, pool, batches = _driven_pool(cfg)
    for i in range(8):
        loop.push(0.001 * i, "arrive", Request(i, 0.001 * i, "tier0", cost=1))
    loop.run()
    # far below the item budget, the count cap alone closes both batches
    assert [len(b) for b in batches] == [4, 4]


def test_partial_remainder_deadline_from_oldest_enqueue():
    """Regression: re-arming a partial remainder from `now` let its head
    request wait up to 2x max_wait_s across successive batch closes."""
    loop = EventLoop()
    pool = ReplicaPool(
        "p", _spec(), PoolConfig(max_batch=8, max_batch_items=4, max_wait_s=0.1,
                                 n_replicas=1, autoscale=False), loop)
    reqs = [Request(0, 0.0, "tier0", cost=3), Request(1, 0.04, "tier0", cost=2),
            Request(2, 0.06, "tier0", cost=1)]
    for r in reqs:
        r.t_enqueue = r.t_arrive
    pool.queue = list(reqs)
    pool.queued_cost = 6
    pool._flush(0.06)
    # batch = [cost 3] (adding cost 2 would exceed the item budget of 4);
    # the remainder's head enqueued at 0.04, so it must flush by 0.14
    assert pool.queue == reqs[1:]
    assert pool._batch_deadline == pytest.approx(0.14)  # not now + 0.1 = 0.16


def test_until_zero_horizon_honored():
    eng = ElasticEngine(_spec(), EngineConfig(n_replicas=1, autoscale=False))
    arr = poisson_arrivals(lambda t: 50.0, 2.0, seed=14)
    res = eng.run(arr, until=0.0)
    # until=0.0 used to fall through `until or ...` to the arrivals-derived
    # horizon; with a zero horizon nothing completes "in horizon"
    assert res["completed_in_horizon"] == 0
    assert res["throughput"] == 0.0
    assert res["completed"] > 0  # the backlog still drains after the horizon


def test_second_run_is_an_explicit_error():
    eng = ElasticEngine(_spec(), EngineConfig(n_replicas=1, autoscale=False))
    arr = poisson_arrivals(lambda t: 20.0, 1.0, seed=15)
    eng.run(arr, until=2.0)
    with pytest.raises(RuntimeError, match="already run"):
        eng.run(arr, until=2.0)


def test_stage_stamps_survive_ab_replay():
    """Regression: stage-0 used to stamp under the s1_ prefix, so replaying
    one arrival list through a baseline run and then a cascade run silently
    overwrote the baseline stamps (cascade.admit shares the timeline)."""
    arr = poisson_arrivals(lambda t: 30.0, 4.0, seed=16, priority_frac=0.0)
    base = ServingSystem(
        {"baseline": PoolSpec(_spec("baseline", 0.02, 1e-3),
                              PoolConfig(n_replicas=2, priority_bypass=False))},
        slo_p99_s=5.0)
    base.run(arr, until=8.0)
    s0_done = {r.rid: r.timeline["s0_done"] for r in arr}
    casc = _cascade_system(slo_p99_s=5.0)
    casc.run(arr, until=8.0)
    for r in arr:
        assert r.timeline["s0_done"] == s0_done[r.rid]  # baseline stamps intact
        assert "s1_done" in r.timeline and "s2_done" in r.timeline


class _SplitRouter(Router):
    """Deterministic test router: ranking traffic to the heavy pool,
    pointwise traffic to the cheap pool."""

    name = "split_test"

    def select_pool(self, req, pools, now):
        by = {p.name: p for p in pools}
        return by["heavy"] if req.cost > 1 else by["cheap"]


def _two_pool_overload(heavy_tiers):
    pools = {
        "heavy": PoolSpec(
            _spec("heavy", 0.02, 1e-3),
            PoolConfig(n_replicas=2, autoscale=False, max_batch=4,
                       max_batch_items=512, priority_bypass=False),
            tiers=heavy_tiers),
        "cheap": PoolSpec(
            _spec("cheap", 0.004, 5e-5),
            PoolConfig(n_replicas=2, autoscale=False)),
    }
    sys_ = ServingSystem(pools, _SplitRouter(), slo_p99_s=0.25,
                         adaptive_shedding=False)
    arr = poisson_arrivals(lambda t: 120.0, 20.0, seed=17, priority_frac=0.0,
                           cost_mix=((1, 0.7), (256, 0.3)))
    return sys_.run(arr, until=20.0)


def test_per_pool_admission_protects_heavy_pool():
    unprotected = _two_pool_overload(None)
    protected = _two_pool_overload(
        {"tier0": TierPolicy(rate=800.0, burst=400.0),
         "tier1": TierPolicy(rate=800.0, burst=400.0)})
    heavy_p, heavy_u = protected["pools"]["heavy"], unprotected["pools"]["heavy"]
    # cost-weighted draws bound admitted WORK: the heavy pool sheds and its
    # stage p99 recovers instead of growing with the unbounded backlog
    assert heavy_p["shed"] > 0
    assert heavy_p["p99"] < 0.5 * heavy_u["p99"]
    # the cheap pool keeps absorbing its tail traffic, untouched
    assert protected["pools"]["cheap"]["shed"] == 0
    assert (protected["pools"]["cheap"]["completed"]
            == unprotected["pools"]["cheap"]["completed"] > 0)
    # pool-local sheds count as rejections: conservation still holds
    assert protected["arrived"] == (protected["completed"]
                                    + protected["rejected"]
                                    + protected["in_queue"])


def test_cost_model_router_is_cost_sensitive():
    pools = {
        "bulk": PoolSpec(_spec("bulk", 0.02, 1e-5), PoolConfig(n_replicas=1)),
        "point": PoolSpec(_spec("point", 0.002, 1e-3), PoolConfig(n_replicas=1)),
    }
    sys_ = ServingSystem(pools, make_router("cost_model"))
    plist = list(sys_.pools.values())
    big = Request(0, 0.0, "tier0", cost=512)
    small = Request(1, 0.0, "tier0", cost=1)
    # the flat latency curve wins at scale, the cheap base wins pointwise
    assert sys_.router.select_pool(big, plist, 0.0).name == "bulk"
    assert sys_.router.select_pool(small, plist, 0.0).name == "point"


def _mixed_run(max_batch_items):
    pools = {
        "baseline": PoolSpec(
            _spec("baseline", 0.02, 1e-3),
            PoolConfig(n_replicas=2, max_batch=64, max_batch_items=max_batch_items,
                       autoscale=False, priority_bypass=False)),
        "distilled": PoolSpec(
            _spec("distilled", 0.004, 5e-5),
            PoolConfig(n_replicas=2, max_batch=64, max_batch_items=max_batch_items,
                       autoscale=False, priority_bypass=False)),
    }
    sys_ = ServingSystem(pools, make_router("cost_model"), slo_p99_s=0.3,
                         adaptive_shedding=False)
    arr = poisson_arrivals(lambda t: 250.0, 15.0, seed=18, priority_frac=0.0,
                           cost_mix=((1, 0.9), (256, 0.1)))
    return sys_.run(arr, until=15.0)


def test_item_batching_improves_tail_on_mixed_traffic():
    count_res = _mixed_run(None)
    item_res = _mixed_run(256)
    # a 512-candidate ranking query no longer rides in (and stalls) the same
    # batch as dozens of pointwise queries: tail latency drops without
    # giving up sustained throughput
    assert item_res["p99"] < count_res["p99"]
    assert item_res["completed_in_horizon"] >= count_res["completed_in_horizon"]


# ---------------------------------------------------------------------------
# multi-cell federation (cross-cell routing + spillover)
# ---------------------------------------------------------------------------


def _cell_spec(n_replicas=2, slo=0.15, autoscale=False, capacity=None,
               scaler=None, shedding=True):
    return CellSpec(
        pools={"baseline": PoolSpec(
            _spec("baseline", 0.018, 8e-4),
            PoolConfig(n_replicas=n_replicas, autoscale=autoscale,
                       max_batch=32, max_wait_s=0.02),
            scaler)},
        capacity=capacity, slo_p99_s=slo, adaptive_shedding=shedding)


def _skewed_arrivals(rate, horizon, weights, seed=0):
    arr = poisson_arrivals(lambda t: float(rate), horizon, seed=seed,
                           priority_frac=0.0)
    return assign_homes(arr, weights, seed=seed + 1)


SKEW3 = {"us": 0.6, "eu": 0.25, "ap": 0.15}


@pytest.mark.parametrize("policy", sorted(CELL_POLICIES))
def test_federation_conservation_with_spillover(policy):
    """Fleet-wide conservation holds with spillover on: injected ==
    completed + rejected + in_flight, in_flight (queues + inter-cell
    transit) fully drains, and every spill-out has a matching spill-in."""
    fed = FederatedSystem({n: _cell_spec() for n in SKEW3}, policy=policy,
                          spillover=True, rtt_s=0.005, slo_p99_s=0.15)
    arr = _skewed_arrivals(2400.0, 12.0, SKEW3, seed=20)
    res = fed.run(arr, until=12.0)
    assert res["injected"] == len(arr)
    assert res["injected"] == res["completed"] + res["rejected"] + res["in_flight"]
    assert res["in_flight"] == 0 and res["in_transit"] == 0
    # spill legs balance once transit has drained
    assert res["spilled"] == res["spilled_in"]
    # per-cell attribution: arrived (incl. spilled-in) splits exactly into
    # completions, rejections and hand-offs — spills are NOT rejections
    for c in res["cells"].values():
        assert c["arrived"] == (c["completed"] + c["rejected"]
                                + c["spill"]["spilled_out"])
    if policy == "sticky":  # skewed sticky traffic must actually spill
        assert res["spilled"] > 0


@pytest.mark.parametrize("n_cells", [1, 3])
def test_federation_deterministic_replay(n_cells):
    """One arrival list replays bit-identically through a 1-cell and an
    N-cell topology (spillover, RTT transit and cell policies included)."""
    weights = dict(list(SKEW3.items())[:n_cells])
    runs = []
    for _ in range(2):
        fed = FederatedSystem({n: _cell_spec() for n in weights},
                              policy="sticky", spillover=True,
                              rtt_s=0.005, slo_p99_s=0.15)
        arr = _skewed_arrivals(1500.0, 8.0, weights, seed=21)
        runs.append(fed.run(arr, until=8.0))
    assert runs[0]["p99"] == runs[1]["p99"]
    assert runs[0]["completed"] == runs[1]["completed"]
    assert runs[0]["spilled"] == runs[1]["spilled"]
    for name in weights:
        a, b = runs[0]["cells"][name], runs[1]["cells"][name]
        assert a["completed"] == b["completed"]
        assert a["spill"] == b["spill"]
    assert runs[0]["completed"] > 0


def test_single_cell_federation_matches_plain_system():
    """A 1-cell federation is just the embedded ServingSystem: same
    arrivals, same completions/latency stats as running it standalone."""
    arr1 = poisson_arrivals(lambda t: 300.0, 8.0, seed=22, priority_frac=0.0)
    arr2 = poisson_arrivals(lambda t: 300.0, 8.0, seed=22, priority_frac=0.0)
    fed = FederatedSystem({"only": _cell_spec()}, policy="sticky",
                          spillover=True, slo_p99_s=0.15)
    plain = ServingSystem(
        {"baseline": PoolSpec(_spec("baseline", 0.018, 8e-4),
                              PoolConfig(n_replicas=2, autoscale=False,
                                         max_batch=32, max_wait_s=0.02))},
        slo_p99_s=0.15)
    res_f = fed.run(arr1, until=8.0)
    res_p = plain.run(arr2, until=8.0)
    assert res_f["completed"] == res_p["completed"]
    assert res_f["p99"] == res_p["p99"]
    assert res_f["spilled"] == 0  # nowhere to spill


def test_per_cell_budget_independence():
    """Cell budgets are independent: the overloaded cell exhausts its OWN
    CapacityBudget while the idle cell's replicas never move — one cell
    scaling up cannot spend another cell's budget."""
    cells = {
        "hot": _cell_spec(autoscale=True, capacity=6, shedding=False,
                          scaler=ScalerConfig(min_replicas=2, max_replicas=16)),
        "cold": _cell_spec(autoscale=True, capacity=6, shedding=False,
                           scaler=ScalerConfig(min_replicas=2, max_replicas=16)),
    }
    fed = FederatedSystem(cells, policy="sticky", spillover=False,
                          slo_p99_s=0.15)
    arr = _skewed_arrivals(4400.0, 20.0, {"hot": 0.95, "cold": 0.05}, seed=23)
    res = fed.run(arr, until=20.0)
    hot = res["cells"]["hot"]["pools"]["baseline"]["trace"]["replicas"]
    cold = res["cells"]["cold"]["pools"]["baseline"]["trace"]["replicas"]
    assert max(hot) == 6  # grew to its own budget...
    assert max(cold) == 2  # ...without touching the idle cell's
    assert all(h <= 6 for h in hot)


@pytest.mark.slow
def test_global_cap_bounds_sum_of_cell_budgets():
    """With a global fleet cap, per-cell budgets become children of it:
    each cell still respects its own ceiling AND the cells' total replica
    count never exceeds the global cap at any scale tick."""
    cells = {
        "a": _cell_spec(autoscale=True, capacity=5, shedding=False,
                        scaler=ScalerConfig(min_replicas=2, max_replicas=16)),
        "b": _cell_spec(autoscale=True, capacity=5, shedding=False,
                        scaler=ScalerConfig(min_replicas=2, max_replicas=16)),
    }
    fed = FederatedSystem(cells, policy="sticky", spillover=False,
                          capacity=7, slo_p99_s=0.15)
    arr = _skewed_arrivals(6000.0, 20.0, {"a": 0.5, "b": 0.5}, seed=24)
    res = fed.run(arr, until=20.0)
    tr_a = res["cells"]["a"]["pools"]["baseline"]["trace"]["replicas"]
    tr_b = res["cells"]["b"]["pools"]["baseline"]["trace"]["replicas"]
    for a, b in zip(tr_a, tr_b):
        assert a <= 5 and b <= 5  # cell-local ceilings
        assert a + b <= 7  # global cap binds the sum
    assert max(a + b for a, b in zip(tr_a, tr_b)) == 7  # cap was contended


def test_capacity_budget_parent_grants():
    parent = CapacityBudget(total=5)
    child_a = CapacityBudget(total=4, parent=parent)
    child_b = CapacityBudget(total=4, parent=parent)
    assert child_a.acquire(3) == 3  # within both budgets
    assert child_b.acquire(4) == 2  # clamped by the parent's remaining 2
    assert child_b.acquire(1) == 0
    assert parent.available == 0
    child_a.release(2)  # frees the parent too
    assert child_b.acquire(2) == 2
    assert child_b.used == 4 and parent.used == 5


def test_spillover_rescues_skewed_overload():
    """The experiment-5 claim in analytic form: under 60/25/15 skew at
    ~80% fleet load, spillover cuts fleet p99 at equal-or-better fleet
    throughput versus letting the hot cell shed alone."""
    res = {}
    for spillover in (False, True):
        fed = FederatedSystem({n: _cell_spec() for n in SKEW3},
                              policy="sticky", spillover=spillover,
                              rtt_s=0.005, slo_p99_s=0.15)
        arr = _skewed_arrivals(2400.0, 15.0, SKEW3, seed=25)
        res[spillover] = fed.run(arr, until=15.0)
    assert res[True]["p99"] < res[False]["p99"]
    assert (res[True]["completed_in_horizon"]
            >= res[False]["completed_in_horizon"])
    assert res[True]["spilled"] > 0


def _cascade_cell(n_rerank):
    return CellSpec(
        pools={
            "distilled": PoolSpec(_spec("distilled", 0.004, 5e-5),
                                  PoolConfig(n_replicas=4, autoscale=False,
                                             max_batch=4, priority_bypass=False)),
            "baseline": PoolSpec(_spec("baseline", 0.02, 1e-3),
                                 PoolConfig(n_replicas=n_rerank, autoscale=False,
                                            max_batch=4, priority_bypass=False)),
        },
        cascade=CascadeConfig("distilled", "baseline",
                              candidates=256, rerank_k=16),
        slo_p99_s=0.3)


def test_spilled_cascade_keeps_stage_timeline():
    """Regression: a cascade request whose rerank stage spills cross-cell
    keeps its full stage timeline — s1_* stamped at the home cell, s2_*
    at the remote cell after exactly the RTT, stages still in order."""
    rtt = 0.005
    fed = FederatedSystem({"hot": _cascade_cell(1), "cold": _cascade_cell(4)},
                          policy="sticky", spillover=True, rtt_s=rtt,
                          slo_p99_s=0.3)
    arr = poisson_arrivals(lambda t: 120.0, 10.0, seed=26, priority_frac=0.0)
    assign_homes(arr, {"hot": 0.9, "cold": 0.1}, seed=27)
    res = fed.run(arr, until=10.0)
    assert res["cascade_spilled"] > 0
    assert res["injected"] == res["completed"] + res["rejected"] + res["in_flight"]
    spilled = 0
    for r in arr:
        tl = r.timeline
        if "s2_enqueue" not in tl:
            continue
        gap = tl["s2_enqueue"] - tl["s1_done"]
        assert tl["s1_enqueue"] <= tl["s1_start"] <= tl["s1_done"]
        assert tl["s2_enqueue"] <= tl["s2_start"] <= tl["s2_done"]
        if gap > 1e-9:  # the spilled ones paid exactly the inter-cell RTT
            assert gap == pytest.approx(rtt, abs=1e-9)
            spilled += 1
        else:  # home-cell stages chain back-to-back
            assert gap == pytest.approx(0.0, abs=1e-9)
    assert spilled == res["cascade_spilled"]


def test_federated_rollup_sums_cells():
    cells = {
        "a": {"arrived": 10, "completed": 7, "rejected": 1, "in_queue": 0,
              "completed_in_horizon": 7, "final_replicas": 2,
              "spill": {"spilled_out": 2, "spilled_in": 0,
                        "cascade_out": 1, "cascade_in": 0}},
        "b": {"arrived": 5, "completed": 5, "rejected": 0, "in_queue": 0,
              "completed_in_horizon": 4, "final_replicas": 3,
              "spill": {"spilled_out": 0, "spilled_in": 2,
                        "cascade_out": 0, "cascade_in": 1}},
    }
    roll = federated_rollup(cells)
    assert roll["arrived"] == 15 and roll["completed"] == 12
    assert roll["spilled_out"] == roll["spilled_in"] == 2
    assert roll["cascade_out"] == roll["cascade_in"] == 1
    assert roll["final_replicas"] == 5


def test_unknown_cell_policy_raises():
    with pytest.raises(KeyError):
        make_cell_policy("round_robin_nope")


def test_federation_second_run_raises():
    fed = FederatedSystem({"only": _cell_spec()})
    arr = poisson_arrivals(lambda t: 20.0, 1.0, seed=28)
    fed.run(arr, until=2.0)
    with pytest.raises(RuntimeError, match="already run"):
        fed.run(arr, until=2.0)


# ---------------------------------------------------------------------------
# caching layer: eviction policies, miss costs, result cache, conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", sorted(CACHE_POLICIES))
def test_cache_eviction_deterministic(policy):
    """Same stream, same capacity => bit-identical hit/miss counts,
    eviction count and final resident set, for every policy."""
    stream = zipf_id_stream(20_000, 3000, 1.2, seed=31)
    runs = []
    for _ in range(2):
        cache = EmbeddingCache(256, policy)
        hits, misses = cache.lookup(stream)
        runs.append((hits, misses, cache.evictions, cache.resident_keys()))
    assert runs[0] == runs[1]
    hits, misses, evictions, keys = runs[0]
    assert hits + misses == len(stream)
    assert hits > 0 and evictions > 0
    assert len(keys) <= 256 and len(set(keys)) == len(keys)


def test_cache_capacity_bound_and_warm_counts():
    cache = EmbeddingCache(16, "lru")
    cache.warm(range(100))  # warming admits but never counts
    assert cache.hits == cache.misses == 0
    assert len(cache.resident_keys()) == 16
    hits, misses = cache.lookup([99, 98, 0])  # 0 was evicted long ago
    assert (hits, misses) == (2, 1)
    assert cache.hit_rate == pytest.approx(2 / 3)


def test_unknown_cache_policy_raises():
    with pytest.raises(KeyError):
        make_cache_policy("belady_nope", 8)


def test_s3fifo_capacity_invariant():
    with pytest.raises(ValueError):  # 1 row can't split small + main
        EmbeddingCache(1, "s3fifo")
    cache = EmbeddingCache(2, "s3fifo")
    cache.warm(range(10))
    assert len(cache.resident_keys()) <= 2


def test_lru_hit_rate_matches_che_approximation():
    """Measured LRU hit-rate on a Zipf stream lands within tolerance of
    the Che-approximation estimate: with characteristic time T solving
    sum_i (1 - exp(-p_i T)) = C, the hit rate is
    sum_i p_i (1 - exp(-p_i T))."""
    vocab, capacity, alpha = 2000, 200, 1.2
    p = np.arange(1, vocab + 1, dtype=np.float64) ** -alpha
    p /= p.sum()
    lo, hi = 0.0, 1e12
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        lo, hi = (mid, hi) if np.sum(1.0 - np.exp(-p * mid)) < capacity else (lo, mid)
    T = 0.5 * (lo + hi)
    predicted = float(np.sum(p * (1.0 - np.exp(-p * T))))
    cache = EmbeddingCache(capacity, "lru")
    stream = zipf_id_stream(60_000, vocab, alpha, seed=32)
    cache.warm(stream[:10_000])  # reach steady state before measuring
    cache.lookup(stream[10_000:])
    assert cache.hit_rate == pytest.approx(predicted, abs=0.03)


def test_miss_rows_extend_service_time():
    """The cache-aware decomposition: dense calibrated compute plus
    embed_fetch_s per missed row — and nothing else."""
    spec = ReplicaSpec("m", LatencyModel.analytic(0.01, 1e-4), embed_fetch_s=1e-3)
    assert spec.service_time(4, 0) == spec.latency(4)
    assert spec.service_time(4, 8) == pytest.approx(spec.latency(4) + 8e-3)
    rep = Replica(0, spec, ready_at=0.0)
    start, done = rep.start_batch(0.0, 4, miss_rows=8)
    assert done - start == pytest.approx(spec.latency(4) + 8e-3)


def test_result_cache_ttl_and_eviction():
    rc = ResultCache(capacity=2, ttl_s=1.0)
    rc.put(0.0, ("a",))
    assert rc.get(0.5, ("a",)) is not None
    assert rc.get(2.0, ("a",)) is None  # expired (and dropped)
    rc.put(2.0, ("b",))
    rc.put(2.0, ("c",))
    rc.put(2.0, ("d",))  # capacity 2: LRU "b" evicted
    assert rc.get(2.1, ("b",)) is None
    assert rc.get(2.1, ("c",)) is not None
    assert rc.get(2.1, ("d",)) is not None


def _cached_pool_spec(name, cache=None, fetch=2e-4):
    spec = dataclasses.replace(_spec(name, 0.01, 2e-4), embed_fetch_s=fetch)
    return PoolSpec(
        spec,
        PoolConfig(n_replicas=2, autoscale=False, max_batch=32, max_wait_s=0.02),
        cache=cache,
    )


@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_request_conservation_with_caching(policy):
    """Fleet conservation (injected == completed + rejected + in_queue,
    queues drained) holds for every router with the caching layer live:
    a cached pool (result cache included), an uncached pool paying full
    fetch, id-carrying Zipf traffic and a shedding limiter."""
    kw = {"seed": 5} if policy == "power_of_two" else {}
    pools = {
        "cached": _cached_pool_spec(
            "cached", CacheConfig(512, "lru", result_capacity=512, result_ttl_s=1.0)),
        "uncached": _cached_pool_spec("uncached"),
    }
    sys_ = ServingSystem(
        pools, make_router(policy, **kw),
        tiers={"tier0": TierPolicy(300, 30), "tier1": TierPolicy(300, 30)},
        slo_p99_s=0.15)
    arr = poisson_arrivals(SPIKE, 20.0, seed=33)
    attach_zipf_ids(arr, 4000, 8, alpha=1.2, seed=34, n_distinct=500)
    res = sys_.run(arr, until=20.0)
    assert res["arrived"] == len(arr)
    assert res["arrived"] == res["completed"] + res["rejected"] + res["in_queue"]
    assert res["in_queue"] == 0
    assert sum(p["completed"] for p in res["pools"].values()) == res["completed"]
    assert res["cache"]["hits"] > 0  # the cache actually saw traffic
    assert 0.0 < res["cache"]["hit_rate"] <= 1.0


def test_result_cache_serves_repeat_queries():
    """Repeat queries (same ids signature within the TTL) complete from
    the result cache: counted, completed with zero stage latency, and
    conservation still holds."""
    pools = {"only": _cached_pool_spec(
        "only", CacheConfig(512, "lru", result_capacity=1024, result_ttl_s=5.0))}
    sys_ = ServingSystem(pools, slo_p99_s=5.0, adaptive_shedding=False)
    arr = poisson_arrivals(lambda t: 200.0, 10.0, seed=35, priority_frac=0.0)
    attach_zipf_ids(arr, 4000, 8, alpha=1.3, seed=36, n_distinct=100)
    res = sys_.run(arr, until=10.0)
    hits = res["cache"]["result_hits"]
    assert hits > 0
    assert res["completed"] == len(arr)
    # a result hit stamps enqueue == done (zero time in the pool)
    instant = sum(
        1 for r in arr if r.timeline["s0_done"] == r.timeline["s0_enqueue"])
    assert instant == hits


def test_warm_cache_beats_no_cache_on_zipf_traffic():
    """The experiment-6 headline in analytic form: offered load past the
    NO-cache fleet's sustainable rate but inside the warm-cache fleet's —
    the warm cache wins tail latency AND in-horizon completions."""
    vocab, ids_per_req, horizon = 5000, 8, 10.0
    spec = dataclasses.replace(_spec("baseline", 0.02, 1e-3),
                               embed_fetch_s=2.0 * 0.052 / (32 * ids_per_req))
    wait = 0.02
    r_cold = sustainable_rate(spec, 2, wait, ids_per_req, hit_rate=0.0)
    r_warm = sustainable_rate(spec, 2, wait, ids_per_req, hit_rate=0.8)
    rate = min(1.2 * r_cold, 0.9 * r_warm)
    results = {}
    for label, cache in (("none", None), ("warm", CacheConfig(vocab // 8, "lru"))):
        pools = {"baseline": PoolSpec(
            spec, PoolConfig(n_replicas=2, autoscale=False,
                             max_batch=32, max_wait_s=wait),
            cache=cache)}
        sys_ = ServingSystem(pools, slo_p99_s=0.15, adaptive_shedding=False)
        if cache is not None:
            sys_.pools["baseline"].embed_cache.warm(
                zipf_id_stream(4 * vocab, vocab, 1.1, seed=37))
        arr = poisson_arrivals(lambda t: rate, horizon, seed=38, priority_frac=0.0)
        attach_zipf_ids(arr, vocab, ids_per_req, alpha=1.1, seed=39)
        results[label] = sys_.run(arr, until=horizon)
    assert results["warm"]["cache"]["hit_rate"] > 0.6
    assert results["warm"]["p99"] < results["none"]["p99"]
    assert (results["warm"]["completed_in_horizon"]
            > results["none"]["completed_in_horizon"])


def test_cost_model_router_prefers_warm_pool():
    """Identical pools except the cache: after both served the same
    id-carrying traffic, the cost model charges the cold pool its
    predicted miss cost and the warm pool wins the estimate."""
    loop = EventLoop()
    spec = dataclasses.replace(_spec("m", 0.01, 1e-4), embed_fetch_s=1e-3)
    cfg = lambda: PoolConfig(n_replicas=1, autoscale=False)
    cold = ReplicaPool("cold", spec, cfg(), loop)
    warm = ReplicaPool("warm", spec, cfg(), loop, event_key="warm2",
                       cache_cfg=CacheConfig(64, "lru"))
    warm.embed_cache.warm(range(64))
    ids = tuple(range(8))
    for pool in (cold, warm):
        pool.submit(0.0, Request(0, 0.0, "tier0", priority=True, ids=ids))
    loop.run()
    assert warm.hit_rate() == 1.0
    est_cold = CostModelRouter.estimate(cold, 1, 100.0)
    est_warm = CostModelRouter.estimate(warm, 1, 100.0)
    assert est_warm < est_cold
    # the gap is exactly the predicted fetch cost of the 8 rows/item
    assert est_cold - est_warm == pytest.approx(8 * spec.embed_fetch_s)


def test_federation_conservation_with_cell_local_caches():
    """Spillover with per-cell caches and DISJOINT hot id sets: fleet
    conservation holds, and the spill-receiving cell's hit-rate drops
    below the no-spill run's — remote requests miss cold."""
    vocab = 4000

    def cells():
        return {
            name: CellSpec(
                pools={"baseline": _cached_pool_spec(
                    "baseline", CacheConfig(vocab // 8, "lru"), fetch=1e-3)},
                slo_p99_s=0.15, adaptive_shedding=False)
            for name in ("hot", "cold")
        }

    res = {}
    for spillover in (False, True):
        fed = FederatedSystem(cells(), policy="sticky", spillover=spillover,
                              rtt_s=0.005, slo_p99_s=0.15)
        # hot cell: 75% of 1600/s = 1200/s vs a warm-cache equilibrium of
        # ~830/s — past local capacity, inside the 2-cell fleet's
        arr = poisson_arrivals(lambda t: 1600.0, 10.0, seed=40, priority_frac=0.0)
        assign_homes(arr, {"hot": 0.75, "cold": 0.25}, seed=41)
        for i, name in enumerate(("hot", "cold")):
            mine = [r for r in arr if r.home == name]
            attach_zipf_ids(mine, vocab, 8, alpha=1.2, seed=42 + i,
                            offset=i * vocab)
        res[spillover] = fed.run(arr, until=10.0)
    for r in res.values():
        assert r["injected"] == r["completed"] + r["rejected"] + r["in_flight"]
        assert r["in_flight"] == 0
    assert res[True]["spilled"] > 0
    hit = lambda r, c: r["cells"][c]["cache"]["hit_rate"]
    assert hit(res[True], "cold") < hit(res[False], "cold")
    # fleet rollup aggregates the cell caches
    roll = federated_rollup(res[True]["cells"])
    assert roll["cache"]["hits"] == sum(
        res[True]["cells"][c]["cache"]["hits"] for c in ("hot", "cold"))


# ---------------------------------------------------------------------------
# per-cell-pair RTT matrix
# ---------------------------------------------------------------------------


def test_rtt_matrix_lookup_rules():
    m = RttMatrix(0.005, {("a", "b"): 0.02, ("b", "c"): 0.001})
    assert m("a", "b") == 0.02
    assert m("b", "a") == 0.02  # symmetric fallback
    assert m("c", "b") == 0.001
    assert m("a", "c") == 0.005  # scalar fallback
    assert m("a", "a") == 0.0 and m("", "b") == 0.0  # same-cell / front door


def test_spilled_stage_pays_per_pair_rtt():
    """With an RTT matrix, a spilled rerank stage pays the (src, dst)
    pair's transfer time — visible as exactly that gap between s1_done
    and s2_enqueue."""
    pair_rtt = 0.012
    fed = FederatedSystem({"hot": _cascade_cell(1), "cold": _cascade_cell(4)},
                          policy="sticky", spillover=True, rtt_s=0.005,
                          rtt={("hot", "cold"): pair_rtt}, slo_p99_s=0.3)
    arr = poisson_arrivals(lambda t: 120.0, 10.0, seed=43, priority_frac=0.0)
    assign_homes(arr, {"hot": 0.9, "cold": 0.1}, seed=44)
    res = fed.run(arr, until=10.0)
    assert res["cascade_spilled"] > 0
    gaps = [r.timeline["s2_enqueue"] - r.timeline["s1_done"]
            for r in arr if "s2_enqueue" in r.timeline]
    spilled = [g for g in gaps if g > 1e-9]
    assert len(spilled) == res["cascade_spilled"]
    for g in spilled:
        assert g == pytest.approx(pair_rtt, abs=1e-9)


# ---------------------------------------------------------------------------
# adaptive control plane (serving/control.py) + control-loop regressions
# ---------------------------------------------------------------------------


def test_sustainable_rate_flat_curve_no_zero_division():
    """Regression: a flat latency curve with no embedding traffic made
    marginal + miss_fetch == 0 and sustainable_rate divide by zero."""
    flat = ReplicaSpec("m", LatencyModel.analytic(0.01, 0.0))
    # base fits the batching window: unbounded, not a crash
    assert sustainable_rate(flat, 2, 0.02) == float("inf")
    # base exceeds the window: the documented 1 rps floor
    assert sustainable_rate(flat, 1, 0.005) == 1.0
    # embedding traffic restores a finite equilibrium on the same curve
    fetchy = dataclasses.replace(flat, embed_fetch_s=1e-3)
    rate = sustainable_rate(fetchy, 2, 0.02, ids_per_request=8)
    assert np.isfinite(rate) and rate > 1.0


def test_result_cache_keys_on_ids_and_cost():
    """Regression: the result-cache signature was req.ids alone, so a
    pointwise probe and a 512-candidate ranking request over the same
    ids shared a cached result."""
    loop = EventLoop()
    pool = ReplicaPool(
        "p", _spec(), PoolConfig(n_replicas=1, autoscale=False,
                                 priority_bypass=False, max_wait_s=0.005),
        loop, slo_s=1.0,
        cache_cfg=CacheConfig(64, "lru", result_capacity=16, result_ttl_s=60.0))
    ids = (1, 2, 3)
    pool.submit(0.0, Request(0, 0.0, "tier0", cost=1, ids=ids))
    loop.run()
    # same ids, different cost: a different computation — must be served,
    # not replayed from the pointwise result
    rank = Request(1, 1.0, "tier0", cost=512, ids=ids)
    pool.submit(1.0, rank)
    loop.run()
    assert rank.timeline["s0_done"] > rank.timeline["s0_enqueue"]
    # same ids, same cost: a true repeat — instant
    repeat = Request(2, 2.0, "tier0", cost=1, ids=ids)
    pool.submit(2.0, repeat)
    loop.run()
    assert repeat.timeline["s0_done"] == repeat.timeline["s0_enqueue"]
    assert pool.result_cache.hits == 1


def test_first_scale_tick_clamped_into_short_horizon():
    """Regression: with horizon < scale_tick_s the first scale event
    fired past the horizon — traces stayed empty and the limiter /
    scaler / batch controller never adapted on short runs."""
    eng = ElasticEngine(_spec("m", 0.002, 1e-5),
                        EngineConfig(n_replicas=1, autoscale=False))
    arr = poisson_arrivals(lambda t: 100.0, 0.4, seed=50)
    res = eng.run(arr, until=0.4)
    assert res["trace"]["t"] == [0.4]
    assert res["pools"]["m"]["trace"]["t"] == [0.4]


def test_first_scale_tick_clamped_in_federation():
    fed = FederatedSystem({"only": _cell_spec()}, policy="sticky",
                          slo_p99_s=0.15)
    arr = poisson_arrivals(lambda t: 100.0, 0.4, seed=51, priority_frac=0.0)
    res = fed.run(arr, until=0.4)
    assert res["trace"]["t"] == [0.4]
    assert res["cells"]["only"]["trace"]["t"] == [0.4]


def test_ewma_first_sample_exact_then_decays():
    with pytest.raises(ValueError):
        Ewma(1.5)
    e = Ewma(0.5)
    assert e.value is None
    assert e.update(4.0) == 4.0  # first sample initialises exactly
    assert e.update(8.0) == 6.0
    assert e.samples == 2


def test_online_latency_model_converges_on_miscalibration():
    """A spec whose offline calibration is 2x off: the DENSE correction
    locks onto the observed/offline ratio and the corrected curve
    matches the true one at every batch size — while the FETCH
    correction (learned separately since the dense/fetch split) stays
    untouched by pure dense drift."""
    offline = LatencyModel.analytic(0.01, 1e-4)
    truth = LatencyModel.analytic(0.02, 2e-4)
    model = OnlineLatencyModel(offline, embed_fetch_s=1e-3, alpha=0.25)
    assert model.correction == 1.0  # unobserved: trust the calibration
    assert model.dense(64) == pytest.approx(offline(64))
    for items in (1, 8, 32, 128, 512) * 4:
        model.observe(items, 0, truth(items))
    assert model.correction == pytest.approx(2.0, abs=1e-9)
    for items in (1, 16, 100, 1000):
        assert model.dense(items) == pytest.approx(truth(items), rel=1e-9)
    # dense drift no longer contaminates the fetch estimate: these
    # samples carried no fetched rows, so the fetch leg trusts its
    # calibration until a fetch-carrying batch disagrees with it
    assert model.fetch_correction == 1.0
    assert model.fetch_s == pytest.approx(1e-3)
    # noisy ratios converge to the mean ratio, and keep tracking drift
    noisy = OnlineLatencyModel(offline, alpha=0.25)
    for i in range(60):
        noisy.observe(32, 0, (1.5 if i % 2 else 2.5) * offline(32))
    assert noisy.correction == pytest.approx(2.0, abs=0.3)


def test_online_latency_model_fetch_only_drift():
    """Satellite: only `embed_fetch_s` drifts (a degraded memory bus /
    shard link — the dense curve is still accurate). The fetch
    correction converges onto the true per-row cost and the dense
    correction stays at 1.0; predictions for fetch-heavy batches come
    back to truth while pure dense predictions never move."""
    offline = LatencyModel.analytic(0.01, 1e-4)
    fetch_cal, fetch_true = 1e-4, 3e-4  # 3x drift on the fetch leg only
    model = OnlineLatencyModel(offline, embed_fetch_s=fetch_cal, alpha=0.25)
    spec = ReplicaSpec("m", offline, embed_fetch_s=fetch_cal,
                       true_embed_fetch_s=fetch_true)
    for items, rows in ((32, 64), (128, 256), (64, 512)) * 6:
        model.observe(items, rows, spec.service_time(items, rows))
    assert model.correction == 1.0  # every sample carried fetched rows
    assert model.fetch_correction == pytest.approx(3.0, rel=1e-6)
    assert model.fetch_s == pytest.approx(fetch_true, rel=1e-6)
    assert model.dense(100) == pytest.approx(offline(100))
    # the decomposed MissProfile path attributes the same way: transit
    # seconds are known exactly and subtracted before the residual
    prof_model = OnlineLatencyModel(offline, embed_fetch_s=fetch_cal, alpha=0.25)
    prof = MissProfile(l2_hits=5, local_rows=100, remote_rows=60,
                       transit_s=0.004)
    for _ in range(12):
        prof_model.observe(32, prof, spec.service_time(32, prof))
    assert prof_model.correction == 1.0
    assert prof_model.fetch_correction == pytest.approx(3.0, rel=1e-6)


def test_batch_size_controller_narrow_widen_clamp():
    cfg = ControlConfig(min_batch_items=64, max_batch_items=1024,
                        widen=2.0, narrow=0.5, headroom=0.5)
    c = BatchSizeController(cfg, initial=256)
    assert c.cap == 256
    assert c.tick(p99=1.0, slo_s=0.1) == 128  # breach narrows
    assert c.tick(p99=1.0, slo_s=0.1) == 64
    assert c.tick(p99=1.0, slo_s=0.1) == 64  # clamped at the floor
    assert c.tick(p99=0.07, slo_s=0.1) == 64  # in the deadband: hold
    assert c.tick(p99=0.0, slo_s=0.1) == 64  # no signal: hold
    assert c.tick(p99=0.01, slo_s=0.1) == 128  # headroom widens
    for _ in range(10):
        c.tick(p99=0.01, slo_s=0.1)
    assert c.cap == 1024  # clamped at the ceiling
    # an uncapped pool starts the controller at the clamp ceiling
    assert BatchSizeController(cfg, initial=None).cap == 1024
    # a pool configured TIGHTER than the controller's default floor keeps
    # its own cap as the floor — adaptation never silently raises it
    tight = BatchSizeController(cfg, initial=8)
    assert tight.cap == 8
    assert tight.tick(p99=1.0, slo_s=0.1) == 8


def test_adaptive_cap_binds_batch_splits():
    """The controller's LIVE cap — not the static config — closes and
    splits batches: after a breach narrows the cap, dispatched batches
    respect the narrowed budget."""
    loop = EventLoop()
    pool = ReplicaPool(
        "p", _spec("m", 0.005, 1e-4),
        PoolConfig(max_batch=64, max_batch_items=256, max_wait_s=0.01,
                   n_replicas=2, autoscale=False, priority_bypass=False),
        loop, slo_s=0.1,
        control_cfg=ControlConfig(online_latency=False, adapt_batch=True,
                                  min_batch_items=32, narrow=0.5))
    batches = []
    orig = pool._dispatch
    pool._dispatch = lambda now, take: (batches.append(sum(r.cost for r in take)),
                                        orig(now, take))
    pool.controller.tick(1.0, 0.1)  # breach: 256 -> 128
    assert pool.item_cap() == 128
    for i in range(16):
        loop.push(0.001 * i, "arrive", Request(i, 0.001 * i, "tier0", cost=16))
    loop.on("arrive", lambda now, r: pool.submit(now, r))
    loop.run()
    assert sum(batches) == 16 * 16
    assert max(batches) <= 128  # the narrowed cap, not the configured 256


def _control_system(router, *, drift=False, control=True, **kw):
    """Twin pools (same TRUE curve, so both compete for every request)
    with the full control plane on; the "drifted" pool's offline
    calibration optionally claims 2x faster than its true curve."""
    truth = LatencyModel.analytic(0.02, 1e-3)
    drifted_spec = ReplicaSpec(
        "drifted",
        LatencyModel.analytic(0.01, 5e-4) if drift else truth,
        cold_start_s=5.0, warm_start_s=0.2,
        true_latency=truth if drift else None)
    ctl = ControlConfig() if control else None
    pcfg = lambda: PoolConfig(n_replicas=2, max_batch_items=256,
                              autoscale=False, priority_bypass=False)
    pools = {
        "accurate": PoolSpec(ReplicaSpec("accurate", truth, cold_start_s=5.0,
                                         warm_start_s=0.2),
                             pcfg(), control=ctl),
        "drifted": PoolSpec(drifted_spec, pcfg(), control=ctl),
    }
    return ServingSystem(pools, router, **kw)


@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_request_conservation_with_adaptive_control(policy):
    """Conservation (arrived == completed + rejected + in_queue, queues
    drained) holds for every router with online latency learning AND
    adaptive batch sizing live."""
    kw = {"seed": 5} if policy == "power_of_two" else {}
    sys_ = _control_system(
        make_router(policy, **kw), drift=True,
        tiers={"tier0": TierPolicy(300, 30), "tier1": TierPolicy(300, 30)},
        slo_p99_s=0.15)
    arr = poisson_arrivals(SPIKE, 30.0, seed=52)
    res = sys_.run(arr, until=30.0)
    assert res["arrived"] == len(arr)
    assert res["arrived"] == res["completed"] + res["rejected"] + res["in_queue"]
    assert res["in_queue"] == 0
    assert sum(p["completed"] for p in res["pools"].values()) == res["completed"]
    assert res["control"]["online_pools"] == 2
    assert res["control"]["samples"] > 0


def test_adaptive_control_deterministic_replay():
    """Two identical runs with the full control plane adapting (learned
    corrections, moving batch caps) produce bit-identical timelines,
    traces and summaries."""
    runs, timelines = [], []
    for _ in range(2):
        sys_ = _control_system(make_router("cost_model"), drift=True,
                               slo_p99_s=0.15)
        arr = poisson_arrivals(SPIKE, 20.0, seed=53)
        runs.append(sys_.run(arr, until=20.0))
        timelines.append({r.rid: dict(r.timeline) for r in arr})
    assert runs[0]["p99"] == runs[1]["p99"]
    assert runs[0]["completed"] == runs[1]["completed"]
    assert timelines[0] == timelines[1]
    for name in ("accurate", "drifted"):
        a, b = runs[0]["pools"][name], runs[1]["pools"][name]
        assert a["trace"]["max_batch_items"] == b["trace"]["max_batch_items"]
        assert a["trace"]["latency_corr"] == b["trace"]["latency_corr"]
        assert a["control"] == b["control"]


def test_online_model_recovers_miscalibrated_system():
    """System-level convergence: under cost-model routing, the drifted
    pool's learned correction converges onto the 2x mis-calibration
    while the accurate twin stays at ~1.0 (the p99-recovery claim at a
    tuned operating point is asserted by bench_serving experiment 7)."""
    res = {}
    for control in (False, True):
        sys_ = _control_system(make_router("cost_model"), drift=True,
                               control=control, slo_p99_s=0.5,
                               adaptive_shedding=False)
        arr = poisson_arrivals(lambda t: 45.0, 20.0, seed=54,
                               priority_frac=0.0, cost=64)
        res[control] = sys_.run(arr, until=20.0)
    ctl = res[True]["pools"]["drifted"]["control"]
    assert ctl["samples"] > 10
    assert ctl["latency_correction"] == pytest.approx(2.0, abs=0.2)
    acc = res[True]["pools"]["accurate"]["control"]
    assert acc["latency_correction"] == pytest.approx(1.0, abs=0.1)
    # the static run keeps trusting the stale spec (identity correction)
    assert res[False]["pools"]["drifted"]["control"]["latency_correction"] == 1.0
    # the rollup sees the fleet's learned state
    roll = res[True]["control"]
    assert roll["online_pools"] == roll["adaptive_batch_pools"] == 2
    assert 1.0 < roll["mean_latency_correction"] < 2.0


def test_fleet_control_rollup_identity_when_uncontrolled():
    assert fleet_control_rollup([]) == {
        "online_pools": 0, "adaptive_batch_pools": 0, "samples": 0,
        "mean_latency_correction": 1.0, "mean_fetch_correction": 1.0,
        "by_platform": {}}
    # the mean is sample-weighted (a one-sample pool cannot dilute a
    # heavily observed drifted one) and the output keys round-trip as
    # input, which is how federated_rollup reuses the helper per cell
    roll = fleet_control_rollup([
        {"online_latency": True, "adaptive_batch": False,
         "latency_correction": 2.0, "fetch_correction": 3.0, "samples": 99},
        {"online_latency": True, "adaptive_batch": True,
         "latency_correction": 1.0, "samples": 1},
    ])
    assert roll["online_pools"] == 2 and roll["adaptive_batch_pools"] == 1
    assert roll["mean_latency_correction"] == pytest.approx(1.99)
    assert roll["mean_fetch_correction"] == pytest.approx(2.98)
    assert fleet_control_rollup([roll]) == roll
    sys_ = _hetero_system(make_router("least_loaded"))
    arr = poisson_arrivals(lambda t: 100.0, 4.0, seed=55)
    res = sys_.run(arr, until=6.0)
    assert res["control"]["online_pools"] == 0
    assert res["control"]["mean_latency_correction"] == 1.0


def test_fleet_cache_rollup_edge_cases():
    """Empty input, all-zero pools, and the round-trip property the
    docstring promises: output keys are themselves accepted as input,
    which is how federated_rollup feeds cell cache blocks back through."""
    empty = fleet_cache_rollup([])
    assert empty["hits"] == empty["misses"] == empty["staleness"] == 0
    assert empty["hit_rate"] == 0.0 and empty["l2_hit_rate"] == 0.0
    # uncached pools contribute all-zero summaries without skewing rates
    zero = {"hits": 0, "misses": 0, "evictions": 0, "result_hits": 0}
    assert fleet_cache_rollup([zero, zero, zero]) == empty
    mixed = fleet_cache_rollup([
        {"hits": 30, "misses": 10, "evictions": 2, "result_hits": 5,
         "staleness": 4, "invalidated": 7},
        zero,
        {"hits": 10, "misses": 30, "evictions": 1, "result_hits": 0,
         "l2_hits": 9, "l2_misses": 3, "local_fetches": 2,
         "remote_fetches": 1, "transit_s": 0.25},
    ])
    assert mixed["hits"] == 40 and mixed["misses"] == 40
    assert mixed["hit_rate"] == pytest.approx(0.5)
    assert mixed["l2_hit_rate"] == pytest.approx(0.75)
    assert mixed["staleness"] == 4 and mixed["invalidated"] == 7
    assert mixed["remote_fetches"] == 1 and mixed["transit_s"] == 0.25
    # round-trip: rollup-of-rollups re-sums counters, recomputes rates
    assert fleet_cache_rollup([mixed]) == mixed
    both = fleet_cache_rollup([mixed, mixed])
    assert both["hits"] == 80 and both["hit_rate"] == pytest.approx(0.5)
    assert both["transit_s"] == pytest.approx(0.5)
    assert fleet_cache_rollup([both]) == both


def test_windowed_rows_per_item_forgets_old_mix():
    """Regression for the lifetime average: after the traffic mix shifts
    from 16 ids/item to 2 ids/item, the windowed estimator tracks the
    new mix instead of being dragged by everything ever dispatched."""
    loop = EventLoop()
    spec = dataclasses.replace(_spec("m", 0.005, 1e-4), embed_fetch_s=1e-4)
    pool = ReplicaPool("p", spec,
                       PoolConfig(n_replicas=2, autoscale=False,
                                  priority_bypass=False, max_batch=1),
                       loop, slo_s=1.0)
    t = 0.0
    for i in range(50):  # old mix: 16 ids per 1-item request
        pool.submit(t, Request(i, t, "tier0", cost=1, ids=tuple(range(16))))
        t += 0.05
        loop.run()
    for i in range(50, 80):  # new mix: 2 ids per request
        pool.submit(t, Request(i, t, "tier0", cost=1, ids=(1, 2)))
        t += 0.05
        loop.run()
    rows_per_item = pool._rows_per_item.value
    assert rows_per_item == pytest.approx(2.0, abs=0.05)  # lifetime avg ~10.75
    # and the miss-cost prediction follows (no cache: every row fetches)
    assert pool.predicted_miss_cost(10) == pytest.approx(
        rows_per_item * 10 * 1e-4)


# ---------------------------------------------------------------------------
# heterogeneous platform classes + query-size-aware routing
# ---------------------------------------------------------------------------


def test_platform_family_constructors_curve_shapes():
    """cpu_like / accelerator_like encode the DeepRecSys curve shapes:
    CPU wins pointwise, the accelerator wins wide ranking batches, and
    the curves cross once in between (~30 items at the defaults)."""
    cpu = ReplicaSpec.cpu_like("v")
    acc = ReplicaSpec.accelerator_like("v")
    assert cpu.platform == "cpu" and acc.platform == "accelerator"
    assert _spec().platform == "generic"  # plain construction untagged
    assert cpu.latency(1) < acc.latency(1)
    assert cpu.latency(512) > acc.latency(512)
    cross = next(n for n in range(1, 513) if acc.latency(n) <= cpu.latency(n))
    assert 16 <= cross <= 48
    # curve + start costs are overridable without losing the class tag
    fast = ReplicaSpec.accelerator_like("v2", base_s=0.01, warm_start_s=0.02)
    assert fast.platform == "accelerator"
    assert fast.latency(1) == pytest.approx(0.01 + 3e-5)
    assert fast.warm_start_s == 0.02
    # and a fully calibrated curve passes straight through **kw
    lut = LatencyModel.analytic(0.03, 1e-5)
    assert ReplicaSpec.cpu_like("v3", latency=lut).latency is lut


def test_pool_config_for_platform_defaults_and_overrides():
    cpu = PoolConfig.for_platform("cpu")
    acc = PoolConfig.for_platform("accelerator")
    # CPU-class closes small batches fast; accelerator batches wide
    assert (cpu.max_batch, cpu.max_batch_items, cpu.max_wait_s) == (16, 64, 0.002)
    assert (acc.max_batch, acc.max_batch_items, acc.max_wait_s) == (64, 2048, 0.010)
    # unknown platform -> generic PoolConfig defaults
    assert PoolConfig.for_platform("tpu-v9") == PoolConfig()
    # any field overrides its class default
    tuned = PoolConfig.for_platform("accelerator", n_replicas=5, max_wait_s=0.02)
    assert tuned.n_replicas == 5 and tuned.max_wait_s == 0.02
    assert tuned.max_batch_items == 2048


def test_bimodal_cost_mix_shapes_and_validation():
    from repro.data.synthetic import bimodal_cost_mix

    assert bimodal_cost_mix() == ((1, 0.9), (512, 0.1))
    assert bimodal_cost_mix(rank_frac=0.0) == ((1, 1.0),)
    assert bimodal_cost_mix(rank_frac=1.0) == ((512, 1.0),)
    spread = bimodal_cost_mix(spread=0.25, modes=3)
    costs = [c for c, _ in spread]
    assert costs == [1, 384, 512, 640]
    assert sum(w for _, w in spread) == pytest.approx(1.0)
    # binomial-shaped: the central ranking size dominates the shoulders
    weights = {c: w for c, w in spread}
    assert weights[512] > weights[384] == weights[640]
    assert bimodal_cost_mix(spread=0.25, modes=3) == spread  # deterministic
    with pytest.raises(ValueError):
        bimodal_cost_mix(rank_frac=1.5)


def _platform_fleet(**kw):
    pools = {
        "cpu": PoolSpec(ReplicaSpec.cpu_like("base"),
                        PoolConfig.for_platform("cpu", n_replicas=2,
                                                autoscale=False)),
        "acc": PoolSpec(ReplicaSpec.accelerator_like("base"),
                        PoolConfig.for_platform("accelerator", n_replicas=2,
                                                autoscale=False)),
    }
    return ServingSystem(pools, kw.pop("router", make_router("size_aware")),
                         slo_p99_s=0.2, **kw)


def test_size_aware_routes_by_class_and_blind_router_cannot():
    """On an idle mixed fleet the size-aware router sends a ranking
    batch to the accelerator class and a pointwise probe to the CPU
    class; the size-blind ablation prices every arrival at cost 1 and
    sends the ranking batch to the CPU pool's cheaper pointwise quote —
    the exact admission mistake experiment 9 measures."""
    sys_ = _platform_fleet()
    pools = list(sys_.pools.values())
    rank = Request(0, 0.0, "tier0", cost=512)
    point = Request(1, 0.0, "tier0", cost=1)
    assert sys_.router.select_pool(rank, pools, 0.0).name == "acc"
    assert sys_.router.select_pool(point, pools, 0.0).name == "cpu"
    blind = make_router("cost_model_blind")
    assert blind.select_pool(rank, pools, 0.0).name == "cpu"
    assert blind.select_pool(point, pools, 0.0).name == "cpu"
    # an explicit threshold overrides the idle-curve comparison
    thresh = make_router("size_aware", size_threshold=8)
    assert thresh.select_pool(Request(2, 0.0, "tier0", cost=8),
                              pools, 0.0).name == "acc"
    assert thresh.select_pool(Request(3, 0.0, "tier0", cost=7),
                              pools, 0.0).name == "cpu"


def test_size_aware_falls_back_without_both_classes():
    """A fleet missing either platform class degrades to plain
    cost-model routing: same pool choice, request for request."""
    homogeneous = {
        "a": PoolSpec(_spec("m", 0.02, 1e-3), PoolConfig(n_replicas=2)),
        "b": PoolSpec(_spec("m", 0.004, 5e-5), PoolConfig(n_replicas=2)),
    }
    aware = ServingSystem(dict(homogeneous), make_router("size_aware"))
    ref = ServingSystem(dict(homogeneous), make_router("cost_model"))
    for cost in (1, 8, 64, 512):
        req = Request(cost, 0.0, "tier0", cost=cost)
        assert (aware.router.select_pool(req, list(aware.pools.values()), 0.0).name
                == ref.router.select_pool(req, list(ref.pools.values()), 0.0).name)


def test_heterogeneous_fleet_replays_bit_exact():
    """The mixed CPU/accelerator fleet under a bimodal size mix is as
    deterministic as the homogeneous ones: a fresh build over the same
    seed reproduces every summary number exactly."""
    from repro.data.synthetic import bimodal_cost_mix

    def one():
        sys_ = _platform_fleet()
        arr = poisson_arrivals(lambda t: 300.0, 5.0, seed=7,
                               cost_mix=bimodal_cost_mix(rank_frac=0.05))
        return sys_.run(arr, until=5.0)

    a, b = one(), one()
    for key in ("p50", "p99", "mean_latency", "throughput",
                "completed", "rejected", "slo_attainment"):
        assert a[key] == b[key], key
    assert {n: p["completed"] for n, p in a["pools"].items()} \
        == {n: p["completed"] for n, p in b["pools"].items()}
    # and the summary carries the class tag per pool
    assert a["pools"]["cpu"]["platform"] == "cpu"
    assert a["pools"]["acc"]["platform"] == "accelerator"


def test_fleet_control_rollup_keeps_platform_classes_apart():
    """Per-class corrections never blend across classes: a drifted CPU
    fleet shows up under by_platform["cpu"] with the accelerator mean
    untouched, while the top-level mean stays the all-class blend —
    and a cell rollup re-fed through the rollup merges class-wise."""
    cpu = {"online_latency": True, "adaptive_batch": False, "samples": 90,
           "latency_correction": 2.0, "fetch_correction": 1.5,
           "platform": "cpu"}
    acc = {"online_latency": True, "adaptive_batch": True, "samples": 10,
           "latency_correction": 1.0, "fetch_correction": 1.0,
           "platform": "accelerator"}
    out = fleet_control_rollup([cpu, acc])
    assert out["online_pools"] == 2 and out["adaptive_batch_pools"] == 1
    assert out["samples"] == 100
    assert out["mean_latency_correction"] == pytest.approx(1.9)
    by = out["by_platform"]
    assert by["cpu"]["mean_latency_correction"] == pytest.approx(2.0)
    assert by["cpu"]["mean_fetch_correction"] == pytest.approx(1.5)
    assert by["accelerator"]["mean_latency_correction"] == pytest.approx(1.0)
    # cell-level re-entry: two cells' rollups merge per class, sample-
    # weighted, so a one-sample cell cannot dilute a drifted one
    cell2 = fleet_control_rollup([
        {"online_latency": True, "adaptive_batch": False, "samples": 10,
         "latency_correction": 4.0, "fetch_correction": 1.0,
         "platform": "cpu"}])
    fleet = fleet_control_rollup([out, cell2])
    assert fleet["samples"] == 110
    assert fleet["by_platform"]["cpu"]["samples"] == 100
    assert fleet["by_platform"]["cpu"]["mean_latency_correction"] \
        == pytest.approx((90 * 2.0 + 10 * 4.0) / 100)
    assert fleet["by_platform"]["accelerator"]["mean_latency_correction"] \
        == pytest.approx(1.0)
    # a legacy summary with no platform tag lands under "generic"
    legacy = fleet_control_rollup([{"online_latency": False,
                                    "adaptive_batch": False, "samples": 5,
                                    "latency_correction": 1.2,
                                    "fetch_correction": 1.0}])
    assert set(legacy["by_platform"]) == {"generic"}


# ---------------------------------------------------------------------------
# service_time / sustainable_rate edge-case regressions
# ---------------------------------------------------------------------------


def test_service_time_missprofile_transit_without_fetch_rows():
    """Regression: a batch whose every missed row was absorbed by the
    shared L2 still pays the recorded inter-cell transit — zero
    fetch_rows must not short-circuit the transit term (and zero of
    BOTH must collapse to the pure dense time, same as the int path)."""
    spec = dataclasses.replace(_spec("m", 0.01, 1e-4), embed_fetch_s=1e-3)
    l2_only = MissProfile(l2_hits=8, transit_s=0.004)
    assert l2_only.fetch_rows == 0 and l2_only.total_rows == 8
    assert spec.service_time(4, l2_only) \
        == pytest.approx(spec.latency(4) + 0.004)
    assert spec.service_time(4, MissProfile()) == spec.latency(4)
    assert spec.service_time(4, 0) == spec.latency(4)


def test_service_time_fetch_drift_with_accurate_dense_curve():
    """Regression: when only the fetch leg drifts (true_embed_fetch_s
    set, true_latency left None) the service clock charges the OFFLINE
    dense curve plus the TRUE per-row cost — the dense truth must not
    default to zero or to the drifted fetch."""
    offline = LatencyModel.analytic(0.01, 1e-4)
    spec = ReplicaSpec("m", offline, embed_fetch_s=1e-4,
                       true_embed_fetch_s=3e-4)
    assert spec.service_time(4, 10) == pytest.approx(offline(4) + 10 * 3e-4)
    prof = MissProfile(l2_hits=2, local_rows=6, remote_rows=4,
                       transit_s=0.002)
    assert spec.service_time(4, prof) \
        == pytest.approx(offline(4) + 10 * 3e-4 + 0.002)
    # sustainable_rate is the PLANNING view: it prices embedding traffic
    # at the calibrated fetch cost, not the (unknowable) drifted truth
    w, b1 = 0.02, offline(1)
    marginal = (offline(32) - b1) / 31.0
    expect = (2 * w - b1) / (w * (marginal + 8 * 1e-4))
    assert sustainable_rate(spec, 2, w, ids_per_request=8) \
        == pytest.approx(expect)


def test_sustainable_rate_hit_rate_and_zero_fetch_edges():
    """Edges around the miss-fetch term: a FULL hit rate on a flat
    curve removes the only finite term (back to the unbounded / 1 rps
    branch, not a ZeroDivisionError), and ids_per_request is inert when
    the spec has no per-row fetch cost."""
    flat = dataclasses.replace(
        ReplicaSpec("m", LatencyModel.analytic(0.01, 0.0)),
        embed_fetch_s=1e-3)
    assert np.isfinite(sustainable_rate(flat, 2, 0.02, ids_per_request=8))
    assert sustainable_rate(flat, 2, 0.02, ids_per_request=8,
                            hit_rate=1.0) == float("inf")
    # base exceeds the window at full hit rate: the documented floor
    assert sustainable_rate(flat, 1, 0.005, ids_per_request=8,
                            hit_rate=1.0) == 1.0
    # warmer cache -> strictly higher equilibrium on the way there
    cold = sustainable_rate(flat, 2, 0.02, ids_per_request=8, hit_rate=0.0)
    warm = sustainable_rate(flat, 2, 0.02, ids_per_request=8, hit_rate=0.9)
    assert warm > cold
    # zero fetch cost: embedding traffic cannot change the rate
    sloped = _spec("m", 0.005, 1e-4)  # embed_fetch_s defaults to 0
    assert sloped.embed_fetch_s == 0.0
    assert sustainable_rate(sloped, 2, 0.02, ids_per_request=100) \
        == sustainable_rate(sloped, 2, 0.02)
