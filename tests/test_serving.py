"""Elastic serving engine tests (paper §IV.B behaviours)."""
import numpy as np
import pytest

from repro.core.serving.autoscaler import AutoScaler, ScalerConfig
from repro.core.serving.engine import ElasticEngine, EngineConfig, Request, poisson_arrivals
from repro.core.serving.rate_limiter import HybridRateLimiter, TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec


def _spec(base=0.02, per=0.001):
    return ReplicaSpec("m", LatencyModel.analytic(base, per),
                       cold_start_s=5.0, warm_start_s=0.2)


SPIKE = lambda t: 100.0 if t < 15 else (900.0 if t < 45 else 150.0)


def test_all_served_under_capacity():
    eng = ElasticEngine(_spec(0.002, 1e-5), EngineConfig(n_replicas=2, autoscale=False))
    arr = poisson_arrivals(lambda t: 100.0, 10.0, seed=1)
    res = eng.run(arr, until=12.0)
    assert res["rejected"] == 0
    assert res["completed"] == len(arr)
    assert res["p99"] < 0.05


def test_autoscaler_rescues_overload():
    arr = poisson_arrivals(SPIKE, 70.0, seed=0)
    res = {}
    for auto in (False, True):
        eng = ElasticEngine(
            _spec(), EngineConfig(n_replicas=2, autoscale=auto, slo_p99_s=0.2, max_batch=32),
            tiers={"tier0": TierPolicy(1200, 100), "tier1": TierPolicy(1200, 100)},
        )
        res[auto] = eng.run(arr, until=70.0)
    assert res[True]["p50"] < 0.1 * res[False]["p50"]  # collapse vs elastic
    assert max(res[True]["trace"]["replicas"]) > 2  # actually scaled up
    assert res[True]["final_replicas"] <= 3  # and back down after the spike


def test_priority_bypass_beats_batching():
    spec = _spec(0.02, 0.001)
    arr = poisson_arrivals(lambda t: 400.0, 20.0, seed=2, priority_frac=0.05)
    eng = ElasticEngine(spec, EngineConfig(n_replicas=8, autoscale=False,
                                           max_batch=64, max_wait_s=0.02))
    # instrument: track latencies by priority
    pri, nor = [], []
    orig_record = eng.monitor.record
    lookup = {r.rid: r.priority for r in arr}
    def record(finish, latency, _orig=orig_record):
        _orig(finish, latency)
    eng.monitor.record = record
    res = eng.run(arr, until=20.0)
    assert res["completed"] == len(arr) - res["rejected"]
    # bypass requests never wait max_wait: engine-level check is that p50
    # stays below batch wait + service
    assert res["p50"] < 0.06


def test_rate_limiter_sheds_low_tier_first():
    rl = HybridRateLimiter({"tier0": TierPolicy(100, 10), "tier1": TierPolicy(100, 10)})
    rl.adapt(p99=1.0, slo=0.1)  # breach -> shed one level
    assert rl.shed_level == 1
    assert rl.admit(0.1, "tier0") is True
    assert rl.admit(0.1, "tier1") is False  # lowest tier shed
    rl.adapt(p99=0.01, slo=0.1)
    assert rl.shed_level == 0


def test_token_bucket_rate():
    rl = HybridRateLimiter({"tier0": TierPolicy(rate=10.0, burst=5.0)})
    admitted = sum(rl.admit(0.0, "tier0") for _ in range(10))
    assert admitted == 5  # burst only
    admitted_later = sum(rl.admit(2.0, "tier0") for _ in range(10))
    assert admitted_later == 5  # refilled to burst cap


def test_warm_pool_faster_than_cold():
    sc = AutoScaler(ScalerConfig(warm_pool_size=1))
    assert sc.take_start_delay(0.2, 5.0) == 0.2  # first from warm pool
    assert sc.take_start_delay(0.2, 5.0) == 5.0  # pool exhausted -> cold


def test_simulation_deterministic():
    arr = poisson_arrivals(SPIKE, 30.0, seed=7)
    runs = []
    for _ in range(2):
        eng = ElasticEngine(_spec(), EngineConfig(n_replicas=2, autoscale=True))
        runs.append(eng.run(arr, until=30.0))
    assert runs[0]["p99"] == runs[1]["p99"]
    assert runs[0]["completed"] == runs[1]["completed"]


def test_latency_model_interpolation():
    lm = LatencyModel(np.array([1.0, 100.0]), np.array([0.01, 0.1]))
    assert abs(lm(1) - 0.01) < 1e-9
    assert 0.01 < lm(50) < 0.1
