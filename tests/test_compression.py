"""Property + unit tests for the paper's C1/C4/C5 machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, settings, strategies as st

from repro.core import lightweight, pruning, quantization


# ---------------------------------------------------------------------------
# C4 pruning (Formulas 5-7)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(
    p=st.floats(0.05, 0.9),
    n=st.integers(8, 64),
    m=st.integers(8, 64),
    seed=st.integers(0, 10_000),
)
def test_prune_ratio_property(p, n, m, seed):
    """Formula 5: the realized sparsity matches the target ratio."""
    w = jax.random.normal(jax.random.key(seed), (n, m))
    mask = pruning.prune_mask(w, p)
    realized = 1.0 - float(jnp.mean(mask))
    assert abs(realized - p) < 0.12  # quantile granularity on small tensors
    # Formula 6: the mask keeps exactly the large-magnitude entries
    theta = pruning.magnitude_threshold(w, p)
    np.testing.assert_array_equal(mask, (jnp.abs(w) >= theta).astype(w.dtype))


def test_iterative_prune_composes():
    """Formula 7: K tightening rounds reach the target on survivors."""
    params = {"layer": {"w0": jax.random.normal(jax.random.key(0), (64, 64))}}
    tree = params
    for r in pruning.prune_schedule(0.4, 3):
        tree = pruning.prune_tree(tree, r)
    s = pruning.sparsity(tree)
    assert 0.33 < s < 0.47, s
    # masks are binary and only ever shrink
    mask = tree["layer"]["w0"]["mask"]
    assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}


def test_block_prune_structure():
    w = jax.random.normal(jax.random.key(0), (256, 256))
    mask = pruning.block_prune_mask(w, 0.5, block=128)
    blocks = np.asarray(mask).reshape(2, 128, 2, 128)
    for i in range(2):
        for j in range(2):
            vals = np.unique(blocks[i, :, j, :])
            assert len(vals) == 1  # whole block kept or dropped


def test_prune_skips_tables():
    params = {"tables": {"item": jnp.ones((50, 8))}, "tower_w0": jnp.ones((8, 8))}
    out = pruning.prune_tree(params, 0.5)
    assert isinstance(out["tables"]["item"], jax.Array)
    assert isinstance(out["tower_w0"], dict) and "mask" in out["tower_w0"]


# ---------------------------------------------------------------------------
# C5 quantization (Formulas 8-9)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([4, 8]))
def test_fake_quant_error_bound(seed, bits):
    """Formula 9 error is bounded by s/2 inside the clip range."""
    w = jax.random.normal(jax.random.key(seed), (32, 32))
    s = float(quantization.dynamic_range_step(w, bits))
    wq = quantization.fake_quant(w, bits)
    assert float(jnp.abs(wq - w).max()) <= s / 2 + 1e-6


def test_int8_weight_roundtrip():
    w = jax.random.normal(jax.random.key(0), (64, 32))
    rep = quantization.quantize_weight(w)
    assert rep["q"].dtype == jnp.int8
    err = jnp.abs(quantization.dequantize(rep) - w)
    per_col_scale = jnp.max(jnp.abs(w), axis=0) / 127.0
    assert (err <= per_col_scale[None, :] * 0.51 + 1e-6).all()


def test_table_quantization_per_row():
    t = jax.random.normal(jax.random.key(0), (100, 16)) * jnp.arange(1, 101)[:, None]
    rep = quantization.quantize_table(t)
    deq = quantization.dequantize(rep)
    rel = jnp.abs(deq - t) / jnp.maximum(jnp.abs(t).max(axis=1, keepdims=True), 1e-9)
    assert float(rel.max()) < 0.01  # per-row scales keep big rows accurate


def test_ste_gradient_is_straight_through():
    w = jax.random.normal(jax.random.key(0), (16, 16))
    g = jax.grad(lambda w_: jnp.sum(quantization.ste_quant(w_) * 3.0))(w)
    np.testing.assert_allclose(g, 3.0 * jnp.ones_like(w), rtol=1e-6)


def test_quantize_tree_combined_reps():
    params = {
        "tables": {"item": jnp.ones((32, 8))},
        "tower_w0": {"w": jax.random.normal(jax.random.key(0), (16, 16)),
                     "mask": (jax.random.uniform(jax.random.key(1), (16, 16)) > 0.4).astype(jnp.float32)},
    }
    q = quantization.quantize_tree(params)
    assert "q" in q["tables"]["item"] and q["tables"]["item"]["s"].shape == (32,)
    assert {"q", "s", "mask"} <= set(q["tower_w0"])  # pruned+quantized rep


# ---------------------------------------------------------------------------
# C1 lightweight representations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rep_kind", ["dense", "masked", "lowrank", "grouped", "int8"])
def test_linear_dispatch_consistency(rep_kind):
    w = jax.random.normal(jax.random.key(0), (32, 64))
    x = jax.random.normal(jax.random.key(1), (4, 32))
    if rep_kind == "dense":
        rep = w
    elif rep_kind == "masked":
        rep = {"w": w, "mask": (jax.random.uniform(jax.random.key(2), w.shape) > 0.3).astype(w.dtype)}
    elif rep_kind == "lowrank":
        rep = lightweight.low_rank_factorize(w, rank=32)  # full rank -> exact
    elif rep_kind == "grouped":
        rep = lightweight.to_grouped(w, 4)
    else:
        from repro.core.quantization import quantize_weight

        rep = quantize_weight(w)
    out = lightweight.linear(rep, x)
    ref = x @ lightweight.weight_view(rep)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_low_rank_truncation_error_decreases():
    w = jax.random.normal(jax.random.key(0), (64, 64))
    errs = []
    for r in (4, 16, 48):
        rep = lightweight.low_rank_factorize(w, r)
        errs.append(float(jnp.linalg.norm(lightweight.weight_view(rep) - w)))
    assert errs[0] > errs[1] > errs[2]


def test_nbytes_accounting():
    w = jnp.ones((100, 100))
    assert lightweight.nbytes(w) == 40_000
    masked = {"w": w, "mask": jnp.concatenate([jnp.ones((50, 100)), jnp.zeros((50, 100))])}
    assert lightweight.nbytes(masked) == 20_000  # survivors x 4B
    from repro.core.quantization import quantize_weight

    assert lightweight.nbytes(quantize_weight(w)) == 100 * 100 + 100 * 4
