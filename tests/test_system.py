"""End-to-end system behaviour: train -> compress (5-variant ladder) ->
accuracy retention -> serve through the elastic engine. This is the paper's
whole pipeline at smoke scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_recsys
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.data.metrics import auc, ranking_metrics
from repro.data.synthetic import TaobaoWorld, taobao_batches, taobao_eval_candidates
from repro.models.common import init_params
from repro.models.recsys import api
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


@pytest.fixture(scope="module")
def trained_teacher(rec_rules):
    cfg = reduced_recsys("taobao_ssa")
    world = TaobaoWorld(1000, 1000, 1000)
    params = init_params(api.param_defs(cfg), jax.random.key(0))
    opt = get_optimizer("adamw", 3e-3)
    step = jax.jit(make_train_step(lambda p, b: api.loss(p, b, cfg, rec_rules), opt))
    state = opt.init(params)
    losses = []
    gen = ( {k: jnp.asarray(v) for k, v in b.items()}
            for b in taobao_batches(cfg, 256, 10_000, world=world, seed=1) )
    for i, b in zip(range(240), gen):
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    return cfg, world, params, losses


def test_training_learns(trained_teacher):
    _, _, _, losses = trained_teacher
    assert losses[-1] < losses[0] - 0.02  # real learning on synthetic signal


def test_model_beats_chance_auc(trained_teacher, rec_rules):
    cfg, world, params, _ = trained_teacher
    b = next(iter(taobao_batches(cfg, 2048, 1, world=world, seed=99)))
    jb = {k: jnp.asarray(v) for k, v in b.items()}
    scores = np.asarray(api.serve(params, jb, cfg, rec_rules))
    assert auc(scores, b["label"]) > 0.6


@pytest.fixture(scope="module")
def ladder(trained_teacher, rec_rules):
    cfg, world, params, _ = trained_teacher

    def batch_fn():
        for b in taobao_batches(cfg, 256, 10_000, world=world, seed=3):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    return run_ladder(
        params, cfg, rec_rules, batch_fn,
        LadderConfig(finetune_steps=8, qat_steps=8, distill_steps=12),
    )


def test_ladder_produces_five_variants(ladder):
    assert set(ladder) == {
        "baseline", "quantized", "pruned", "pruned_quantized", "distilled"
    }


def test_ladder_resource_ordering(ladder):
    """Paper Fig 7: quantized ~4x smaller; pruned ~40% fewer params;
    distilled smallest param count of the dense variants."""
    stats = variant_stats(ladder)
    assert stats["quantized"]["bytes"] < 0.30 * stats["baseline"]["bytes"]
    assert 0.3 < stats["pruned"]["sparsity"] < 0.5
    assert stats["pruned_quantized"]["bytes"] < stats["quantized"]["bytes"]
    assert stats["distilled"]["params"] < stats["baseline"]["params"]


def test_accuracy_retention(ladder, trained_teacher, rec_rules):
    """Paper Fig 6: compressed variants rank nearly as well as baseline."""
    cfg, world, _, _ = trained_teacher
    ev = taobao_eval_candidates(cfg, n_queries=128, n_cand=20, world=world)
    jb = {k: jnp.asarray(v) for k, v in ev["batch"].items()}

    def hr(variant):
        v = ladder[variant]
        s = np.asarray(api.serve(v["params"], jb, v["cfg"], rec_rules))
        m = ranking_metrics(s.reshape(128, 20), ev["pos_idx"], k=5)
        return m["hit_rate"]

    base = hr("baseline")
    assert base > 1.6 * 5 / 20  # well above random hit@5 (measured ~0.59)
    for name in ("quantized", "pruned_quantized", "distilled"):
        assert hr(name) > 0.75 * base, name  # <25% relative degradation


def test_variants_serve_through_engine(ladder, rec_rules, trained_teacher):
    from repro.core.serving.engine import ElasticEngine, EngineConfig, poisson_arrivals
    from repro.core.serving.replica import LatencyModel, ReplicaSpec

    cfg, world, _, _ = trained_teacher
    gen = taobao_batches(cfg, 512, 1, world=world, seed=7)
    batch = {k: jnp.asarray(v) for k, v in next(iter(gen)).items() if k != "label"}
    v = ladder["distilled"]
    jitted = jax.jit(lambda p, b: api.serve(p, b, v["cfg"], rec_rules))
    jax.block_until_ready(jitted(v["params"], batch))  # real executable works
    spec = ReplicaSpec("distilled", LatencyModel.analytic(0.002, 2e-5))
    eng = ElasticEngine(spec, EngineConfig(n_replicas=2, autoscale=False))
    res = eng.run(poisson_arrivals(lambda t: 200.0, 5.0, seed=1), until=5.0)
    assert res["completed"] > 0 and res["p99"] < 0.1
