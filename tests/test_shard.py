"""Sharded embedding tier tests (serving/shard.py and its wiring):
deterministic hashing/placement, hand-checked local/remote/transit fetch
accounting against the RTT matrix, versioned invalidation semantics
(refetch-in-place, staleness with invalidation off), the cell-shared L2
between pools, conservation under every router with the full hierarchy
live, federation-wide accounting, and bit-identical replay of adaptive
sharded runs."""
import pytest

from repro.core.serving.cache import CacheConfig, EmbeddingCache
from repro.core.serving.control import ControlConfig
from repro.core.serving.engine import (
    PoolSpec, Request, ServingSystem, attach_zipf_ids, poisson_arrivals,
)
from repro.core.serving.federation import (
    CellSpec, FederatedSystem, assign_homes,
)
from repro.core.serving.pool import PoolConfig
from repro.core.serving.replica import LatencyModel, MissProfile, ReplicaSpec
from repro.core.serving.router import ROUTERS, make_router
from repro.core.serving.shard import EmbeddingShardService, RttMatrix
from repro.data.synthetic import update_event_stream


def _spec(name="m", base=0.005, per=1e-4, fetch=1e-4):
    return ReplicaSpec(name, LatencyModel.analytic(base, per),
                       cold_start_s=5.0, warm_start_s=0.2,
                       embed_fetch_s=fetch)


# ---------------------------------------------------------------------------
# placement + hashing
# ---------------------------------------------------------------------------


def test_shard_hashing_and_placement_deterministic():
    svc = EmbeddingShardService(8, ("a", "b", "c"))
    again = EmbeddingShardService(8, ("a", "b", "c"))
    for key in range(1000):
        s = svc.shard_of(key)
        assert 0 <= s < 8
        assert s == again.shard_of(key)  # pure function of (key, n_shards)
        assert svc.home(s) == ("a", "b", "c")[s % 3]
    # the Fibonacci hash spreads CONSECUTIVE (hot Zipf) ids: the 16
    # hottest ids must not pile onto one shard
    hot = {svc.shard_of(k) for k in range(16)}
    assert len(hot) >= 4
    # no placement: every shard is homeless -> local everywhere
    flat = EmbeddingShardService(4)
    assert all(flat.home(s) == "" for s in range(4))
    with pytest.raises(ValueError):
        EmbeddingShardService(0)


def test_fetch_accounting_matches_rtt_matrix():
    rtt = RttMatrix(0.010, {("a", "b"): 0.002})
    svc = EmbeddingShardService(4, ("a", "b"), rtt=rtt)
    ids = list(range(64))
    by_shard = {}
    for i in ids:
        by_shard.setdefault(svc.shard_of(i), []).append(i)
    local_expect = sum(
        len(v) for s, v in by_shard.items() if svc.home(s) == "a")
    remote_shards = {s for s in by_shard if svc.home(s) == "b"}
    prof = svc.fetch("a", ids)
    assert prof.local_rows == local_expect
    assert prof.remote_rows == len(ids) - local_expect
    # per-shard fetch batching: ONE rtt per distinct remote shard, not
    # one per row — and the (a, b) pair's own value, not the default
    assert prof.transit_s == pytest.approx(0.002 * len(remote_shards))
    assert prof.fetch_rows == len(ids)
    stats = svc.cell_stats("a")
    assert stats["local_fetches"] == local_expect
    assert stats["remote_fetches"] == prof.remote_rows
    assert stats["transit_s"] == pytest.approx(prof.transit_s)
    assert svc.cell_stats("b") == {
        "local_fetches": 0, "remote_fetches": 0, "transit_s": 0.0}
    assert svc.predicted_transit_per_row("a") == pytest.approx(
        prof.transit_s / len(ids))
    assert svc.predicted_transit_per_row("b") == 0.0
    # front-door / unplaced fetches are local regardless of placement
    flat = EmbeddingShardService(4, ("a", "b"), rtt=rtt)
    assert flat.fetch("", ids).remote_rows == 0


def test_service_time_prices_miss_profile():
    spec = _spec(fetch=2e-4)
    prof = MissProfile(l2_hits=10, local_rows=30, remote_rows=20,
                      transit_s=0.004)
    dense = spec.service_time(8, 0)
    assert spec.service_time(8, prof) == pytest.approx(
        dense + 50 * 2e-4 + 0.004)
    # L2 hits cost nothing at the replica (the L2 probe is the pool's)
    assert spec.service_time(8, MissProfile(l2_hits=99)) == pytest.approx(dense)
    # int miss_rows (pre-shard path) is priced identically to a
    # transit-free all-local profile
    assert spec.service_time(8, 50) == pytest.approx(
        spec.service_time(8, MissProfile(local_rows=50)))


# ---------------------------------------------------------------------------
# versioned invalidation
# ---------------------------------------------------------------------------


def test_publish_invalidates_resident_rows_down_the_hierarchy():
    svc = EmbeddingShardService(4)
    l2 = EmbeddingCache(64)
    l1 = EmbeddingCache(16)
    svc.register_cache(l2)
    svc.register_cache(l1)
    for cache in (l1, l2):
        cache.warm(range(8))
    assert l1.access(3) and l2.access(3)
    svc.publish([3, 4, 99])  # 99 not resident anywhere
    assert svc.version_of(3) == 1 and svc.version_of(99) == 1
    assert svc.invalidated_rows == 4  # ids 3+4 in each of the two caches
    # a dirty hit is re-reported as a miss: the row refetches in place
    h0, m0 = l1.hits, l1.misses
    assert l1.access(3) is False
    assert (l1.hits, l1.misses) == (h0, m0 + 1)
    assert l1.access(3) is True  # refetched at the new version: clean hit
    assert l1.staleness == 0
    # double publish of a non-resident id never double-counts
    svc.publish([99])
    assert svc.version_of(99) == 2
    assert svc.invalidated_rows == 4


def test_staleness_counts_superseded_serves_when_invalidation_off():
    svc = EmbeddingShardService(4, invalidation=False)
    cache = EmbeddingCache(16)
    svc.register_cache(cache)
    cache.warm(range(4))
    svc.publish([0, 1])
    assert svc.invalidated_rows == 0
    for _ in range(3):
        assert cache.access(0) is True  # keeps serving the stale copy
    assert cache.access(2) is True  # never republished: clean
    assert cache.stats()["staleness"] == 3
    assert cache.stats()["invalidated"] == 0


# ---------------------------------------------------------------------------
# the shared L2 between pools
# ---------------------------------------------------------------------------


def _l2_system(shard=None, l2_rows=4096, **kw):
    cache = CacheConfig(64, l2=CacheConfig(l2_rows))
    pools = {
        "pa": PoolSpec(_spec("pa"), PoolConfig(n_replicas=1, autoscale=False,
                                               priority_bypass=False),
                       cache=cache),
        "pb": PoolSpec(_spec("pb"), PoolConfig(n_replicas=1, autoscale=False,
                                               priority_bypass=False),
                       cache=cache),
    }
    return ServingSystem(pools, shard=shard, **kw)


def test_l2_shared_across_pools():
    sys_ = _l2_system(shard=EmbeddingShardService(8))
    pa, pb = sys_.pools["pa"], sys_.pools["pb"]
    ids = tuple(range(32))
    pa.submit(0.0, Request(0, 0.0, "tier0", cost=1, ids=ids))
    sys_.loop.run()
    assert sys_.l2_cache.misses == 32  # pool A's L1 misses warmed the L2
    pb.submit(1.0, Request(1, 1.0, "tier0", cost=1, ids=ids))
    sys_.loop.run()
    # pool B's own L1 is cold, but the CELL-shared L2 already holds every
    # row pool A fetched — no second shard fetch for the same ids
    assert sys_.l2_cache.hits == 32
    assert sys_.shard.cell_stats("")["local_fetches"] == 32
    summary = sys_.summary()
    assert summary["cache"]["l2_hits"] == 32
    assert summary["cache"]["l2_misses"] == 32


def test_pools_must_agree_on_l2_config():
    pools = {
        "pa": PoolSpec(_spec("pa"), cache=CacheConfig(64, l2=CacheConfig(512))),
        "pb": PoolSpec(_spec("pb"), cache=CacheConfig(64, l2=CacheConfig(1024))),
    }
    with pytest.raises(ValueError, match="disagree"):
        ServingSystem(pools)


# ---------------------------------------------------------------------------
# conservation with the full hierarchy live
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router_name", sorted(ROUTERS))
def test_conservation_all_routers_with_shard_l2_invalidation(router_name):
    shard = EmbeddingShardService(8)
    sys_ = _l2_system(shard=shard, router=make_router(router_name))
    arr = attach_zipf_ids(
        poisson_arrivals(lambda t: 250.0, 6.0, seed=3), 4096, 16, seed=3)
    sys_.loop.add_stream(
        "shard_update", update_event_stream(5.0, 6.0, 4096, 16, seed=4))
    res = sys_.run(arr, until=10.0)
    assert res["completed"] > 0
    assert res["arrived"] == res["completed"] + res["rejected"] + res["in_queue"]
    assert res["dropped_events"] == 0
    cache = res["cache"]
    assert cache["hits"] + cache["misses"] > 0
    # every row that fell through both cache levels was fetched exactly
    # once: L2 misses == shard fetches (all local: no placement)
    assert cache["l2_misses"] == cache["local_fetches"]
    assert cache["remote_fetches"] == 0
    assert shard.publishes > 0 and shard.invalidated_rows > 0


def _shard_fed(invalidation=True, control=None, seed=11):
    rtt = {("a", "b"): 0.004}
    shard = EmbeddingShardService(16, ("a", "b"), invalidation=invalidation)
    cache = CacheConfig(128, l2=CacheConfig(1024))
    cfg = PoolConfig(n_replicas=2, autoscale=False, priority_bypass=False)
    cells = {
        name: CellSpec({"p": PoolSpec(_spec(f"p{name}"), cfg, cache=cache,
                                      control=control)})
        for name in ("a", "b")
    }
    fed = FederatedSystem(cells, "sticky", rtt_s=0.004, rtt=rtt, shard=shard)
    arr = attach_zipf_ids(
        poisson_arrivals(lambda t: 300.0, 8.0, seed=seed), 8192, 16, seed=seed)
    assign_homes(arr, {"a": 0.5, "b": 0.5}, seed=seed)
    fed.loop.add_stream(
        "shard_update", update_event_stream(8.0, 8.0, 8192, 32, seed=seed + 1))
    return fed, arr


@pytest.mark.parametrize("invalidation", [True, False])
def test_federation_conservation_with_sharding(invalidation):
    fed, arr = _shard_fed(invalidation=invalidation)
    res = fed.run(arr, until=12.0)
    assert res["completed"] > 0
    assert res["injected"] == res["completed"] + res["rejected"] + res["in_flight"]
    assert res["in_flight"] == 0 and res["dropped_events"] == 0
    shard = res["shard"]
    # tables sharded across both cells: each cell fetches both locally
    # and remotely, and remote fetches paid transit
    assert shard["local_fetches"] > 0 and shard["remote_fetches"] > 0
    assert shard["transit_s"] > 0.0
    assert shard["publishes"] > 0 and shard["updated_rows"] > 0
    # the fleet rollup's fetch split equals the shard service's own
    # (per-cell tallies enter once per cell — no double counting)
    roll = sum(res["cells"][c]["cache"]["remote_fetches"] for c in ("a", "b"))
    assert roll == shard["remote_fetches"]
    staleness = sum(res["cells"][c]["cache"]["staleness"] for c in ("a", "b"))
    if invalidation:
        # versions propagate shard -> L2 -> L1: nothing stale is served
        assert staleness == 0 and shard["invalidated_rows"] > 0
    else:
        assert staleness > 0 and shard["invalidated_rows"] == 0


def test_adaptive_sharded_runs_replay_bit_identically():
    results = []
    for _ in range(2):
        fed, arr = _shard_fed(control=ControlConfig())
        results.append(fed.run(arr, until=12.0))
    a, b = results
    assert a["p99"] == b["p99"] and a["completed"] == b["completed"]
    assert a["trace"] == b["trace"]
    assert a["shard"] == b["shard"]  # version_sum is the replay fingerprint
    for c in ("a", "b"):
        assert a["cells"][c]["cache"] == b["cells"][c]["cache"]
        assert a["cells"][c]["trace"] == b["cells"][c]["trace"]
        assert a["cells"][c]["control"] == b["cells"][c]["control"]
