"""simlint (tools/lint) — fixture-verified behavior per rule.

Every rule gets at least one true-positive fixture (the violation is
reported) and one true-negative fixture (the idiomatic spelling passes),
plus suppression, baseline, and CLI exit-code coverage. Fixtures are
written to tmp_path and linted through the same `run_paths` driver the
CLI uses, so what these tests pin down is exactly what CI enforces.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from tools.lint import CHECKERS, run_paths  # noqa: E402
from tools.lint.core import (Finding, Suppressions,  # noqa: E402
                             load_baseline, write_baseline)

REPO = Path(__file__).resolve().parents[1]


def lint_source(tmp_path, source, rules=None, name="fixture.py"):
    path = tmp_path / name
    path.write_text(source)
    return run_paths([path], root=tmp_path, rules=rules)


def rules_of(findings):
    return {f.rule for f in findings}


def test_registry_has_all_five_rules():
    assert set(CHECKERS) == {"SL001", "SL002", "SL003", "SL004", "SL005"}


# ---- SL001 determinism ----

SL001_POSITIVE = """\
import os
import random
import time

def bad(loop, cfgs):
    t0 = time.perf_counter()
    now = time.time()
    salt = os.urandom(8)
    pick = random.random()
    order = sorted(cfgs, key=lambda c: id(c))
    cap = next(iter({1, 2, 3}))
    opts = {("a", 1), ("b", 2)}
    first = next(iter(opts))
    listed = list(opts)
    for item in opts:
        loop.push(0.0, "arrive", item)
    return [x for x in opts]
"""

SL001_NEGATIVE = """\
import numpy as np

def good(loop, cfgs):
    rng = np.random.default_rng(7)
    draw = rng.random()
    opts = {("a", 1), ("b", 2)}
    if ("a", 1) in opts:  # membership tests never leak order
        pass
    first = min(opts)  # order-free reduction over a set
    for item in sorted(opts):  # sorted() launders the set
        loop.push(0.0, "arrive", item)
    ordered = sorted(cfgs, key=lambda c: c.name)
    return ordered, draw
"""


def test_sl001_true_positives(tmp_path):
    findings = lint_source(tmp_path, SL001_POSITIVE, rules=["SL001"])
    messages = " | ".join(f.message for f in findings)
    assert rules_of(findings) == {"SL001"}
    assert "time.perf_counter" in messages
    assert "time.time" in messages
    assert "os.urandom" in messages
    assert "random.random" in messages
    assert "id()" in messages
    assert "next(iter(" in messages
    assert "for loop" in messages
    assert "comprehension" in messages
    assert "list(<set>)" in messages
    assert len(findings) >= 9


def test_sl001_true_negatives(tmp_path):
    assert lint_source(tmp_path, SL001_NEGATIVE, rules=["SL001"]) == []


def test_sl001_rebinding_a_set_name_clears_it(tmp_path):
    source = (
        "def ok(opts):\n"
        "    opts = set(opts)\n"
        "    opts = sorted(opts)\n"
        "    return [o for o in opts]\n"
    )
    assert lint_source(tmp_path, source, rules=["SL001"]) == []


# ---- SL002 units ----

SL002_POSITIVE = """\
def bad(lat_s, wait_ms, rate_rps):
    total_ms = lat_s  # cross-assign without conversion
    mixed = lat_s + wait_ms
    diff = wait_ms - lat_s
    rate_rps += lat_s
    return total_ms, mixed, diff, rate_rps
"""

SL002_NEGATIVE = """\
def good(lat_s, wait_ms, extra_s):
    total_s = lat_s + extra_s  # same unit adds freely
    lat_ms = lat_s * 1e3  # explicit conversion factor
    back_s = wait_ms / 1e3
    total_ms = lat_s * 1e3 + wait_ms  # converted operand carries no suffix
    plain = lat_s  # un-suffixed name on the left is unchecked
    return total_s, lat_ms, back_s, total_ms, plain
"""


def test_sl002_true_positives(tmp_path):
    findings = lint_source(tmp_path, SL002_POSITIVE, rules=["SL002"])
    assert rules_of(findings) == {"SL002"}
    assert len(findings) == 4
    assert any("'_s' and '_ms'" in f.message or "'_ms' and '_s'" in f.message
               for f in findings)
    assert any("'_rps'" in f.message for f in findings)


def test_sl002_true_negatives(tmp_path):
    assert lint_source(tmp_path, SL002_NEGATIVE, rules=["SL002"]) == []


# ---- SL003 summary-schema drift ----

SL003_POSITIVE = """\
def summary():
    return {"arrived": 1, "completed": 2}

def federated_rollup(cells):
    out = {}
    for s in cells:
        out["arrived"] = s["arrived"] + s["vanished"]  # no producer emits it
    for key in ("arrived", "completed", "rejected"):  # inline key list
        out[key] = 0
    return out
"""

SL003_NEGATIVE = """\
ROLLUP_KEYS = ("arrived", "completed", "rejected")

def summary():
    out = {key: 0 for key in ROLLUP_KEYS}
    out["extra"] = 1
    return out

def federated_rollup(cells):
    out = {key: 0 for key in ROLLUP_KEYS}
    for s in cells:
        for key in ROLLUP_KEYS:  # constant-driven, single source of truth
            out[key] += s[key]
        opt = s.get("maybe_absent", 0)  # .get() stays optional
    return out
"""


def test_sl003_true_positives(tmp_path):
    findings = lint_source(tmp_path, SL003_POSITIVE, rules=["SL003"])
    assert rules_of(findings) == {"SL003"}
    messages = " | ".join(f.message for f in findings)
    assert "'vanished'" in messages  # consumed key nobody produces
    assert "inline schema key list" in messages
    # 'rejected' comes only from the inline tuple, which counts as
    # consumption — and no producer emits it either
    assert "'rejected'" in messages


def test_sl003_true_negatives(tmp_path):
    assert lint_source(tmp_path, SL003_NEGATIVE, rules=["SL003"]) == []


def test_sl003_dataclass_asdict_counts_as_production(tmp_path):
    source = (
        "import dataclasses\n"
        "@dataclasses.dataclass\n"
        "class SpillStats:\n"
        "    spilled_out: int = 0\n"
        "    spilled_in: int = 0\n"
        "    def as_dict(self):\n"
        "        return dataclasses.asdict(self)\n"
        "def federated_rollup(cells):\n"
        "    return [c['spilled_out'] + c['spilled_in'] for c in cells]\n"
    )
    assert lint_source(tmp_path, source, rules=["SL003"]) == []


def test_sl003_cross_file_producer_satisfies_consumer(tmp_path):
    (tmp_path / "producer.py").write_text(
        "def summary():\n    return {'deep_key': 1}\n")
    (tmp_path / "consumer.py").write_text(
        "def federated_rollup(cells):\n"
        "    return [c['deep_key'] for c in cells]\n")
    findings = run_paths([tmp_path], root=tmp_path, rules=["SL003"])
    assert findings == []


# ---- SL004 event-kind exhaustiveness ----

SL004_POSITIVE = """\
def wire(loop):
    loop.on("arrive", lambda t, p: None)
    loop.on("ghost_kind", lambda t, p: None)  # never pushed
    loop.push(0.0, "arrive")
    loop.push(0.0, "orphan_kind")  # never registered
"""

SL004_NEGATIVE = """\
class System:
    def _event(self, kind):
        return f"{kind}:{self.ns}"

    def _transit(self, now, kind, payload, delay_s):
        self.loop.push(now + delay_s, kind, payload)

    def wire(self):
        self.loop.on("route", self.handle)
        self.loop.on(self._event("scale"), self.handle)
        self.loop.on(f"batch_done:{self.key}", self.handle)
        self.loop.add_stream("tick", iter(()))
        self.loop.on("tick", self.handle)

    def drive(self, now):
        self._transit(now, "route", None, 0.1)  # forwarded kind
        self.loop.push(now, self._event("scale"))  # wrapper kind
        self.loop.push(now, f"batch_done:{self.key}")  # namespaced kind
"""


def test_sl004_true_positives(tmp_path):
    findings = lint_source(tmp_path, SL004_POSITIVE, rules=["SL004"])
    assert rules_of(findings) == {"SL004"}
    messages = " | ".join(f.message for f in findings)
    assert "'orphan_kind' is pushed" in messages
    assert "'ghost_kind' has a handler" in messages
    assert len(findings) == 2


def test_sl004_true_negatives(tmp_path):
    assert lint_source(tmp_path, SL004_NEGATIVE, rules=["SL004"]) == []


def test_sl004_is_cross_file(tmp_path):
    (tmp_path / "register.py").write_text(
        "def wire(loop):\n    loop.on('split_kind', id)\n")
    (tmp_path / "pusher.py").write_text(
        "def drive(loop):\n    loop.push(0.0, 'split_kind')\n")
    assert run_paths([tmp_path], root=tmp_path, rules=["SL004"]) == []


# ---- SL005 float-accumulation hygiene ----

SL005_POSITIVE = """\
def report(rows):
    total_latency = 0.0
    for row in rows:
        total_latency += row.latency  # bare += accumulation
    mean_latency = sum(r.latency for r in rows) / len(rows)
    wait = sum(r.queue_wait for r in rows)
    return total_latency, mean_latency, wait
"""

SL005_NEGATIVE = """\
import numpy as np

def fleet_breakdown_rollup(blocks):
    total_latency = 0.0
    for b in blocks:
        total_latency += b["end_to_end_s"]  # rollups are blessed
    return total_latency

def report(latencies, costs):
    vector = np.sum(latencies)  # numpy pairwise summation passes
    spend = sum(costs)  # non-latency sums are out of scope
    return vector, spend
"""


def test_sl005_true_positives(tmp_path):
    findings = lint_source(tmp_path, SL005_POSITIVE, rules=["SL005"])
    assert rules_of(findings) == {"SL005"}
    messages = " | ".join(f.message for f in findings)
    assert "bare sum()" in messages
    assert "bare += " in messages
    assert len(findings) == 3


def test_sl005_true_negatives(tmp_path):
    assert lint_source(tmp_path, SL005_NEGATIVE, rules=["SL005"]) == []


def test_sl005_tracing_module_is_blessed(tmp_path):
    findings = lint_source(tmp_path, SL005_POSITIVE, rules=["SL005"],
                           name="tracing.py")
    assert findings == []


# ---- suppressions ----

def test_trailing_comment_suppresses_that_line_only(tmp_path):
    source = (
        "import time\n"
        "def f():\n"
        "    a = time.time()  # simlint: disable=SL001\n"
        "    b = time.time()\n"
        "    return a, b\n"
    )
    findings = lint_source(tmp_path, source, rules=["SL001"])
    assert [f.line for f in findings] == [4]


def test_standalone_comment_suppresses_whole_file(tmp_path):
    source = (
        "# simlint: disable=SL001  (fixture: wall clock is the point)\n"
        "import time\n"
        "def f():\n"
        "    return time.time(), time.perf_counter()\n"
    )
    assert lint_source(tmp_path, source, rules=["SL001"]) == []


def test_suppression_is_per_rule():
    supp = Suppressions("# simlint: disable=SL002\n")
    hidden = Finding("SL002", "x.py", 3, "m")
    visible = Finding("SL001", "x.py", 3, "m")
    assert supp.hides(hidden) and not supp.hides(visible)


def test_justification_text_does_not_join_rule_list():
    supp = Suppressions("x = 1  # simlint: disable=SL001 legit wall clock\n")
    assert supp.hides(Finding("SL001", "x.py", 1, "m"))
    assert not supp.hides(Finding("SL005", "x.py", 1, "m"))


# ---- baseline ----

def test_baseline_roundtrip_filters_known_findings(tmp_path):
    findings = lint_source(tmp_path, "import time\nt = time.time()\n",
                           rules=["SL001"])
    assert findings
    baseline_path = tmp_path / "baseline.json"
    write_baseline(baseline_path, findings)
    keys = load_baseline(baseline_path)
    assert all(f.key() in keys for f in findings)
    # keys are line-free so unrelated edits upstream don't resurrect them
    assert all("::SL001::" in k and ":1:" not in k for k in keys)


def test_committed_baseline_is_empty():
    doc = json.loads((REPO / "tools" / "lint" / "baseline.json").read_text())
    assert doc["findings"] == []


# ---- CLI + the real tree ----

def _run_cli(args, cwd):
    return subprocess.run(
        [sys.executable, "-m", "tools.lint", *args],
        cwd=cwd, capture_output=True, text=True)


def test_cli_clean_tree_exits_zero():
    proc = _run_cli(["src/repro/core/serving", "benchmarks", "tools"], REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_cli_reports_and_fails_on_findings(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nt = time.time()\n")
    report = tmp_path / "report.txt"
    proc = _run_cli([str(bad), "--no-baseline", "--report", str(report)],
                    REPO)
    assert proc.returncode == 1
    assert "SL001" in proc.stdout
    assert "SL001" in report.read_text()


def test_cli_rejects_unknown_rule():
    proc = _run_cli(["--rules", "SL999"], REPO)
    assert proc.returncode == 2


# ---- the real schema constants stay truthful ----

def test_spill_keys_mirror_spillstats_fields():
    import dataclasses
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.serving.metrics import SPILL_KEYS, SpillStats
    assert SPILL_KEYS == tuple(
        f.name for f in dataclasses.fields(SpillStats))


def test_cache_counter_keys_match_cache_rollup_output():
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.serving.metrics import (CACHE_COUNTER_KEYS,
                                            fleet_cache_rollup)
    out = fleet_cache_rollup([])
    assert set(CACHE_COUNTER_KEYS) <= set(out)
