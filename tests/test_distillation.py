"""C3 attention-KL distillation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_recsys
from repro.core import distillation
from repro.models.common import init_params
from repro.models.recsys import api, taobao_ssa


def _batch(cfg, B=16):
    key = jax.random.key(0)
    L = cfg.seq_len
    return {
        "user": jax.random.randint(key, (B,), 0, 100),
        "item": jax.random.randint(key, (B,), 0, 100),
        "category": jax.random.randint(key, (B,), 0, 100),
        "hist_item": jax.random.randint(key, (B, L), 0, 100),
        "hist_category": jax.random.randint(key, (B, L), 0, 100),
        "hist_len": jax.random.randint(key, (B,), 1, L),
        "label": jax.random.bernoulli(key, 0.4, (B,)).astype(jnp.float32),
    }


def test_attention_kl_zero_on_self():
    p = jax.nn.softmax(jax.random.normal(jax.random.key(0), (2, 4, 8, 8)), -1)
    assert float(distillation.attention_kl(p, p)) == pytest.approx(0.0, abs=1e-6)


def test_attention_kl_positive_and_ordered():
    t = jax.nn.softmax(jax.random.normal(jax.random.key(0), (2, 4, 8, 8)), -1)
    s_close = jax.nn.softmax(jnp.log(t) + 0.1 * jax.random.normal(jax.random.key(1), t.shape), -1)
    s_far = jax.nn.softmax(jax.random.normal(jax.random.key(2), t.shape), -1)
    kl_close = float(distillation.attention_kl(t, s_close))
    kl_far = float(distillation.attention_kl(t, s_far))
    assert 0 < kl_close < kl_far


def test_student_config_smaller():
    cfg = reduced_recsys("taobao_ssa")
    s_cfg = distillation.make_student_cfg(cfg)
    assert s_cfg.n_attn_layers < cfg.n_attn_layers


@pytest.mark.slow
def test_student_init_and_distill_step(rec_rules):
    cfg = reduced_recsys("taobao_ssa")
    teacher = init_params(api.param_defs(cfg), jax.random.key(0))
    s_cfg = distillation.make_student_cfg(cfg)
    student = distillation.init_student_from_teacher(teacher, s_cfg, jax.random.key(1))
    # C1 reps present: low-rank attention projections, grouped FFN
    assert "a" in student["enc0"]["wq"] and "gw" in student["enc0"]["w1"]

    batch = _batch(cfg)
    loss, metrics = distillation.distill_loss(
        student, teacher, batch, s_cfg, cfg, rec_rules
    )
    assert np.isfinite(float(loss))
    assert float(metrics["attn_kl"]) >= 0

    # a few SGD steps reduce the distillation loss
    from repro.training.optimizer import get_optimizer
    from repro.training.train_loop import make_train_step

    opt = get_optimizer("adamw", 1e-3)
    step = jax.jit(make_train_step(
        lambda p, b: distillation.distill_loss(p, teacher, b, s_cfg, cfg, rec_rules), opt
    ))
    state = opt.init(student)
    losses = []
    for _ in range(6):
        student, state, m = step(student, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_teacher_gradient_blocked(rec_rules):
    cfg = reduced_recsys("taobao_ssa")
    teacher = init_params(api.param_defs(cfg), jax.random.key(0))
    s_cfg = distillation.make_student_cfg(cfg)
    student = distillation.init_student_from_teacher(teacher, s_cfg, jax.random.key(1))
    batch = _batch(cfg)
    g = jax.grad(
        lambda t: distillation.distill_loss(student, t, batch, s_cfg, cfg, rec_rules)[0]
    )(teacher)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert total == pytest.approx(0.0, abs=1e-8)
