"""Unit tests for the tracing/attribution layer (core/serving/tracing.py)
and its metrics-side counterparts (fleet_breakdown_rollup,
MetricsRegistry): the bit-exact decomposition closure, the deterministic
pure-hash sampler, span recording + Chrome-trace export structure, the
breakdown accumulator/rollup round trip, and the Prometheus exposition —
including the dropped_events / dropped_kinds / staleness surfacing the
federated rollup now guarantees."""
import json
import math

import pytest

from repro.core.serving.engine import (
    PoolSpec, ServingSystem, attach_zipf_ids, poisson_arrivals,
)
from repro.core.serving.federation import (
    CellSpec, FederatedSystem, assign_homes,
)
from repro.core.serving.metrics import (
    MetricsRegistry, federated_rollup, fleet_breakdown_rollup,
)
from repro.core.serving.pool import PoolConfig, Request
from repro.core.serving.replica import LatencyModel, MissProfile, ReplicaSpec
from repro.core.serving.router import make_router
from repro.core.serving.tracing import (
    COMPONENTS, HISTOGRAM_BUCKETS_S, BreakdownAccumulator, Tracer,
    decompose, service_phases, stage_components,
)


def _spec(name="m", base=0.004, per=1e-4):
    return ReplicaSpec(name, LatencyModel.analytic(base, per),
                       embed_fetch_s=1e-5)


def _system(tracer=None, n_replicas=2):
    pools = {
        "main": PoolSpec(_spec(), PoolConfig(
            max_batch=4, max_wait_s=0.002, n_replicas=n_replicas,
            autoscale=False)),
    }
    return ServingSystem(pools, make_router("least_loaded"), slo_p99_s=0.1,
                         adaptive_shedding=False, tracer=tracer)


def _run(tracer=None, rate=300.0, horizon=1.0, seed=3):
    arr = poisson_arrivals(lambda t: rate, horizon, seed=seed)
    sys_ = _system(tracer)
    res = sys_.run(arr, until=horizon)
    return arr, res


# ---------------------------------------------------------------------------
# decomposition
# ---------------------------------------------------------------------------

def _synthetic_request(**stamps):
    req = Request(rid=1, t_arrive=stamps.pop("t_arrive", 0.0), tier="tier0")
    for k, v in stamps.items():
        req.timeline[f"s0_{k}"] = v
    return req


def test_decompose_reads_every_stamp():
    req = _synthetic_request(
        t_arrive=0.0, enqueue=0.001, dispatch=0.004, start=0.005,
        compute_done=0.009, fetch_local_done=0.010,
        fetch_remote_done=0.012, service_done=0.014)
    comps = decompose(req, 0.014)
    assert comps["queue_wait"] == pytest.approx(0.003)
    assert comps["replica_wait"] == pytest.approx(0.001)
    assert comps["dense_compute"] == pytest.approx(0.004)
    assert comps["embed_fetch_local"] == pytest.approx(0.001)
    assert comps["embed_fetch_remote"] == pytest.approx(0.002)
    assert comps["shard_transit"] == pytest.approx(0.002)
    # the 1 ms before enqueue is inter-stage transit (front-door hop)
    assert comps["transit"] == pytest.approx(0.001)


def test_decompose_closure_is_bit_exact_on_adversarial_floats():
    """The two-term closure must land EXACTLY on `done - t_origin` even
    for stamp patterns chosen to stress round-ties-to-even (the regime
    where a single residual term provably cannot close the sum)."""
    import random
    rng = random.Random(0xC0FFEE)
    for _ in range(2000):
        t = sorted(rng.uniform(0.0, 10.0) for _ in range(8))
        req = _synthetic_request(
            t_arrive=t[0], enqueue=t[1], dispatch=t[2], start=t[3],
            compute_done=t[4], fetch_local_done=t[5],
            fetch_remote_done=t[6], service_done=t[7])
        done = t[7]
        comps = decompose(req, done)
        acc = 0.0
        for name in COMPONENTS:
            acc += comps[name]
        assert acc == done - t[0]  # no tolerance: IEEE-754 equality
        assert abs(comps["closure"]) <= 4 * math.ulp(done - t[0] or 1.0)


def test_decompose_fast_path_is_all_transit():
    # a result-cache hit stamps only enqueue/start/done: every modelled
    # component is zero and the whole latency lands in the residual
    req = _synthetic_request(t_arrive=0.0, enqueue=0.002, start=0.002)
    comps = decompose(req, 0.002)
    assert comps["transit"] + comps["closure"] == 0.002
    for name in COMPONENTS[:-2]:
        assert comps[name] == 0.0


def test_decompose_stage_restriction():
    """A pool's stage-local view (stages=[k], t_origin=t_enqueue) must
    not double-count upstream stages against the stage-local total."""
    req = Request(rid=7, t_arrive=0.0, tier="tier0", stage=2)
    req.timeline.update({
        "s1_enqueue": 0.001, "s1_start": 0.002, "s1_dispatch": 0.002,
        "s1_service_done": 0.004, "s1_done": 0.004,
        "s2_enqueue": 0.005, "s2_dispatch": 0.006, "s2_start": 0.006,
        "s2_service_done": 0.009, "s2_done": 0.009,
    })
    local = decompose(req, 0.009, t_origin=0.005, stages=[2])
    acc = 0.0
    for name in COMPONENTS:
        acc += local[name]
    assert acc == 0.009 - 0.005
    assert local["queue_wait"] == pytest.approx(0.001)
    full = decompose(req, 0.009)  # default: full path, origin t_arrive
    acc = 0.0
    for name in COMPONENTS:
        acc += full[name]
    assert acc == 0.009
    assert full["queue_wait"] == pytest.approx(0.002)  # both stages


def test_service_phases_splits_miss_profile():
    spec = _spec(base=0.004, per=0.0)
    dense, local, remote, transit = service_phases(
        spec, 8, MissProfile(l2_hits=1, local_rows=3, remote_rows=2,
                             transit_s=0.0015))
    assert dense == pytest.approx(0.004)
    assert local == pytest.approx(3e-5)
    assert remote == pytest.approx(2e-5)
    assert transit == pytest.approx(0.0015)
    # plain int miss_rows (no shard service): everything is local
    dense, local, remote, transit = service_phases(spec, 8, 4)
    assert (local, remote, transit) == (pytest.approx(4e-5), 0.0, 0.0)


# ---------------------------------------------------------------------------
# accumulator + rollup
# ---------------------------------------------------------------------------

def test_breakdown_accumulator_summary_shape():
    acc = BreakdownAccumulator()
    req = _synthetic_request(t_arrive=0.0, enqueue=0.0, dispatch=0.001,
                             start=0.001, service_done=0.005)
    acc.observe(req, 0.005)
    s = acc.summary()
    assert s["count"] == 1
    assert s["end_to_end_s"] == pytest.approx(0.005)
    assert set(s["components"]) == set(COMPONENTS)
    assert sum(s["shares"].values()) == pytest.approx(1.0)
    assert s["histogram_buckets_s"] == list(HISTOGRAM_BUCKETS_S)
    for name in COMPONENTS:
        hist = s["histograms"][name]
        assert len(hist) == len(HISTOGRAM_BUCKETS_S) + 1
        assert hist == sorted(hist)  # cumulative, le-style
        assert hist[-1] == s["count"]


def test_fleet_breakdown_rollup_round_trips():
    a, b = BreakdownAccumulator(), BreakdownAccumulator()
    req = _synthetic_request(t_arrive=0.0, enqueue=0.0, dispatch=0.002,
                             start=0.002, service_done=0.01)
    a.observe(req, 0.01)
    b.observe(req, 0.01)
    b.observe(req, 0.01)
    merged = fleet_breakdown_rollup([a.summary(), b.summary()])
    assert merged["count"] == 3
    assert merged["end_to_end_s"] == pytest.approx(0.03)
    for name in COMPONENTS:
        assert merged["components"][name] == pytest.approx(
            a.summary()["components"][name] * 3)
        assert merged["histograms"][name][-1] == 3
    # empty/falsy blocks are skipped, not fatal
    assert fleet_breakdown_rollup([None, a.summary()])["count"] == 1
    bad = a.summary()
    bad["histogram_buckets_s"] = [1.0, 2.0]
    with pytest.raises(ValueError):
        fleet_breakdown_rollup([b.summary(), bad])


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_sampler_is_deterministic_and_dense_at_one():
    tr = Tracer(sample_every=4, seed=9)
    picks = [tr.sampled(rid) for rid in range(4000)]
    assert picks == [tr.sampled(rid) for rid in range(4000)]
    frac = sum(picks) / len(picks)
    assert 0.15 < frac < 0.35  # ~1/4, hash-spread
    assert all(Tracer(sample_every=1).sampled(r) for r in range(100))
    # different seeds pick different subsets
    other = [Tracer(sample_every=4, seed=10).sampled(r) for r in range(4000)]
    assert other != picks
    with pytest.raises(ValueError):
        Tracer(sample_every=0)


def test_tracer_caps_spans_and_counts_drops():
    tr = Tracer(sample_every=1, max_spans=5)
    for i in range(9):
        tr.record_batch("", "main", 0, float(i), float(i) + 0.5, 4, 2)
    assert len(tr) == 5
    assert tr.dropped_spans == 4
    assert tr.summary()["dropped_spans"] == 4
    assert tr.to_chrome_trace()["metadata"]["dropped_spans"] == 4


def test_chrome_trace_structure():
    tr = Tracer(sample_every=1, seed=0)
    arr, res = _run(tracer=tr)
    assert res["completed"] > 0 and len(tr) > 0
    doc = tr.to_chrome_trace()
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] != "M"]
    # every (pid, tid) used by a span is named by metadata
    named_pids = {e["pid"] for e in meta if e["name"] == "process_name"}
    named_tids = {(e["pid"], e["tid"])
                  for e in meta if e["name"] == "thread_name"}
    for e in spans:
        assert e["pid"] in named_pids
        assert (e["pid"], e["tid"]) in named_tids
    # globally non-decreasing timestamps
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)
    # sync B/E balance per (pid, tid); async b/e balance per (cat, id, name)
    depth = {}
    for e in spans:
        if e["ph"] == "B":
            depth[(e["pid"], e["tid"])] = depth.get((e["pid"], e["tid"]), 0) + 1
        elif e["ph"] == "E":
            key = (e["pid"], e["tid"])
            depth[key] = depth.get(key, 0) - 1
            assert depth[key] >= 0
    assert all(v == 0 for v in depth.values())
    async_open = {}
    for e in spans:
        if e["ph"] == "b":
            async_open[(e["id"], e["name"])] = \
                async_open.get((e["id"], e["name"]), 0) + 1
        elif e["ph"] == "e":
            async_open[(e["id"], e["name"])] = \
                async_open.get((e["id"], e["name"]), 0) - 1
    assert all(v == 0 for v in async_open.values())
    # the whole document is JSON-serializable as-is (what --trace-out does)
    json.dumps(doc)


def test_tracer_only_records_sampled_requests():
    tr = Tracer(sample_every=16, seed=2)
    arr, res = _run(tracer=tr)
    cols = tr._spans.as_dict()
    from repro.core.serving.tracing import _SPAN_KINDS
    for kind_id, rid in zip(cols["kind"], cols["rid"]):
        if _SPAN_KINDS[kind_id] != "batch":  # batch rid column = n_requests
            assert tr.sampled(rid)


# ---------------------------------------------------------------------------
# prometheus exposition
# ---------------------------------------------------------------------------

def _federation(tracer=None):
    def cell():
        return CellSpec(pools={"main": PoolSpec(_spec(), PoolConfig(
            max_batch=4, max_wait_s=0.002, n_replicas=2, autoscale=False))},
            slo_p99_s=0.1, adaptive_shedding=False)
    return FederatedSystem({"a": cell(), "b": cell()},
                           policy="least_loaded", rtt_s=0.002,
                           slo_p99_s=0.1, tracer=tracer)


def test_federated_rollup_surfaces_drops_and_staleness():
    fed = _federation()
    arr = poisson_arrivals(lambda t: 200.0, 1.0, seed=11)
    attach_zipf_ids(arr, 1000, 4, seed=1)
    assign_homes(arr, {"a": 0.6, "b": 0.4}, seed=2)
    res = fed.run(arr, until=1.0)
    rollup = federated_rollup(res["cells"])
    assert "dropped_events" in rollup and rollup["dropped_events"] >= 0
    assert isinstance(rollup["dropped_kinds"], dict)
    assert "staleness" in rollup
    assert rollup["staleness"] == rollup["cache"]["staleness"]
    assert rollup["latency_breakdown"]["count"] == rollup["completed"]
    # cells share one event loop: drops must merge by max, never sum
    per_cell = [c.get("dropped_events", 0) for c in res["cells"].values()]
    assert rollup["dropped_events"] == max(per_cell)


def test_prometheus_text_exposes_conserved_counters():
    fed = _federation()
    arr = poisson_arrivals(lambda t: 200.0, 1.0, seed=11)
    attach_zipf_ids(arr, 1000, 4, seed=1)
    assign_homes(arr, {"a": 0.6, "b": 0.4}, seed=2)
    res = fed.run(arr, until=1.0)
    text = MetricsRegistry.from_summary(res).to_prometheus_text()
    assert text.endswith("\n")
    # conserved counters surface at fleet scope AND per cell
    for metric in ("completed_total", "rejected_total", "dropped_events_total",
                   "cache_staleness_total"):
        assert f'repro_serving_{metric}{{scope="fleet"}}' in text
        assert f'scope="cell",cell="a"' in text
    # breakdown series: per-component sums + le-bucketed histograms
    assert 'latency_component_seconds_total{component="queue_wait",scope="fleet"}' in text
    assert 'le="+Inf"' in text
    # exposition-format sanity: every non-comment line is "name{...} value"
    for line in text.splitlines():
        if line.startswith("#") or not line:
            continue
        name_part, _, value = line.rpartition(" ")
        float(value)
        assert name_part.startswith("repro_serving_")
    # the counters must MATCH the rollup (the acceptance criterion)
    rollup = federated_rollup(res["cells"])
    line = next(l for l in text.splitlines()
                if l.startswith('repro_serving_completed_total{scope="fleet"}'))
    assert int(line.split()[-1]) == rollup["completed"] == res["completed"]


def test_prometheus_system_scope_from_plain_summary():
    _, res = _run()
    text = MetricsRegistry.from_summary(res).to_prometheus_text()
    assert 'repro_serving_completed_total{scope="system"}' in text
    assert int(next(
        l for l in text.splitlines()
        if l.startswith('repro_serving_completed_total{scope="system"}')
    ).split()[-1]) == res["completed"]


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.add("weird", "gauge", "odd labels", 1.0,
            label='a"b\\c\nd')
    text = reg.to_prometheus_text()
    assert '\\"' in text and "\\\\" in text and "\\n" in text
    assert "\nd" not in text.replace("\\n", "")
