"""Optimizers, checkpointing, fault tolerance, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.grad_compression import (
    compress_psum_mean, init_residuals, make_compressed_allreduce,
)
from repro.training import checkpoint
from repro.training.fault_tolerance import FTConfig, HeartbeatMonitor, ResilientTrainer
from repro.training.optimizer import (
    _dequantize_blockwise, _quantize_blockwise, abstract_state, get_optimizer,
    state_pspecs,
)
from repro.training.train_loop import make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quadratic_problem():
    target = jax.random.normal(jax.random.key(0), (32, 16))

    def loss_fn(params, batch):
        l = jnp.mean(jnp.square(params["w"] - target))
        return l, {}

    return {"w": jnp.zeros((32, 16))}, loss_fn


@pytest.mark.parametrize("name", ["sgd", "adamw", "adam8bit"])
def test_optimizers_converge(name):
    params, loss_fn = _quadratic_problem()
    opt = get_optimizer(name, 0.05 if name != "sgd" else 0.2)
    step = jax.jit(make_train_step(loss_fn, opt, grad_clip=0.0))
    state = opt.init(params)
    first = None
    n_steps = 60 if name != "sgd" else 400  # plain SGD-M needs more steps
    for i in range(n_steps):
        params, state, m = step(params, state, {})
        first = first or float(m["loss"])
    assert float(m["loss"]) < 0.02 * first


def test_adam8bit_matches_adamw_closely():
    params, loss_fn = _quadratic_problem()
    trajs = {}
    for name in ("adamw", "adam8bit"):
        p = jax.tree.map(lambda x: x, params)
        opt = get_optimizer(name, 0.05)
        step = jax.jit(make_train_step(loss_fn, opt, grad_clip=0.0))
        st = opt.init(p)
        for _ in range(30):
            p, st, m = step(p, st, {})
        trajs[name] = float(m["loss"])
    assert abs(trajs["adam8bit"] - trajs["adamw"]) < 0.25 * trajs["adamw"] + 1e-3


def test_blockwise_quant_roundtrip():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 10
    q, s = _quantize_blockwise(x)
    err = jnp.abs(_dequantize_blockwise(q, s) - x)
    per_block_scale = jnp.repeat(s, 256)[:1000]
    assert (err <= per_block_scale * 0.51 + 1e-6).all()


def test_abstract_state_matches_init():
    params = {"w": jnp.zeros((10, 4)), "b": jnp.zeros((4,))}
    for name in ("sgd", "adamw", "adam8bit"):
        opt = get_optimizer(name)
        real = opt.init(params)
        abstract = abstract_state(name, params)
        assert jax.tree.structure(real) == jax.tree.structure(abstract)
        from jax.sharding import PartitionSpec as P

        specs = state_pspecs(name, jax.tree.map(lambda _: P(), params))
        assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
            == jax.tree.structure(real)


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((7,), jnp.int32)},
    }
    checkpoint.save(tmp_path, 5, tree, extra={"note": "x"})
    assert checkpoint.latest_step(tmp_path) == 5
    out = checkpoint.restore(tmp_path, 5, tree)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), tree, out)


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.ones((4,))}
    checkpoint.save(tmp_path, 1, tree)
    # a stale tmp dir from a crashed writer must not be visible
    (tmp_path / "step_00000002.tmp").mkdir()
    assert checkpoint.latest_step(tmp_path) == 1


def test_resilient_trainer_resumes_after_crash(tmp_path):
    params, loss_fn = _quadratic_problem()
    opt = get_optimizer("adamw", 0.05)
    step = jax.jit(make_train_step(loss_fn, opt, grad_clip=0.0))
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=10)
    mk = lambda start: iter(lambda: {}, None)  # infinite empty batches

    trainer = ResilientTrainer(step, cfg, make_batches=mk)
    state = opt.init(params)
    with pytest.raises(RuntimeError):
        trainer.run(params, state, 50, crash_at=25)
    assert checkpoint.latest_step(tmp_path) == 20  # last periodic ckpt

    p2, s2, restarts, last = trainer.run(params, state, 50)
    assert restarts == 1 and last == 50


def test_heartbeat_and_straggler_detection():
    cfg = FTConfig(heartbeat_s=1.0, dead_after=3, straggler_factor=2.0,
                   straggler_patience=2)
    mon = HeartbeatMonitor(["w0", "w1", "w2"], cfg)
    for t in range(10):
        mon.beat("w0", float(t), 0.1)
        mon.beat("w1", float(t), 0.1)
        mon.beat("w2", float(t), 0.5)  # persistently 5x slower
    assert mon.dead_workers(20.0) == ["w0", "w1", "w2"]  # all silent by t=20
    mon.beat("w0", 20.0)
    assert "w0" not in mon.dead_workers(20.5)
    assert mon.stragglers() == []  # first strike
    assert mon.stragglers() == ["w2"]  # patience reached
    mon.evict("w2")
    assert "w2" not in mon.last_beat


def test_elastic_restore_different_sharding(tmp_path, test_mesh):
    """Checkpoint written replicated restores under an explicit sharding —
    the 512->256 re-mesh path (device_put with a NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    checkpoint.save(tmp_path, 1, tree)
    sh = {"w": NamedSharding(test_mesh, P("data", None))}
    out = checkpoint.restore(tmp_path, 1, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(out["w"], tree["w"])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_grad_compression_error_feedback_unbiased():
    """On a constant gradient, error feedback makes the time-averaged
    compressed gradient converge to the true one."""
    g = jax.random.normal(jax.random.key(0), (300,)) * 3.0
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        mean, res = compress_psum_mean(g, res, ())  # no axes: single device
        acc = acc + mean
    avg = acc / steps
    np.testing.assert_allclose(avg, g, rtol=2e-2, atol=2e-2)


def test_grad_compression_tree_api():
    grads = {"a": jnp.ones((10,)), "b": {"c": jnp.full((5,), 2.0)}}
    res = init_residuals(grads)
    fn = make_compressed_allreduce(())
    means, new_res = fn(grads, res)
    assert jax.tree.structure(means) == jax.tree.structure(grads)
    # single step error bounded by quantization granularity
    np.testing.assert_allclose(means["a"], grads["a"], rtol=0.02, atol=0.02)
