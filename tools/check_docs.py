"""Docs drift guard (run by the CI docs job):

  1. every intra-repo markdown link in README.md and docs/*.md resolves
     to an existing file or directory;
  2. every fenced ```python block in those files executes cleanly
     (blocks within one file share a namespace, tutorial-style).

Run it the same way CI does:

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys
import traceback

ROOT = pathlib.Path(__file__).resolve().parents[1]

# inline links [text](target); images and reference-style links are out of
# scope, as are bare URLs
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
EXTERNAL = ("http://", "https://", "mailto:")


def _rel(path: pathlib.Path):
    """Repo-relative for readability; as-given for docs outside ROOT
    (the test suite checks fixture docs in tmp dirs)."""
    try:
        return path.relative_to(ROOT)
    except ValueError:
        return path


def doc_files() -> list:
    files = []
    readme = ROOT / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((ROOT / "docs").glob("*.md")))
    return files


def check_links(path: pathlib.Path, text: str) -> list:
    errors = []
    for target in LINK.findall(text):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        # "/docs/x.md" is repo-root-absolute on GitHub, not filesystem-absolute
        base = ROOT / rel.lstrip("/") if rel.startswith("/") else path.parent / rel
        if not base.exists():
            errors.append(f"{_rel(path)}: broken link -> {target}")
    return errors


def run_blocks(path: pathlib.Path, text: str) -> list:
    namespace: dict = {"__name__": f"docs_block[{path.name}]"}
    for i, code in enumerate(FENCE.findall(text)):
        try:
            exec(compile(code, f"{path.name}[python block {i}]", "exec"), namespace)
        except Exception:
            return [
                f"{_rel(path)}: python block {i} failed:\n"
                + traceback.format_exc(limit=3)
            ]
    return []


def main() -> int:
    errors = []
    for path in doc_files():
        text = path.read_text()
        errors.extend(check_links(path, text))
        errors.extend(run_blocks(path, text))
        n_blocks = len(FENCE.findall(text))
        print(f"checked {_rel(path)}: "
              f"{len(LINK.findall(text))} links, {n_blocks} python blocks")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
