"""Chrome-trace artifact validator (run by the CI docs job on the trace
the docs/observability.md runnable block writes, and usable against any
`bench_serving --trace-out` artifact):

  1. the document parses and carries a `traceEvents` array;
  2. non-metadata events have globally non-decreasing timestamps;
  3. synchronous B/E pairs balance per (pid, tid) as a LIFO stack and
     names match on close (nesting is well-formed, nothing dangles);
  4. async "b"/"e" pairs balance per (cat, id, name);
  5. every (pid, tid) a span uses is named by "M" metadata events
     (process_name for the pid, thread_name for the pid+tid) — what
     makes the trace readable, not just loadable, in Perfetto.

Run it the same way CI does:

    python tools/check_trace.py PATH/to/trace.json
"""
from __future__ import annotations

import json
import pathlib
import sys


def check_trace(doc) -> list:
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["document has no traceEvents array"]
    named_pids, named_tids = set(), set()
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            named_pids.add(ev["pid"])
        elif ev.get("name") == "thread_name":
            named_tids.add((ev["pid"], ev["tid"]))
    spans = [ev for ev in events if ev.get("ph") != "M"]
    last_ts = None
    stacks = {}  # (pid, tid) -> [name, ...] for sync B/E
    async_open = {}  # (cat, id, name) -> open count
    for i, ev in enumerate(spans):
        ph, ts = ev.get("ph"), ev.get("ts")
        where = f"event {i} ({ph} {ev.get('name')!r})"
        if ts is None or ts < 0:
            errors.append(f"{where}: missing/negative ts")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"{where}: ts {ts} < previous {last_ts}"
                          " (not sorted)")
        last_ts = ts
        pid, tid = ev.get("pid"), ev.get("tid")
        if pid not in named_pids:
            errors.append(f"{where}: pid {pid} has no process_name metadata")
        if (pid, tid) not in named_tids:
            errors.append(f"{where}: tid {pid}/{tid} has no thread_name"
                          " metadata")
        if ph == "B":
            stacks.setdefault((pid, tid), []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get((pid, tid))
            if not stack:
                errors.append(f"{where}: E with empty stack on {pid}/{tid}")
            elif ev.get("name") not in (None, stack[-1]):
                errors.append(f"{where}: E {ev.get('name')!r} closes"
                              f" B {stack.pop()!r} (mismatched nesting)")
            else:
                stack.pop()
        elif ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"), ev.get("name"))
            if None in key:
                errors.append(f"{where}: async event missing cat/id/name")
                continue
            async_open[key] = async_open.get(key, 0) + (1 if ph == "b" else -1)
            if async_open[key] < 0:
                errors.append(f"{where}: async e before its b for {key}")
        elif ph not in ("X", "i", "C"):  # other legal phases pass through
            errors.append(f"{where}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        if stack:
            errors.append(f"track {key}: {len(stack)} unclosed B span(s):"
                          f" {stack[:3]}")
    dangling = {k: n for k, n in async_open.items() if n != 0}
    if dangling:
        errors.append(f"{len(dangling)} unbalanced async span key(s), e.g."
                      f" {next(iter(dangling.items()))}")
    if not spans:
        errors.append("trace has metadata but zero spans")
    return errors


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    if not args:
        print("usage: check_trace.py TRACE.json [TRACE2.json ...]",
              file=sys.stderr)
        return 2
    failed = False
    for arg in args:
        path = pathlib.Path(arg)
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            print(f"{path}: unreadable: {exc}", file=sys.stderr)
            failed = True
            continue
        errors = check_trace(doc)
        n = sum(1 for ev in doc["traceEvents"] if ev.get("ph") != "M") \
            if isinstance(doc, dict) and isinstance(doc.get("traceEvents"),
                                                    list) else 0
        if errors:
            print(f"{path}: {len(errors)} problem(s)", file=sys.stderr)
            print("\n".join(errors[:20]), file=sys.stderr)
            failed = True
        else:
            print(f"checked {path}: {n} span events OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
