"""SL004 — event-kind exhaustiveness across the event loop.

Every string event kind the stack schedules — ``loop.push(t, kind, ...)``
or ``loop.add_stream(kind, ...)`` — must have a handler registered with
``loop.on(kind, ...)`` somewhere in the linted tree, and every
registered kind must actually be scheduled by someone. A kind pushed
with no handler silently increments ``dropped_events``; a handler for a
kind nobody pushes is dead wiring from a refactor.

Kind extraction understands the repo's real shapes:

  * plain string literals: ``loop.on("arrive", ...)``;
  * namespaced f-strings: ``f"batch_timeout:{self.event_key}"``
    normalizes to its literal prefix ``batch_timeout`` on both the push
    and the registration side;
  * wrapper calls with one string argument: ``self._event("scale")``
    counts as ``"scale"``;
  * kind-forwarding helpers: a function whose ``kind`` parameter flows
    into an internal ``.push`` call (federation's ``_transit``) makes
    its call sites count — ``self._transit(now, "spill", ...)`` pushes
    ``"spill"``. Forwarders are resolved within the defining file.

Dynamic kinds that never resolve to a literal are skipped, not guessed.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .core import Checker, Finding, register, str_const


def _literal_kind(node: ast.AST) -> Optional[str]:
    """Resolve a kind expression to its registry name, else None."""
    s = str_const(node)
    if s is not None:
        return s
    if isinstance(node, ast.JoinedStr):
        prefix = ""
        for value in node.values:
            part = str_const(value)
            if part is None:
                break
            prefix += part
        return prefix.rstrip(":") or None
    if isinstance(node, ast.Call) and len(node.args) >= 1:
        # one-string-arg wrapper like self._event("scale")
        inner = str_const(node.args[0])
        if inner is not None and len(node.args) == 1:
            return inner
    return None


def _func_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _forwarders(tree: ast.AST) -> Dict[str, int]:
    """name -> positional index (self excluded) of a parameter that the
    function forwards as the kind argument of an internal ``.push``."""
    out: Dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = [a.arg for a in node.args.args]
        offset = 1 if params and params[0] in ("self", "cls") else 0
        for call in ast.walk(node):
            if not (isinstance(call, ast.Call)
                    and _func_name(call) == "push" and len(call.args) >= 2):
                continue
            kind_arg = call.args[1]
            if isinstance(kind_arg, ast.Name) and kind_arg.id in params:
                out[node.name] = params.index(kind_arg.id) - offset
    return out


@register
class EventKindChecker(Checker):
    rule = "SL004"
    title = "event kinds: every push has a handler and vice versa"

    def __init__(self) -> None:
        # kind -> [(path, line)] sites
        self.pushed: Dict[str, List[Tuple[str, int]]] = {}
        self.registered: Dict[str, List[Tuple[str, int]]] = {}

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        forwarders = _forwarders(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = _func_name(node)
            kind: Optional[str] = None
            side: Optional[Dict] = None
            if name == "on" and node.args:
                kind, side = _literal_kind(node.args[0]), self.registered
            elif name == "push" and len(node.args) >= 2:
                kind, side = _literal_kind(node.args[1]), self.pushed
            elif name == "add_stream" and node.args:
                kind, side = _literal_kind(node.args[0]), self.pushed
            elif name in forwarders:
                index = forwarders[name]
                if 0 <= index < len(node.args):
                    kind, side = _literal_kind(node.args[index]), self.pushed
            if kind is not None and side is not None:
                side.setdefault(kind, []).append((path, node.lineno))
        return []

    def finalize(self) -> List[Finding]:
        findings: List[Finding] = []
        for kind in sorted(set(self.pushed) - set(self.registered)):
            path, line = self.pushed[kind][0]
            findings.append(self.finding(
                path, line,
                f"event kind '{kind}' is pushed/streamed but has no "
                "loop.on() handler registration in the linted tree"))
        for kind in sorted(set(self.registered) - set(self.pushed)):
            path, line = self.registered[kind][0]
            findings.append(self.finding(
                path, line,
                f"event kind '{kind}' has a handler but is never "
                "pushed/streamed in the linted tree"))
        return findings
