"""SL002 — units: seconds-everywhere, conversions must be explicit.

The serving stack carries time in seconds and rates in requests/second,
and encodes the unit in the identifier suffix (``slo_p99_s``,
``transit_s``, ``target_rps``). This rule flags the two mistakes that
silently corrupt that convention:

  * cross-unit assignment: ``x_ms = y_s`` (plain name to plain name,
    no arithmetic in between);
  * cross-unit ``+``/``-``: ``a_s + b_ms`` where both operands are bare
    identifiers with *different* unit suffixes.

A conversion factor exempts the expression naturally: ``lat_s * 1e3``
is a ``*``/``/`` BinOp and therefore carries no suffix of its own, so
``t_ms = lat_s * 1e3`` never trips the rule. Recognized suffixes:
``_s _ms _us _ns _rps _qps``.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .core import Checker, Finding, register

_UNITS = {"s", "ms", "us", "ns", "rps", "qps"}
_TIME_UNITS = {"s", "ms", "us", "ns"}


def unit_of(node: ast.AST) -> Optional[str]:
    """Unit suffix of a bare Name/Attribute, else None."""
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    else:
        return None
    head, _, tail = name.rpartition("_")
    return tail if head and tail in _UNITS else None


def _compatible(a: str, b: str) -> bool:
    if a == b:
        return True
    # time + time is the only cross-family mix we ever see; rate vs time
    # is always wrong, and so is any ms-vs-s style mismatch
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, checker: "UnitsChecker", path: str):
        self.checker = checker
        self.path = path
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, u1: str, u2: str, what: str) -> None:
        self.findings.append(self.checker.finding(
            self.path, node,
            f"{what} mixes '_{u1}' and '_{u2}' units without an explicit "
            "conversion factor"))

    def visit_Assign(self, node: ast.Assign) -> None:
        rhs = unit_of(node.value)
        if rhs is not None:
            for target in node.targets:
                lhs = unit_of(target)
                if lhs is not None and not _compatible(lhs, rhs):
                    self._flag(node, lhs, rhs, "assignment")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            lhs, rhs = unit_of(node.target), unit_of(node.value)
            if lhs is not None and rhs is not None \
                    and not _compatible(lhs, rhs):
                self._flag(node, lhs, rhs, "assignment")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            lhs, rhs = unit_of(node.target), unit_of(node.value)
            if lhs is not None and rhs is not None \
                    and not _compatible(lhs, rhs):
                self._flag(node, lhs, rhs, "augmented assignment")
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            lhs, rhs = unit_of(node.left), unit_of(node.right)
            if lhs is not None and rhs is not None \
                    and not _compatible(lhs, rhs):
                self._flag(node, lhs, rhs, "arithmetic")
        self.generic_visit(node)


@register
class UnitsChecker(Checker):
    rule = "SL002"
    title = "units: no cross-suffix assignment or arithmetic"

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        visitor = _Visitor(self, path)
        visitor.visit(tree)
        return visitor.findings
