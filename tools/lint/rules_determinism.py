"""SL001 — determinism: the simulated path must replay bit-exactly.

Flags the nondeterminism sources that have historically broken replay in
discrete-event simulators:

  * wall-clock reads (``time.time`` / ``perf_counter`` / ``monotonic`` /
    ``process_time`` and their ``_ns`` variants) — simulated time is the
    loop clock, never the host's;
  * process-global RNG (``random.*``, ``os.urandom``, legacy
    ``numpy.random.<fn>`` module calls) — only seeded
    ``numpy.random.default_rng`` / ``Generator`` instances are allowed;
  * ``id()`` used inside ``sorted``/``min``/``max``/``.sort`` — CPython
    addresses vary run to run, so id-keyed ordering is nondeterministic;
  * iterating a ``set``/``frozenset`` where the order leaks into results
    (``for`` over a set, ``next(iter(s))``, ``list(s)``, ``tuple(s)``,
    comprehensions over sets). Set iteration order depends on insertion
    history and hash seeding; sort first or keep an ordered structure.

Wall-clock calibration of *real* kernels is legitimate — annotate those
sites with ``# simlint: disable=SL001``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .core import Checker, Finding, dotted_name, register

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns", "os.urandom",
}
# construction of *seeded* generators off the legacy module is fine
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "SFC64", "BitGenerator"}
_ORDERING_FNS = {"sorted", "min", "max"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


class _Scope:
    def __init__(self) -> None:
        self.set_names: Set[str] = set()


class _Visitor(ast.NodeVisitor):
    def __init__(self, checker: "DeterminismChecker", path: str):
        self.checker = checker
        self.path = path
        self.findings: List[Finding] = []
        self.scopes: List[_Scope] = [_Scope()]

    # -- scope handling: one name-set per function nesting level --
    def _visit_scope(self, node: ast.AST) -> None:
        self.scopes.append(_Scope())
        self.generic_visit(node)
        self.scopes.pop()

    visit_FunctionDef = _visit_scope
    visit_AsyncFunctionDef = _visit_scope
    visit_Lambda = _visit_scope

    def _known_set(self, node: ast.AST) -> bool:
        if _is_set_expr(node):
            return True
        if isinstance(node, ast.Name):
            return any(node.id in s.set_names for s in reversed(self.scopes))
        return False

    def _flag(self, node: ast.AST, message: str) -> None:
        self.findings.append(self.checker.finding(self.path, node, message))

    def visit_Assign(self, node: ast.Assign) -> None:
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            scope = self.scopes[-1]
            if _is_set_expr(node.value):
                scope.set_names.add(name)
            else:
                scope.set_names.discard(name)  # rebound to a non-set
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        dotted = dotted_name(node.func)
        if dotted in _WALL_CLOCK:
            self._flag(node, f"wall-clock read {dotted}() on the simulated "
                             "path; use the event-loop clock")
        elif dotted is not None:
            parts = dotted.split(".")
            if parts[0] == "random" and len(parts) > 1:
                self._flag(node, f"process-global RNG {dotted}(); use a "
                                 "seeded numpy.random.Generator")
            elif (len(parts) >= 3 and parts[-2] == "random"
                  and parts[0] in ("np", "numpy")
                  and parts[-1] not in _NP_RANDOM_OK):
                self._flag(node, f"legacy global numpy RNG {dotted}(); use "
                                 "a seeded numpy.random.default_rng")
        # id() inside an ordering call
        fn = node.func
        is_ordering = (isinstance(fn, ast.Name) and fn.id in _ORDERING_FNS) \
            or (isinstance(fn, ast.Attribute) and fn.attr == "sort")
        if is_ordering:
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Name)
                        and sub.func.id == "id"):
                    self._flag(sub, "id() used as an ordering key; object "
                                    "addresses vary across runs")
        # next(iter(set)) / list(set) / tuple(set)
        if isinstance(fn, ast.Name) and node.args:
            arg = node.args[0]
            if fn.id == "next" and isinstance(arg, ast.Call) \
                    and isinstance(arg.func, ast.Name) \
                    and arg.func.id == "iter" and arg.args \
                    and self._known_set(arg.args[0]):
                self._flag(node, "next(iter(<set>)) picks an arbitrary "
                                 "element; sort or use min()/max()")
            elif fn.id in ("list", "tuple") and self._known_set(arg):
                self._flag(node, f"{fn.id}(<set>) materializes arbitrary "
                                 "set order; wrap in sorted()")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._known_set(node.iter):
            self._flag(node, "iterating a set in a for loop leaks arbitrary "
                             "order into the simulation; sort first")
        self.generic_visit(node)

    def _visit_comp(self, node: ast.AST) -> None:
        for gen in node.generators:  # type: ignore[attr-defined]
            if self._known_set(gen.iter):
                self._flag(node, "comprehension over a set leaks arbitrary "
                                 "order into the result; sort first")
        self._visit_scope(node)

    visit_ListComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp
    # SetComp result is itself unordered, so set-over-set is harmless


@register
class DeterminismChecker(Checker):
    rule = "SL001"
    title = "determinism: no wall clock, global RNG, or order leaks"

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        visitor = _Visitor(self, path)
        visitor.visit(tree)
        return visitor.findings
