"""SL003 — summary-schema drift between producers and consumers.

The serving stack's observability contract is a family of summary dicts:
``summary()`` / ``cache_summary()`` / ``cell_stats()`` / ``stats()`` /
``as_dict()`` producers on one side, and the fleet rollups plus the
Prometheus registry (``fleet_cache_rollup``, ``fleet_control_rollup``,
``fleet_breakdown_rollup``, ``federated_rollup``,
``MetricsRegistry.from_summary``/``_add_scope``/``_add_breakdown``) on
the consumer side. PR 9 caught producer/consumer drift by hand; this
rule checks it mechanically:

  * every string key a consumer *requires* (``x["key"]`` subscripts and
    loops over key lists) must be emitted by at least one producer —
    dict literals, ``out["key"] = ...`` stores, dataclass fields behind
    ``dataclasses.asdict(self)``, and ``{k: 0 for k in *_KEYS}``
    comprehensions all count as production;
  * consumers must not hardcode inline schema key lists — iterate a
    module-level ``*_KEYS`` constant instead, so the key set has one
    source of truth the first check can then verify.

``.get("key", default)`` reads are treated as optional and never
required — back-compat fallbacks stay legal.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Checker, Finding, register, str_const

PRODUCER_NAMES = {"summary", "stats", "totals", "percentiles",
                  "cache_summary", "control_summary", "cell_stats",
                  "as_dict"}
CONSUMER_NAMES = {"fleet_cache_rollup", "fleet_control_rollup",
                  "fleet_breakdown_rollup", "federated_rollup",
                  "from_summary", "_add_scope", "_add_breakdown"}


def _is_producer(name: str) -> bool:
    return name in PRODUCER_NAMES or name.endswith("_rollup")


def _element_key(node: ast.AST) -> Optional[str]:
    """'k' for 'k' or ('k', ...) elements of a key list."""
    s = str_const(node)
    if s is not None:
        return s
    if isinstance(node, (ast.Tuple, ast.List)) and node.elts:
        return str_const(node.elts[0])
    return None


def _resolve_keys(node: ast.AST,
                  constants: Dict[str, List[str]]) -> Optional[List[str]]:
    """Key strings of a literal list/tuple, a *_KEYS constant name, or a
    ``+`` concatenation of those; None when unresolvable."""
    if isinstance(node, ast.Name):
        return constants.get(node.id)
    if isinstance(node, (ast.Tuple, ast.List)):
        keys = []
        for elt in node.elts:
            key = _element_key(elt)
            if key is None:
                return None
            keys.append(key)
        return keys
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _resolve_keys(node.left, constants)
        right = _resolve_keys(node.right, constants)
        if left is not None and right is not None:
            return left + right
    return None


def _module_constants(tree: ast.Module) -> Dict[str, List[str]]:
    constants: Dict[str, List[str]] = {}
    for stmt in tree.body:
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        name = stmt.targets[0].id
        if not name.endswith("_KEYS"):
            continue
        keys = _resolve_keys(stmt.value, constants)
        if keys is not None:
            constants[name] = keys
    return constants


def _dataclass_fields(tree: ast.Module) -> Dict[str, List[str]]:
    """class name -> annotated field names for @dataclass classes."""
    fields: Dict[str, List[str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = target.attr if isinstance(target, ast.Attribute) \
                else getattr(target, "id", None)
            if name == "dataclass":
                fields[node.name] = [
                    s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)
                ]
                break
    return fields


@register
class SchemaChecker(Checker):
    rule = "SL003"
    title = "summary-schema drift between producers and consumers"

    def __init__(self) -> None:
        self.produced: Set[str] = set()
        # (path, line, function, key) for keys a consumer requires
        self.consumed: List[Tuple[str, int, str, str]] = []

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        assert isinstance(tree, ast.Module)
        constants = _module_constants(tree)
        dc_fields = _dataclass_fields(tree)
        findings: List[Finding] = []
        for func, owner in _functions_with_class(tree):
            if _is_producer(func.name):
                self._collect_produced(func, owner, constants, dc_fields)
            if func.name in CONSUMER_NAMES:
                findings.extend(
                    self._collect_consumed(path, func, constants))
        return findings

    def finalize(self) -> List[Finding]:
        return [
            self.finding(path, line,
                         f"{func} requires summary key '{key}' that no "
                         "producer emits")
            for path, line, func, key in self.consumed
            if key not in self.produced
        ]

    # -- producers --
    def _collect_produced(self, func: ast.AST, owner: Optional[str],
                          constants: Dict[str, List[str]],
                          dc_fields: Dict[str, List[str]]) -> None:
        for node in ast.walk(func):
            if isinstance(node, ast.Dict):
                for key in node.keys:
                    s = str_const(key) if key is not None else None
                    if s is not None:
                        self.produced.add(s)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for target in targets:
                    if isinstance(target, ast.Subscript):
                        s = str_const(target.slice)
                        if s is not None:
                            self.produced.add(s)
            elif isinstance(node, ast.DictComp):
                gen = node.generators[0] if node.generators else None
                if (gen is not None and isinstance(node.key, ast.Name)
                        and isinstance(gen.target, ast.Name)
                        and node.key.id == gen.target.id):
                    keys = _resolve_keys(gen.iter, constants)
                    if keys is not None:
                        self.produced.update(keys)
            elif isinstance(node, ast.Call):
                fn = node.func
                fn_name = fn.attr if isinstance(fn, ast.Attribute) \
                    else getattr(fn, "id", None)
                if (fn_name == "asdict" and owner is not None
                        and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id == "self"):
                    self.produced.update(dc_fields.get(owner, []))

    # -- consumers --
    def _collect_consumed(self, path: str, func: ast.AST,
                          constants: Dict[str, List[str]]) -> List[Finding]:
        findings: List[Finding] = []
        local_literals: Dict[str, Tuple[List[str], int]] = {}
        for node in ast.walk(func):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Load):
                s = str_const(node.slice)
                if s is not None:
                    self.consumed.append((path, node.lineno, func.name, s))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, (ast.Tuple, ast.List)):
                keys = _resolve_keys(node.value, constants)
                if keys is not None and len(keys) >= 3:
                    local_literals[node.targets[0].id] = (keys, node.lineno)
            elif isinstance(node, ast.For):
                inline: Optional[Tuple[List[str], int]] = None
                if isinstance(node.iter, (ast.Tuple, ast.List)):
                    keys = _resolve_keys(node.iter, constants)
                    if keys is not None and len(keys) >= 3:
                        inline = (keys, node.iter.lineno)
                elif isinstance(node.iter, ast.Name):
                    if node.iter.id in constants:
                        for key in constants[node.iter.id]:
                            self.consumed.append(
                                (path, node.lineno, func.name, key))
                    elif node.iter.id in local_literals:
                        inline = local_literals[node.iter.id]
                if inline is not None:
                    keys, line = inline
                    findings.append(self.finding(
                        path, line,
                        f"{func.name} iterates an inline schema key list "
                        f"starting '{keys[0]}'; extract a module-level "
                        "*_KEYS constant as the single source of truth"))
                    for key in keys:
                        self.consumed.append(
                            (path, node.lineno, func.name, key))
        return findings


def _functions_with_class(tree: ast.Module):
    """Yield (FunctionDef, enclosing class name or None) pairs."""
    out = []

    def walk(node: ast.AST, owner: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                walk(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append((child, owner))
                walk(child, owner)
            else:
                walk(child, owner)

    walk(tree, None)
    return out
