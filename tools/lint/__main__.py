"""simlint CLI — see `python -m tools.lint --help`.

Exit status: 0 when every finding is suppressed or baselined, 1 when
new findings exist, 2 on usage errors. CI runs this before tier-1 and
uploads ``--report`` as the findings artifact.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List

from . import CHECKERS, DEFAULT_PATHS, run_paths
from .core import Finding, load_baseline, write_baseline

_PKG_BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"


def main(argv: List[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="simlint: serving-stack invariant checks "
                    f"({', '.join(sorted(CHECKERS))})")
    parser.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                        help="files or directories to lint "
                             f"(default: {' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule subset, e.g. SL001,SL004")
    parser.add_argument("--baseline", type=pathlib.Path,
                        default=_PKG_BASELINE,
                        help="baseline JSON of grandfathered findings")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline file entirely")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write all current findings to the baseline "
                             "and exit 0")
    parser.add_argument("--report", type=pathlib.Path, default=None,
                        help="also write the findings report to this file")
    args = parser.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in CHECKERS]
        if unknown:
            parser.error(f"unknown rule(s): {', '.join(unknown)}")

    findings = run_paths(args.paths, root=pathlib.Path.cwd(), rules=rules)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    new = [f for f in findings if f.key() not in baseline]
    stale = baseline - {f.key() for f in findings}

    lines = [f.render() for f in new]
    summary = (f"simlint: {len(new)} finding(s), "
               f"{len(findings) - len(new)} baselined, "
               f"{len(stale)} stale baseline entr(y/ies)")
    report = "\n".join(lines + [summary]) + "\n"
    if args.report is not None:
        args.report.write_text(report)
    for line in lines:
        print(line)
    if stale:
        print("stale baseline entries (fixed findings — prune them):",
              file=sys.stderr)
        for key in sorted(stale):
            print(f"  {key}", file=sys.stderr)
    print(summary)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
