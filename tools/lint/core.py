"""simlint core: findings, suppressions, baseline handling, the checker
registry, and the two-phase driver.

Checkers are small classes registered by rule id. The driver parses each
file once, hands every checker the (path, tree, source) triple, then —
after all files are seen — calls ``finalize()`` so cross-file rules
(schema drift, event-kind exhaustiveness) can reconcile what producers
and consumers in *different* modules agreed on.

Suppressions: a ``# simlint: disable=SL001[,SL002]`` comment on a line
of its own disables the rule(s) for the whole file; as a trailing
comment it disables them for that line only. The baseline file
(JSON, committed) grandfathers findings by a line-number-free key so
unrelated edits don't resurrect them.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Type

# rule ids only, so trailing justification text never joins the list:
#   # simlint: disable=SL001,SL005  (why this site is legitimate)
_DISABLE_RE = re.compile(
    r"#\s*simlint:\s*disable=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str
    path: str  # posix path relative to the lint root
    line: int
    message: str

    def key(self) -> str:
        """Baseline identity: line numbers drift with unrelated edits, so
        the key is (path, rule, message) only."""
        return f"{self.path}::{self.rule}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class Suppressions:
    """Parsed ``# simlint: disable=...`` comments for one file."""

    def __init__(self, source: str):
        self.file_rules: Set[str] = set()
        self.line_rules: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = _DISABLE_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            if line.lstrip().startswith("#"):
                self.file_rules |= rules
            else:
                self.line_rules.setdefault(lineno, set()).update(rules)

    def hides(self, finding: Finding) -> bool:
        if finding.rule in self.file_rules:
            return True
        return finding.rule in self.line_rules.get(finding.line, set())


class Checker:
    """Base class: per-file pass + optional project-level finalize."""

    rule = "SL000"
    title = "base checker"

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        return []

    def finalize(self) -> List[Finding]:
        return []

    def finding(self, path: str, node, message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(self.rule, path, line, message)


CHECKERS: Dict[str, Type[Checker]] = {}


def register(cls: Type[Checker]) -> Type[Checker]:
    CHECKERS[cls.rule] = cls
    return cls


# ---- baseline ----

def load_baseline(path: Optional[pathlib.Path]) -> Set[str]:
    if path is None or not path.exists():
        return set()
    doc = json.loads(path.read_text())
    return set(doc.get("findings", []))


def write_baseline(path: pathlib.Path, findings: Sequence[Finding]) -> None:
    doc = {
        "comment": "simlint grandfathered findings; regenerate with "
                   "`python -m tools.lint --write-baseline`",
        "findings": sorted({f.key() for f in findings}),
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")


# ---- driver ----

def iter_py_files(paths: Iterable[pathlib.Path]) -> Iterator[pathlib.Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


def run_paths(paths: Sequence, root: Optional[pathlib.Path] = None,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every .py file under ``paths``; return unsuppressed findings
    (baseline filtering is the caller's job — the CLI applies it)."""
    root = pathlib.Path(root) if root is not None else pathlib.Path.cwd()
    active = [CHECKERS[r]() for r in (rules or sorted(CHECKERS))]
    suppressions: Dict[str, Suppressions] = {}
    findings: List[Finding] = []
    for file_path in iter_py_files([pathlib.Path(p) for p in paths]):
        source = file_path.read_text()
        try:
            tree = ast.parse(source, filename=str(file_path))
        except SyntaxError as exc:
            findings.append(Finding("SL000", _rel(file_path, root),
                                    exc.lineno or 0,
                                    f"file does not parse: {exc.msg}"))
            continue
        rel = _rel(file_path, root)
        supp = Suppressions(source)
        suppressions[rel] = supp
        for checker in active:
            for f in checker.check_file(rel, tree, source):
                if not supp.hides(f):
                    findings.append(f)
    for checker in active:
        for f in checker.finalize():
            supp = suppressions.get(f.path)
            if supp is None or not supp.hides(f):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


def _rel(path: pathlib.Path, root: pathlib.Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


# ---- shared AST helpers ----

def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def str_const(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
