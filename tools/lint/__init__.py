"""simlint — AST-based invariant checker for the serving stack.

Mechanically enforces the contracts the paper's latency/throughput
claims rest on: bit-exact deterministic replay (SL001), seconds-
everywhere units (SL002), producer/consumer summary-schema agreement
(SL003), event-kind exhaustiveness (SL004), and Sterbenz-closed latency
accumulation (SL005). See docs/static-analysis.md for the rule table
and the suppression/baseline workflow.

Usage:

    python -m tools.lint [paths...]          # defaults to the CI tree
    python -m tools.lint --write-baseline    # grandfather current findings

Programmatic: ``from tools.lint import run_paths, CHECKERS``.
"""
from .core import (CHECKERS, Checker, Finding, Suppressions,  # noqa: F401
                   iter_py_files, load_baseline, register, run_paths,
                   write_baseline)

# importing the rule modules registers them with CHECKERS
from . import rules_determinism  # noqa: F401,E402
from . import rules_units  # noqa: F401,E402
from . import rules_schema  # noqa: F401,E402
from . import rules_events  # noqa: F401,E402
from . import rules_accumulation  # noqa: F401,E402

DEFAULT_PATHS = ("src/repro/core/serving", "benchmarks", "tools")
