"""SL005 — float-accumulation hygiene for latency attribution.

PR 9's waterfall guarantee — component sums equal end-to-end latency
BIT-EXACTLY — only holds because every latency accumulation goes
through the Sterbenz-closure helpers in ``core/serving/tracing.py``
(or the fleet rollups, which sum already-closed blocks). A bare
``sum(...)`` or ``+=`` loop over latency/breakdown component values
anywhere else reintroduces the float-associativity drift the closure
was built to absorb.

Flags, outside the blessed scopes (``tracing.py`` itself and functions
named ``*_rollup``):

  * builtin ``sum(...)`` whose argument mentions a latency-ish
    identifier (``*latency*``, ``latencies``, ``*_breakdown``, or one of
    the waterfall component names from ``tracing.COMPONENTS``);
  * ``+=`` onto such an identifier inside a ``for``/``while`` loop.

``numpy`` reductions (``np.sum``, ``arr.sum()``) are attribute calls
and pass — pairwise summation is the fix, not the bug. Annotate truly
intentional sites with ``# simlint: disable=SL005``.
"""
from __future__ import annotations

import ast
import pathlib
from typing import List

from .core import Checker, Finding, register

# mirror of tracing.COMPONENTS plus the end-to-end total itself
_COMPONENT_NAMES = {
    "queue_wait", "replica_wait", "dense_compute", "embed_fetch_local",
    "embed_fetch_remote", "shard_transit", "transit", "closure",
    "end_to_end",
}


def _hot(name: str) -> bool:
    low = name.lower()
    return ("latency" in low or low == "latencies"
            or low.endswith("_breakdown") or low in _COMPONENT_NAMES)


def _mentions_hot(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and _hot(sub.id):
            return True
        if isinstance(sub, ast.Attribute) and _hot(sub.attr):
            return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, checker: "AccumulationChecker", path: str):
        self.checker = checker
        self.path = path
        self.findings: List[Finding] = []
        self.loop_depth = 0
        self.blessed_depth = 0

    def _flag(self, node: ast.AST, message: str) -> None:
        if not self.blessed_depth:
            self.findings.append(
                self.checker.finding(self.path, node, message))

    def _visit_func(self, node: ast.AST) -> None:
        blessed = node.name.endswith("_rollup")  # type: ignore[attr-defined]
        self.blessed_depth += blessed
        self.generic_visit(node)
        self.blessed_depth -= blessed

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _visit_loop(self, node: ast.AST) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id == "sum" \
                and node.args and _mentions_hot(node.args[0]):
            self._flag(node, "bare sum() over latency/breakdown components "
                             "drifts under float associativity; use the "
                             "closure helpers in serving/tracing.py or a "
                             "*_rollup")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, ast.Add) and self.loop_depth \
                and _mentions_hot(node.target):
            self._flag(node, "bare += loop accumulation of latency/"
                             "breakdown components; use the closure "
                             "helpers in serving/tracing.py or a *_rollup")
        self.generic_visit(node)


@register
class AccumulationChecker(Checker):
    rule = "SL005"
    title = "float-accumulation hygiene for latency components"

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> List[Finding]:
        if pathlib.PurePosixPath(path).name == "tracing.py":
            return []
        visitor = _Visitor(self, path)
        visitor.visit(tree)
        return visitor.findings
