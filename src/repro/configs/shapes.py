"""Input-shape registry: every (architecture family x shape) cell.

Each shape names a *workload*, not just dimensions: it determines which step
function (`train_step` / `prefill_step` / `decode_step` / `serve_step` /
`retrieval_step`) the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Shape descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMShape:
    """LM-family workload: seq_len x global_batch."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


@dataclasses.dataclass(frozen=True)
class GNNShape:
    """GNN workload."""

    name: str
    n_nodes: int
    n_edges: int
    d_feat: Optional[int]
    kind: str  # "full_batch" | "minibatch" | "batched_small"
    batch_nodes: int = 0  # sampled-training seed nodes
    fanout: tuple = ()  # neighbor-sampler fanout per hop
    graph_batch: int = 0  # batched-small-graphs batch size


@dataclasses.dataclass(frozen=True)
class RecSysShape:
    """RecSys workload."""

    name: str
    batch: int
    kind: str  # "train" | "serve" | "retrieval"
    n_candidates: int = 0


# ---------------------------------------------------------------------------
# The assigned shape sets (verbatim from the assignment)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": LMShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": LMShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": LMShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": LMShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}

GNN_SHAPES = {
    "full_graph_sm": GNNShape(
        "full_graph_sm", n_nodes=2_708, n_edges=10_556, d_feat=1_433, kind="full_batch"
    ),
    "minibatch_lg": GNNShape(
        "minibatch_lg",
        n_nodes=232_965,
        n_edges=114_615_892,
        d_feat=602,
        kind="minibatch",
        batch_nodes=1_024,
        fanout=(15, 10),
    ),
    "ogb_products": GNNShape(
        "ogb_products", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, kind="full_batch"
    ),
    "molecule": GNNShape(
        "molecule", n_nodes=30, n_edges=64, d_feat=None, kind="batched_small", graph_batch=128
    ),
}

RECSYS_SHAPES = {
    "train_batch": RecSysShape("train_batch", batch=65_536, kind="train"),
    "serve_p99": RecSysShape("serve_p99", batch=512, kind="serve"),
    "serve_bulk": RecSysShape("serve_bulk", batch=262_144, kind="serve"),
    "retrieval_cand": RecSysShape(
        "retrieval_cand", batch=1, kind="retrieval", n_candidates=1_000_000
    ),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
}


def shapes_for_family(family: str):
    return FAMILY_SHAPES[family]
