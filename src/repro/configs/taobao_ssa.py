"""The paper's own Baseline model (§V.A): sequential self-attention ranker.

Taobao User Behavior: 1M users, 200K items, behaviour sequences truncated
to 100, candidate set 50. "original FP32 model with self-attention" at
32.0M parameters / 128 MB fp32 (Table I). Layout chosen to land on 32M:
  item table 200K x 64 = 12.8M, user 1M x 16 = 16M, cat 10K x 64 = 0.64M,
  2 self-attn blocks (d=64, 4H, ff=256) + MLP tower 200-80 ~= 2.5M.
The full compression ladder (Quantized / Pruned / P+Q / Distilled) is
applied to THIS model by `core/compression_loop.py` — it is the subject of
benchmarks/bench_table1.py.
"""
from repro.configs.base import FieldSpec, RecSysConfig


def _fields():
    return (
        FieldSpec(name="user", vocab=1_000_000, dim=16),
        FieldSpec(name="item", vocab=200_000),
        FieldSpec(name="category", vocab=10_000),
        FieldSpec(name="hist_item", vocab=200_000, multi_hot=100, shares="item"),
        FieldSpec(name="hist_category", vocab=10_000, multi_hot=100, shares="category"),
    )


def config() -> RecSysConfig:
    return RecSysConfig(
        name="taobao_ssa",
        family="recsys",
        interaction="self_attn_seq",
        embed_dim=64,
        fields=_fields(),
        seq_len=100,
        n_attn_layers=2,
        n_heads=4,
        d_attn=64,
        mlp_dims=(200, 80),
    )
