"""DIN [arXiv:1706.06978] — Deep Interest Network.

embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80, target attention over
the user-behavior sequence. Taobao-scale tables: user / item / category.
"""
from repro.configs.base import FieldSpec, RecSysConfig


def _fields():
    return (
        FieldSpec(name="user", vocab=1_000_000),
        FieldSpec(name="item", vocab=50_000_000),
        FieldSpec(name="category", vocab=200_000),
        # behaviour history: seq_len lookups sharing the item/category tables
        FieldSpec(name="hist_item", vocab=50_000_000, multi_hot=100, shares="item"),
        FieldSpec(name="hist_category", vocab=200_000, multi_hot=100, shares="category"),
    )


def config() -> RecSysConfig:
    return RecSysConfig(
        name="din",
        family="recsys",
        interaction="target_attn",
        embed_dim=18,
        fields=_fields(),
        seq_len=100,
        attn_mlp_dims=(80, 40),
        mlp_dims=(200, 80),
    )
