"""DIEN [arXiv:1809.03672; unverified] — Deep Interest Evolution Network.

embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80, interest extraction GRU +
AUGRU (attention-update-gate GRU) interest evolution.
"""
from repro.configs.base import RecSysConfig
from repro.configs.din import _fields


def config() -> RecSysConfig:
    return RecSysConfig(
        name="dien",
        family="recsys",
        interaction="augru",
        embed_dim=18,
        fields=_fields(),
        seq_len=100,
        gru_dim=108,
        attn_mlp_dims=(80, 40),
        mlp_dims=(200, 80),
    )
