"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias.
Command-R uses parallel attention+FFN blocks and LayerNorm (no bias),
tied embeddings, rope_theta=8M in the HF config.
"""
from repro.configs.base import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="command_r_35b",
        family="lm",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        rope_theta=8_000_000.0,
        use_bias=False,
        norm_type="layernorm",
        parallel_block=True,
        tie_embeddings=True,
    )
