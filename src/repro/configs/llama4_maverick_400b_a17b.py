"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1,
early fusion. Llama-4 interleaves MoE layers (every other layer routed,
`moe_interleave=2`) and adds one always-on shared expert per MoE layer —
that is how 128 top-1 experts with d_ff=8192 lands at ~400B total / ~17B
active. Early-fusion multimodality concerns the (stubbed) modality
frontend only; the backbone below is what we lower.
"""
from repro.configs.base import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="llama4_maverick_400b_a17b",
        family="lm",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=500_000.0,
        use_bias=False,
        norm_type="rmsnorm",
        n_experts=128,
        top_k=1,
        moe_interleave=2,
        n_shared_experts=1,
    )
