"""AutoInt [arXiv:1810.11921] — self-attention feature interaction.

n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2 d_attn=32.
Same Criteo-style 39-field layout as `fm.py`.
"""
from repro.configs.base import RecSysConfig
from repro.configs.fm import _fields


def config() -> RecSysConfig:
    return RecSysConfig(
        name="autoint",
        family="recsys",
        interaction="self_attn",
        embed_dim=16,
        fields=_fields(),
        n_attn_layers=3,
        n_heads=2,
        d_attn=32,
        mlp_dims=(),  # AutoInt scores from the attention output directly
    )
