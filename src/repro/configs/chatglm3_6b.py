"""ChatGLM3-6B [arXiv:2406.12793; hf:THUDM/chatglm3-6b].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 — RoPE 2d, GQA.
GLM applies rotary position embedding to half of the head dimensions
("2d" RoPE) and uses RMSNorm + SwiGLU; QKV has bias, other projections none.
d_ff=13696 is the HF ffn_hidden_size (already the SwiGLU half-width).
"""
from repro.configs.base import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="chatglm3_6b",
        family="lm",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rope_theta=10_000.0,
        rope_fraction=0.5,
        use_bias=True,  # QKV bias (GLM convention)
        norm_type="rmsnorm",
    )
