"""NequIP [arXiv:2101.03164] — E(3)-equivariant interatomic potential.

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3) tensor-product
message passing. Assigned GNN shapes include non-molecular graphs; we
synthesize 3-D positions there (DESIGN.md §3).
"""
from repro.configs.base import GNNConfig


def config() -> GNNConfig:
    return GNNConfig(
        name="nequip",
        family="gnn",
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        d_out=1,
        n_species=64,
    )
