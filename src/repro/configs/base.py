"""Architecture config dataclasses + the --arch registry.

Every assigned architecture gets one module in `repro/configs/` exporting
``config() -> ArchConfig``. `get_config(name)` is the single entry point used
by the launcher, the dry-run, tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str  # "lm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # positional encoding
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # chatglm3 rotates only half the head dims ("2d" RoPE)
    # norm / bias conventions
    use_bias: bool = False
    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    parallel_block: bool = False  # command-r style parallel attn+FFN residual
    tie_embeddings: bool = False
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 0
    moe_interleave: int = 1  # 1: every layer MoE; 2: every other layer (llama4)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "ep"  # "ep" shard_map all-to-all | "gspmd" auto-sharded
    # paper technique C2: hybrid sparse attention (window + sampled globals)
    sparse_attention: bool = False
    attn_window: int = 4_096
    attn_n_global: int = 1_024
    # compute policy
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # "full" | "dots" | "none"  (§Perf knob)
    pad_vocab: bool = False  # pad V to /512 so the LM head shards on vocab
    # distribution policy knobs (hillclimbed in §Perf)
    seq_sharded_residual: bool = False  # Megatron-SP style residual sharding
    attn_impl: str = "chunked"  # "dense" | "chunked" flash-style
    q_chunk: int = 1_024
    flash_remat: bool = False  # remat the flash step (drop per-chunk scores)
    train_layout: str = "fsdp"  # "fsdp" | "tp" weight layout for training
    int8_serve: bool = False  # C5: int8 weights/tables on the serving path

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        dense_ffn = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        n_moe = self.n_layers // self.moe_interleave if self.n_experts else 0
        n_dense = self.n_layers - n_moe
        ffn = n_dense * dense_ffn
        if self.n_experts:
            per_expert = 3 * d * self.d_ff
            ffn += n_moe * (self.n_experts + self.n_shared_experts) * per_expert
            ffn += n_moe * d * self.n_experts  # router
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        norms = self.n_layers * 2 * d + d
        return self.n_layers * attn + ffn + emb + norms

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.d_ff
        n_moe = self.n_layers // self.moe_interleave
        inactive = n_moe * (self.n_experts - self.top_k) * per_expert
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str  # "gnn"
    n_layers: int
    d_hidden: int  # channels per irrep order
    l_max: int
    n_rbf: int
    cutoff: float
    d_out: int = 1  # per-node regression target (energy contribution)
    n_species: int = 64  # atom-type / node-type vocabulary for input embedding
    dtype: str = "float32"
    remat: bool = True
    # §Perf knobs (baseline = False, paper-faithful graph partition on dp axes)
    full_mesh_graph: bool = False  # shard nodes/edges over the WHOLE mesh
    hoist_gathers: bool = False  # one source-feature gather per l, not per path


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One sparse categorical field backed by a (possibly huge) table."""

    name: str
    vocab: int
    multi_hot: int = 1  # nnz per example (EmbeddingBag reduce if > 1)
    dim: int = 0  # 0 -> RecSysConfig.embed_dim
    shares: str = ""  # share the table of another field (e.g. hist_item -> item)


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    family: str  # "recsys"
    interaction: str  # "fm" | "target_attn" | "self_attn" | "augru"
    embed_dim: int
    fields: Tuple[FieldSpec, ...]
    n_dense_feat: int = 0
    mlp_dims: Tuple[int, ...] = ()
    # DIN / DIEN sequential parts
    seq_len: int = 0
    attn_mlp_dims: Tuple[int, ...] = ()
    gru_dim: int = 0
    # AutoInt attention stack
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    dtype: str = "float32"
    # paper compression ladder toggles (C4/C5) — applied by core/, not here
    quantized: bool = False
    pruned: bool = False
    serve_full_mesh: bool = False  # §Perf: shard serve batch over ALL axes

    def owned_fields(self) -> Tuple[FieldSpec, ...]:
        """Fields that own a table (excludes `shares=` aliases)."""
        return tuple(f for f in self.fields if not f.shares)

    def field_dim(self, f: FieldSpec) -> int:
        return f.dim or self.embed_dim

    def table_rows(self) -> int:
        return sum(f.vocab for f in self.owned_fields())

    def param_count(self) -> int:
        emb = sum(f.vocab * self.field_dim(f) for f in self.owned_fields())
        return emb  # towers counted by the model itself; tables dominate


ArchConfig = object  # union marker for type hints


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_NAMES = (
    # LM family
    "command_r_35b",
    "chatglm3_6b",
    "yi_6b",
    "olmoe_1b_7b",
    "llama4_maverick_400b_a17b",
    # GNN
    "nequip",
    # RecSys
    "fm",
    "din",
    "autoint",
    "dien",
    # the paper's own model (self-attention sequential ranker, Table I baseline)
    "taobao_ssa",
)


def get_config(name: str, **overrides):
    """Load `repro.configs.<name>.config()`, optionally overriding fields."""
    name = name.replace("-", "_")
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def family_of(name: str) -> str:
    return get_config(name).family
