"""Yi-6B [arXiv:2403.04652; hf:01-ai/Yi-6B].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 — llama-arch GQA.
"""
from repro.configs.base import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="yi_6b",
        family="lm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        use_bias=False,
        norm_type="rmsnorm",
    )
