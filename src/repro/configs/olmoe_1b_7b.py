"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924].

16L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=1024 vocab=50304,
MoE 64 experts top-8, every layer MoE, no shared expert.
"""
from repro.configs.base import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="olmoe_1b_7b",
        family="lm",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        rope_theta=10_000.0,
        use_bias=False,
        norm_type="rmsnorm",
        n_experts=64,
        top_k=8,
        moe_interleave=1,
        n_shared_experts=0,
    )
