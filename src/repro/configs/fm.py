"""Factorization Machine [Rendle, ICDM'10].

n_sparse=39 fields, embed_dim=10, 2-way FM interaction via the O(nk)
sum-square trick. Criteo-style field layout: 13 dense + 26 categorical =
39 fields total; dense features are bucketized into small vocab tables
(standard production practice) so every field is an embedding lookup.
Vocab sizes follow the Criteo long-tail (three huge 10M-row tables).
"""
from repro.configs.base import FieldSpec, RecSysConfig

# 13 bucketized-dense fields (small vocabs) + 26 categorical (Criteo tails).
_CRITEO_VOCABS = (
    # bucketized dense I1..I13
    [64] * 13
    # categorical C1..C26 — long-tailed, hashed to these sizes
    + [
        1_460, 583, 10_131_227, 2_202_608, 305, 24, 12_517, 633, 3, 93_145,
        5_683, 8_351_593, 3_194, 27, 14_992, 5_461_306, 10, 5_652, 2_173, 4,
        7_046_547, 18, 15, 286_181, 105, 142_572,
    ]
)

assert len(_CRITEO_VOCABS) == 39


def _fields():
    return tuple(
        FieldSpec(name=f"f{i:02d}", vocab=v) for i, v in enumerate(_CRITEO_VOCABS)
    )


def config() -> RecSysConfig:
    return RecSysConfig(
        name="fm",
        family="recsys",
        interaction="fm",
        embed_dim=10,
        fields=_fields(),
        n_dense_feat=0,  # dense feats bucketized into the first 13 fields
        mlp_dims=(),  # pure FM: linear + 2-way interactions, no deep tower
    )
