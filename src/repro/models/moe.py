"""Mixture-of-Experts layer: top-k router + sort-based (dropping) dispatch.

Dispatch strategy: flatten tokens, replicate each token top_k times, sort the
(token, expert) entries by expert id, truncate each expert's queue at a
static capacity C = ceil(top_k * T / E * capacity_factor), run the expert
FFNs as one batched einsum over the [E, C, D] buffer, and scatter results
back weighted by the router probabilities. This is the production
capacity-based scheme (GShard/Switch semantics) expressed with gather/
scatter instead of the O(T*E*C) one-hot einsum, so it lowers at 1M-token
batch sizes. Expert weights are sharded experts->model (EP); the token
buffer C->data — GSPMD inserts the dispatch collectives (baseline; a manual
shard_map all-to-all variant lives in distributed/collectives.py, used by
the §Perf hillclimb).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef
from repro.models.layers import swiglu


def moe_param_defs(cfg, n_moe_layers: int, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = n_moe_layers
    # train: experts -> model (EP), one weight dim FSDP over (pod, data) —
    # the shard_map EP path all-gathers that dim just-in-time (axis=1).
    # serve: experts -> model, contraction dim -> data (tiny decode psum).
    defs = {
        "router": ParamDef(
            (L, d, e), ("layers", None, None), dtype, "fan_in",
        ),
        "w_gate": ParamDef(
            (L, e, d, f), ("layers", "experts", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", "experts", "moe_in", None),
        ),
        "w_up": ParamDef(
            (L, e, d, f), ("layers", "experts", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", "experts", "moe_in", None),
        ),
        "w_down": ParamDef(
            (L, e, f, d), ("layers", "experts", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", "experts", "moe_in", None),
        ),
    }
    if cfg.n_shared_experts:
        s = cfg.n_shared_experts * f
        defs["shared_gate"] = ParamDef(
            (L, d, s), ("layers", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", None, "ff"),
        )
        defs["shared_up"] = ParamDef(
            (L, d, s), ("layers", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", None, "ff"),
        )
        defs["shared_down"] = ParamDef(
            (L, s, d), ("layers", "expert_dp", None), dtype, "fan_in",
            serve_axes=("layers", "tp_in", None),
        )
    return defs


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = math.ceil(top_k * n_tokens / n_experts * factor)
    return max(8, int(c))


def moe_ffn(x, layer_params, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    layer_params holds this layer's slices: router [D,E], w_* [E,D,F].
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(T, E, K, cfg.capacity_factor)

    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt, layer_params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T,E]
    gate, expert_idx = jax.lax.top_k(probs, K)  # [T,K]
    gate = gate / jnp.clip(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1), axis=0
    )  # fraction of tokens per expert
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch with static capacity ----
    flat_e = expert_idx.reshape(-1)  # [T*K] token-major
    order = jnp.argsort(flat_e)  # stable in XLA for equal keys
    sorted_e = flat_e[order]
    first_of_e = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * K) - first_of_e  # rank within expert queue
    kept = pos_in_e < C
    buf_slot = jnp.where(kept, sorted_e * C + pos_in_e, E * C)  # E*C = drop bin
    sorted_tok = order // K

    # gather tokens into the [E*C, D] buffer (dropped entries scattered off-end)
    buffer = jnp.zeros((E * C, D), x.dtype)
    buffer = buffer.at[buf_slot].set(xt[sorted_tok], mode="drop")
    buffer = buffer.reshape(E, C, D)

    # ---- expert FFNs: batched einsum over the expert dim (EP-sharded) ----
    g = jnp.einsum("ecd,edf->ecf", buffer, layer_params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buffer, layer_params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, layer_params["w_down"]).reshape(E * C, D)

    # ---- combine: each (token, k) entry reads back its buffer slot ----
    entry_slot = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.where(kept, buf_slot, -1).astype(jnp.int32), mode="drop"
    )
    entry_out = jnp.where(
        (entry_slot >= 0)[:, None],
        jnp.take(out_buf, jnp.clip(entry_slot, 0), axis=0),
        0.0,
    )  # [T*K, D]
    weighted = entry_out.reshape(T, K, D) * gate[..., None].astype(x.dtype)
    out = jnp.sum(weighted, axis=1)

    # ---- shared expert (always-on, TP-sharded like a dense FFN) ----
    if "shared_gate" in layer_params:
        out = out + swiglu(
            xt,
            layer_params["shared_gate"],
            layer_params["shared_up"],
            layer_params["shared_down"],
        )
    return out.reshape(B, S, D), aux
