"""LM-family transformer: dense + MoE, GQA + RoPE, train/prefill/decode.

One code path serves all five assigned LM architectures. Layers are stacked
and scanned (`jax.lax.scan`) so the HLO stays small at 40+ layers and remat
policy applies uniformly. Workloads:

  loss(params, batch)                 -> scalar CE (+ MoE aux)    [train_4k]
  prefill(params, tokens)             -> (last_logits, kv_cache)  [prefill_32k]
  decode(params, cache, token, pos)   -> (logits, new_cache)      [decode_32k, long_500k]

Sharding: ParamDef.axes (FSDP, training) / .serve_axes (Megatron-TP,
serving); activations constrained token-sharded (batch x seq) for train and
prefill, KV-seq-sharded for decode (split-K flash-decode, psum combine via
GSPMD softmax over the sharded key axis).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.sharding import constrain
from repro.models import moe as moe_lib
from repro.models.common import ParamDef
from repro.models.layers import (
    apply_norm,
    apply_rope,
    decode_attention,
    dense_attention,
    flash_attention,
    sparse_decode_attention,
    swiglu,
)


def _dtype(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _mm(x, w):
    """Representation-dispatched matmul over the last axis of x.

    Dense array, or C5 int8 {"q": int8 [din,dout], "s": f32 [dout]} — long-
    context decode is WEIGHT-read-bound (EXPERIMENTS §Perf), so int8 weights
    quarter the dominant HBM term; on TPU the MXU runs the int8 pairs
    natively (kernels/int8_matmul is the fused tile-level version)."""
    if isinstance(w, dict):
        deq = (w["q"].astype(jnp.bfloat16) * w["s"].astype(jnp.bfloat16)[None, :])
        return jnp.einsum("...d,dh->...h", x, deq.astype(x.dtype))
    return jnp.einsum("...d,dh->...h", x, w)


def _take_rows(table, tokens):
    """Embedding gather over dense or int8 {"q","s"} tables (per-row scales;
    dequantize AFTER the gather — 4x less lookup traffic)."""
    if isinstance(table, dict):
        q = jnp.take(table["q"], tokens, axis=0)
        s = jnp.take(table["s"], tokens, axis=0)
        return q.astype(jnp.float32) * s[..., None]
    return jnp.take(table, tokens, axis=0)


def _moe_layout(cfg: LMConfig) -> Tuple[int, int, int]:
    """(n_super, n_dense_per_super, n_moe_per_super)."""
    if cfg.n_experts == 0:
        return cfg.n_layers, 1, 0
    if cfg.moe_interleave == 1:
        return cfg.n_layers, 0, 1
    assert cfg.moe_interleave == 2 and cfg.n_layers % 2 == 0
    return cfg.n_layers // 2, 1, 1


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


_FSDP_WAYS = 512  # full multi-pod mesh; also divides the 256-chip single pod


def _fsdp_axes(shape, candidates):
    """Put 'fsdp' on the first candidate dim divisible by the full mesh
    (jit in_shardings require exact divisibility); replicate if none fits."""
    axes = [None] * len(shape)
    for dim in candidates:
        if shape[dim] % _FSDP_WAYS == 0:
            axes[dim] = "fsdp"
            break
    return tuple(axes)


def _wdef(shape, lead, candidates, dt, serve_axes):
    axes = (lead,) + _fsdp_axes(shape[1:], candidates) if lead else _fsdp_axes(shape, candidates)
    return ParamDef(shape, axes, dt, "fan_in", serve_axes=serve_axes)


def param_defs(cfg: LMConfig) -> Dict:
    dt = _dtype(cfg)
    D, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    L, V, F = cfg.n_layers, cfg.vocab_size, cfg.d_ff

    attn = {
        "attn_norm": ParamDef((L, D), ("layers", None), dt, "ones"),
        "wq": _wdef((L, D, H * hd), "layers", (0, 1), dt, ("layers", "tp_in", None)),
        "wk": _wdef((L, D, K * hd), "layers", (0, 1), dt, ("layers", "tp_in", None)),
        "wv": _wdef((L, D, K * hd), "layers", (0, 1), dt, ("layers", "tp_in", None)),
        "wo": _wdef((L, H * hd, D), "layers", (0, 1), dt, ("layers", "tp_in", None)),
    }
    if cfg.use_bias:
        attn["bq"] = ParamDef((L, H * hd), ("layers", None), dt, "zeros")
        attn["bk"] = ParamDef((L, K * hd), ("layers", None), dt, "zeros")
        attn["bv"] = ParamDef((L, K * hd), ("layers", None), dt, "zeros")
    if not cfg.parallel_block:
        attn["ffn_norm"] = ParamDef((L, D), ("layers", None), dt, "ones")

    n_super, n_dense, n_moe = _moe_layout(cfg)
    defs: Dict = {"attn": attn}
    if n_dense:
        Ld = n_super * n_dense if cfg.n_experts == 0 else n_super
        defs["ffn"] = {
            "w_gate": _wdef((Ld, D, F), "layers", (0, 1), dt, ("layers", None, "ff")),
            "w_up": _wdef((Ld, D, F), "layers", (0, 1), dt, ("layers", None, "ff")),
            "w_down": _wdef((Ld, F, D), "layers", (0, 1), dt, ("layers", "tp_in", None)),
        }
    if n_moe:
        defs["moe"] = moe_lib.moe_param_defs(cfg, n_super, dt)

    if cfg.pad_vocab:
        # §Perf: pad V to a mesh multiple so the table FSDP-shards on the
        # VOCAB dim — otherwise (e.g. llama4's 202048, olmoe's 50304) it
        # falls back to sharding D, and every logits einsum contracts a
        # sharded dim -> an all-reduce of the full [tokens, V] logits.
        V = -(-V // _FSDP_WAYS) * _FSDP_WAYS
    emb_axes = _fsdp_axes((V, D), (0, 1))
    defs["embed"] = ParamDef((V, D), emb_axes, dt, "embed", serve_axes=("vocab", None))
    defs["final_norm"] = ParamDef((D,), (None,), dt, "ones")
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((V, D), emb_axes, dt, "embed",
                                   serve_axes=("vocab", None))
    return defs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _project_qkv(x, p, cfg: LMConfig, positions):
    """x: [B,S,D] -> q [B,S,K,G,hd] (rope'd), k,v [B,S,K,hd] (rope'd k)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    q = _mm(x, p["wq"])
    k = _mm(x, p["wk"])
    v = _mm(x, p["wv"])
    if cfg.use_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = q.reshape(B, S, K, G, hd)
    return q, k, v


def _attention_train(x, p, cfg: LMConfig, rules):
    """Full-sequence attention (train/prefill). Returns (attn_out, (k, v))."""
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(x, p, cfg, positions)
    # context parallelism: queries stay seq-sharded; K/V gathered (small GQA)
    q = constrain(q, ("batch", "seq", None, None, None), rules)
    k = constrain(k, ("batch", None, None, None), rules)
    v = constrain(v, ("batch", None, None, None), rules)
    if cfg.attn_impl == "dense":
        out = dense_attention(q, k, v, causal=True)
    else:
        out = flash_attention(q, k, v, causal=True, kv_chunk=cfg.q_chunk,
                              remat_step=cfg.flash_remat)
    out = out.reshape(B, S, cfg.n_heads * cfg.resolved_head_dim)
    out = _mm(out, p["wo"])
    return out, (k, v)


def _attention_decode(x, p, cfg: LMConfig, k_cache, v_cache, pos, rules):
    """One-token decode with cache update. x: [B,1,D]; caches [B,T,K,hd]."""
    B = x.shape[0]
    T = k_cache.shape[1]
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[:, None])

    upd = lambda cache, new: jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
    )(cache, new, pos)
    k_cache = upd(k_cache, k_new)
    v_cache = upd(v_cache, v_new)

    if cfg.sparse_attention:
        out = sparse_decode_attention(
            q, k_cache, v_cache, pos, window=cfg.attn_window, n_global=cfg.attn_n_global
        )
    else:
        out = decode_attention(q, k_cache, v_cache, pos)
    out = out.reshape(B, 1, cfg.n_heads * cfg.resolved_head_dim)
    out = _mm(out, p["wo"])
    return out, (k_cache, v_cache)


def _ffn_dense(x, p):
    if any(isinstance(p[k], dict) for k in ("w_gate", "w_up", "w_down")):
        g = _mm(x, p["w_gate"])
        u = _mm(x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return _mm(h, p["w_down"])
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def _layer(x, attn_p, ffn_p, moe_p, cfg: LMConfig, rules, decode_state=None):
    """One transformer layer. decode_state: None | (k_cache, v_cache, pos)."""
    h = apply_norm(x, attn_p["attn_norm"], cfg.norm_type)
    if decode_state is None:
        attn_out, kv = _attention_train(h, attn_p, cfg, rules)
    else:
        k_cache, v_cache, pos = decode_state
        attn_out, kv = _attention_decode(h, attn_p, cfg, k_cache, v_cache, pos, rules)

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        # command-r: shared-norm parallel attention + FFN
        assert moe_p is None, "parallel_block with MoE not used by any assigned arch"
        x = x + attn_out + _ffn_dense(h, ffn_p)
    else:
        x = x + attn_out
        h2 = apply_norm(x, attn_p["ffn_norm"], cfg.norm_type)
        if moe_p is not None:
            # decode (tiny token counts) stays on the auto-sharded path;
            # train/prefill use the shard_map expert-parallel all-to-all.
            if cfg.moe_impl == "ep" and decode_state is None:
                from repro.distributed.expert_parallel import moe_ffn_ep

                ffn_out, aux = moe_ffn_ep(h2, moe_p, cfg, rules)
            else:
                ffn_out, aux = moe_lib.moe_ffn(h2, moe_p, cfg)
        else:
            ffn_out = _ffn_dense(h2, ffn_p)
        x = x + ffn_out
    axes = ("batch", "seq", None) if decode_state is None else ("batch", None, None)
    x = constrain(x, axes, rules)
    return x, kv, aux


def _super_layer(x, params_slice, cfg: LMConfig, rules, decode_state=None):
    """One scan step: dense layer and/or MoE layer according to the layout.

    params_slice: {"attn": [per-super stacked slices], "ffn":?, "moe":?}
    For interleave=2 the attn slices carry a leading dim of 2.
    """
    n_super, n_dense, n_moe = _moe_layout(cfg)
    kvs = []
    aux_total = jnp.zeros((), jnp.float32)

    sub = 0
    attn_all = params_slice["attn"]
    per_super = n_dense + n_moe if cfg.n_experts and cfg.moe_interleave == 2 else 1

    def attn_slice(i):
        if per_super == 1:
            return attn_all
        return jax.tree.map(lambda a: a[i], attn_all)

    ds = decode_state

    def dstate(i):
        if ds is None:
            return None
        k_cache, v_cache, pos = ds
        if per_super == 1:
            return (k_cache, v_cache, pos)
        return (k_cache[i], v_cache[i], pos)

    if cfg.n_experts == 0:
        x, kv, aux = _layer(x, attn_slice(0), params_slice.get("ffn"), None, cfg, rules, dstate(0))
        kvs.append(kv)
        aux_total += aux
    elif cfg.moe_interleave == 1:
        x, kv, aux = _layer(x, attn_slice(0), None, params_slice["moe"], cfg, rules, dstate(0))
        kvs.append(kv)
        aux_total += aux
    else:  # dense then MoE (llama4 interleave)
        x, kv, aux = _layer(x, attn_slice(0), params_slice["ffn"], None, cfg, rules, dstate(0))
        kvs.append(kv)
        aux_total += aux
        x, kv, aux = _layer(x, attn_slice(1), None, params_slice["moe"], cfg, rules, dstate(1))
        kvs.append(kv)
        aux_total += aux

    if per_super == 1:
        kv_out = kvs[0]
    else:
        kv_out = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
    return x, kv_out, aux_total


def _stack_for_scan(params, cfg: LMConfig):
    """Reshape the [L, ...] attention stack to [n_super, per_super, ...]."""
    n_super, n_dense, n_moe = _moe_layout(cfg)
    per_super = 2 if (cfg.n_experts and cfg.moe_interleave == 2) else 1
    scanned = {"attn": params["attn"]}
    if per_super == 2:
        scanned["attn"] = jax.tree.map(
            lambda a: a.reshape((n_super, 2) + a.shape[1:]), params["attn"]
        )
    if "ffn" in params:
        scanned["ffn"] = params["ffn"]
    if "moe" in params:
        scanned["moe"] = params["moe"]
    return scanned, n_super, per_super


# ---------------------------------------------------------------------------
# Trunk: embedding -> scanned layers -> final norm
# ---------------------------------------------------------------------------


def trunk(params, tokens, cfg: LMConfig, rules):
    """tokens [B,S] -> hidden [B,S,D], aux loss, kv caches [L,B,S,K,hd]x2."""
    x = _take_rows(params["embed"], tokens).astype(_dtype(cfg))
    x = constrain(x, ("batch", "seq", None), rules)

    scanned, n_super, per_super = _stack_for_scan(params, cfg)

    def body(x, layer_params):
        x, kv, aux = _super_layer(x, layer_params, cfg, rules)
        return x, (kv, aux)

    if cfg.remat and cfg.remat_policy != "none":
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )
        else:
            body = jax.checkpoint(body, prevent_cse=False)

    x, (kvs, auxes) = jax.lax.scan(body, x, scanned)
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    x = constrain(x, ("batch", "seq", None), rules)
    k_all, v_all = kvs  # [n_super(, per_super), B, S, K, hd]
    if per_super == 2:
        k_all = k_all.reshape((-1,) + k_all.shape[2:])
        v_all = v_all.reshape((-1,) + v_all.shape[2:])
    return x, jnp.sum(auxes), (k_all, v_all)


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------


def _output_table(params):
    return params.get("lm_head", params["embed"])


def _logits(x, table):
    if isinstance(table, dict):
        deq = table["q"].astype(jnp.bfloat16) * table["s"].astype(jnp.bfloat16)[:, None]
        return jnp.einsum("bsd,vd->bsv", x, deq.astype(x.dtype),
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,vd->bsv", x, table, preferred_element_type=jnp.float32)


def chunked_cross_entropy(x, table, labels, n_chunks: int = 8):
    """Streaming-logsumexp CE over vocab chunks; avoids the [B,S,V] buffer.

    x: [B,S,D]; table: [V,D]; labels: [B,S] int32. Returns mean CE.
    """
    B, S, D = x.shape
    V = table.shape[0]
    while V % n_chunks:
        n_chunks //= 2
    vc = V // n_chunks
    chunks = table.reshape(n_chunks, vc, D)
    v0s = jnp.arange(n_chunks) * vc

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S), jnp.float32)
    d0 = jnp.zeros((B, S), jnp.float32)

    def step(carry, ck):
        m, l, dot = carry
        emb_c, v0 = ck
        logits = jnp.einsum(
            "bsd,vd->bsv", x, emb_c, preferred_element_type=jnp.float32
        )
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(logits - m_new[..., None]), -1)
        local = labels - v0
        in_c = (local >= 0) & (local < vc)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, vc - 1)[..., None], axis=-1
        )[..., 0]
        dot = dot + jnp.where(in_c, picked, 0.0)
        return (m_new, l, dot), None

    (m, l, dot), _ = jax.lax.scan(step, (m0, l0, d0), (chunks, v0s))
    ce = (m + jnp.log(jnp.maximum(l, 1e-30))) - dot
    return jnp.mean(ce)


def loss(params, batch, cfg: LMConfig, rules) -> Tuple[jax.Array, Dict]:
    """Next-token CE + MoE load-balance aux."""
    x, aux, _ = trunk(params, batch["tokens"], cfg, rules)
    ce = chunked_cross_entropy(x, _output_table(params), batch["labels"])
    total = ce + 0.01 * aux
    return total, {"ce": ce, "aux": aux}


def prefill(params, tokens, cfg: LMConfig, rules):
    """Full-sequence forward; returns last-position logits + KV caches."""
    x, _, (k_all, v_all) = trunk(params, tokens, cfg, rules)
    last = x[:, -1:, :]
    logits = _logits(last, _output_table(params))
    return logits[:, 0], (k_all, v_all)


def decode(params, cache, token, pos, cfg: LMConfig, rules):
    """One decode step. cache: (k [L,B,T,K,hd], v [L,B,T,K,hd]);
    token: [B] int32; pos: [B] current positions. Returns (logits, cache)."""
    k_all, v_all = cache
    B = token.shape[0]
    x = _take_rows(params["embed"], token[:, None]).astype(_dtype(cfg))
    x = constrain(x, ("batch", None, None), rules)

    scanned, n_super, per_super = _stack_for_scan(params, cfg)
    if per_super == 2:
        k_sc = k_all.reshape((n_super, 2) + k_all.shape[1:])
        v_sc = v_all.reshape((n_super, 2) + v_all.shape[1:])
    else:
        k_sc, v_sc = k_all, v_all

    def body(x, inputs):
        layer_params, k_cache, v_cache = inputs
        x, (k_new, v_new), _ = _super_layer(
            x, layer_params, cfg, rules, decode_state=(k_cache, v_cache, pos)
        )
        return x, (k_new, v_new)

    x, (k_out, v_out) = jax.lax.scan(body, x, (scanned, k_sc, v_sc))
    if per_super == 2:
        k_out = k_out.reshape((-1,) + k_out.shape[2:])
        v_out = v_out.reshape((-1,) + v_out.shape[2:])
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = _logits(x, _output_table(params))
    return logits[:, 0], (k_out, v_out)


# ---------------------------------------------------------------------------
# Cache / batch constructors (shapes only — used by dryrun input_specs too)
# ---------------------------------------------------------------------------


def cache_shape(cfg: LMConfig, batch: int, seq_len: int):
    hd = cfg.resolved_head_dim
    return (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, hd)


def cache_axes(cfg: LMConfig, long_context: bool):
    kv = "long_kv_seq" if long_context else "kv_seq"
    return ("layers", "batch", kv, "kv_heads", "head_dim")
