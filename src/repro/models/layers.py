"""Shared neural-net layers for the LM family (pure functions over pytrees).

Everything here is written against *global* array shapes; GSPMD partitions
according to the logical-axis constraints applied by the caller.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, scale, norm_type: str):
    return rms_norm(x, scale) if norm_type == "rmsnorm" else layer_norm(x, scale)


# ---------------------------------------------------------------------------
# Rotary position embedding (full + fractional / "2d" GLM variant)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, fraction: float, theta: float):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., seq, rot/2]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    # re-interleave
    out = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention — dense, query-chunked (flash-style), decode, sparse decode
# ---------------------------------------------------------------------------


def dense_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    """Reference full attention. q: [B,S,K,G,hd] (GQA-grouped);
    k, v: [B,T,K,hd]. Returns [B,S,K,G,hd]."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = hd**-0.5
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", q * scale, k, preferred_element_type=jnp.float32
    )  # [B,K,G,S,T]
    if causal:
        qpos = jnp.arange(S) + q_offset
        kpos = jnp.arange(T)
        mask = kpos[None, :] <= qpos[:, None]  # [S,T]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def flash_attention(q, k, v, *, causal: bool, kv_chunk: int = 1024, q_offset: int = 0,
                    remat_step: bool = False):
    """KV-chunked streaming-softmax attention (FlashAttention recurrence).

    Scans over key/value chunks carrying (running max, normalizer, weighted
    accumulator), so the live score buffer is [B,K,G,S,kv_chunk] instead of
    [B,K,G,S,T]. The *query* dim S may be sequence-sharded (context
    parallelism): every operation here is pointwise or contracts over the
    chunked key dim, so GSPMD keeps S sharded throughout.

    q: [B,S,K,G,hd]; k, v: [B,T,K,hd]. Returns [B,S,K,G,hd].
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    if T <= kv_chunk:
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    assert T % kv_chunk == 0, (T, kv_chunk)
    nc = T // kv_chunk
    scale = hd**-0.5
    qs = (q * scale).astype(q.dtype)
    qpos = q_offset + jnp.arange(S)  # global query positions

    ks = jnp.moveaxis(k.reshape(B, nc, kv_chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, kv_chunk, K, hd), 1, 0)
    t0s = jnp.arange(nc) * kv_chunk

    m0 = jnp.full((B, K, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, hd), jnp.float32)

    def step(carry, ck):
        m, l, acc = carry
        kc, vc, t0 = ck
        s = jnp.einsum(
            "bskgd,btkd->bkgst", qs, kc, preferred_element_type=jnp.float32
        )  # [B,K,G,S,c]
        if causal:
            kpos = t0 + jnp.arange(kv_chunk)
            mask = kpos[None, :] <= qpos[:, None]  # [S,c]
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v.dtype), vc)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    if remat_step:
        # without this, scan-for-grad saves the [B,K,G,S,c] score blocks of
        # EVERY chunk for backward (~10 GiB/layer at 4k tokens) — remat of
        # the step keeps only the (m, l, acc) carries (§Perf hillclimb 1)
        step = jax.checkpoint(step, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, t0s))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # [B,S,K,G,hd]


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode. q: [B,1,K,G,hd]; caches: [B,T,K,hd]; pos: [B]
    index of the current token (attends to <= pos)."""
    B, _, Kh, G, hd = q.shape
    T = k_cache.shape[1]
    scale = hd**-0.5
    scores = jnp.einsum("bkgd,btkd->bkgt", q[:, 0] * scale, k_cache)
    scores = scores.astype(jnp.float32)
    valid = jnp.arange(T)[None] <= pos[:, None]  # [B,T]
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_cache.dtype), v_cache)
    return out[:, None]  # [B,1,K,G,hd]


def sparse_decode_attention(q, k_cache, v_cache, pos, *, window: int, n_global: int):
    """The paper's C2 hybrid sparse attention at decode time: full attention
    over the trailing `window` positions + `n_global` strided global samples
    (fixed sparse pattern, Formula 4 -> O(w + n_global) per token).

    q: [B,1,K,G,hd]; caches: [B,T,K,hd]; pos: [B].
    """
    B, _, Kh, G, hd = q.shape
    T = k_cache.shape[1]
    window = min(window, T)
    n_global = min(n_global, T)
    scale = hd**-0.5

    # ---- trailing window: dynamic_slice per batch row at pos-window+1 ----
    start = jnp.clip(pos - window + 1, 0, T - window)  # [B]

    def slice_row(cache_row, s):
        return jax.lax.dynamic_slice_in_dim(cache_row, s, window, axis=0)

    k_win = jax.vmap(slice_row)(k_cache, start)  # [B,window,K,hd]
    v_win = jax.vmap(slice_row)(v_cache, start)
    win_pos = start[:, None] + jnp.arange(window)[None]  # [B,window]

    # ---- strided global samples over [0, pos] ----
    # fixed pattern: n_global evenly spaced positions in [0, pos]
    frac = jnp.linspace(0.0, 1.0, n_global)
    gpos = jnp.floor(frac[None] * jnp.maximum(pos[:, None], 1)).astype(jnp.int32)

    def gather_row(cache_row, idx):
        return jnp.take(cache_row, idx, axis=0)

    k_glb = jax.vmap(gather_row)(k_cache, gpos)  # [B,n_global,K,hd]
    v_glb = jax.vmap(gather_row)(v_cache, gpos)

    k_sp = jnp.concatenate([k_win, k_glb], axis=1)  # [B,W+Gb,K,hd]
    v_sp = jnp.concatenate([v_win, v_glb], axis=1)
    sel_pos = jnp.concatenate([win_pos, gpos], axis=1)  # [B,W+Gb]

    scores = jnp.einsum("bkgd,btkd->bkgt", q[:, 0] * scale, k_sp).astype(jnp.float32)
    valid = sel_pos <= pos[:, None]
    # avoid double-counting: global positions inside the window are masked
    in_window = sel_pos >= start[:, None]
    dup = jnp.concatenate(
        [jnp.zeros((B, window), bool), in_window[:, window:]], axis=1
    )
    scores = jnp.where((valid & ~dup)[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v_sp.dtype), v_sp)
    return out[:, None]


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)
