"""Parameter-definition machinery shared by all model families.

A model declares its parameters once as a pytree of `ParamDef`s (shape +
logical axes + init). From that single declaration we derive:
  - abstract params (ShapeDtypeStruct tree) for the AOT dry-run,
  - real initialized params for smoke tests / the end-to-end driver,
  - PartitionSpec / NamedSharding trees for pjit in_shardings.
Keeping these three views in one place is what makes 40 (arch x shape)
cells tractable without sharding-spec drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import pspec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axes for TRAINING (FSDP-style)
    dtype: Any = jnp.float32
    init: str = "fan_in"  # "fan_in" | "normal" | "zeros" | "ones" | "embed"
    scale: float = 1.0
    serve_axes: Optional[Tuple[Optional[str], ...]] = None  # TP-style, for serving

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        if self.serve_axes is not None:
            assert len(self.shape) == len(self.serve_axes)

    def mode_axes(self, serve: bool) -> Tuple[Optional[str], ...]:
        return self.serve_axes if (serve and self.serve_axes is not None) else self.axes


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(defs):
    """ParamDef tree -> ShapeDtypeStruct tree (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_pspecs(defs, rules, serve: bool = False):
    """ParamDef tree -> PartitionSpec tree (training or serving layout)."""
    return jax.tree.map(
        lambda d: pspec(d.mode_axes(serve), rules), defs, is_leaf=is_def
    )


def _init_one(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale * 0.02
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape) * d.scale * 0.02).astype(d.dtype)
    if d.init == "fan_in":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    raise ValueError(d.init)


def init_params(defs, rng):
    """ParamDef tree -> real arrays. Only call at smoke-test scale."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(d, k) for d, k in zip(leaves, keys)])


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) * jnp.dtype(d.dtype).itemsize for d in leaves))
