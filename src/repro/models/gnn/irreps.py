"""Minimal SO(3) irrep algebra for l <= 2 (NequIP substrate).

Representation choice (DESIGN.md §3): l=0 scalars, l=1 as 3-vectors acted on
by R, l=2 as 5-vectors in an orthonormal basis {Q_k} of symmetric-traceless
3x3 matrices acted on by M -> R M Rᵀ. All Clebsch-Gordan coupling paths are
then explicit vector/matrix algebra — manifestly equivariant, no Wigner
machinery, and trivially testable (tests/test_gnn.py rotates inputs and
checks outputs co-rotate). Parity is not tracked (SO(3), not O(3)); noted
as a changed assumption in DESIGN.md.

Feature container: dict {0: [..., C, 1], 1: [..., C, 3], 2: [..., C, 5]}.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

_s2 = 1.0 / np.sqrt(2.0)
_s6 = 1.0 / np.sqrt(6.0)

# Orthonormal (Frobenius) basis of symmetric traceless 3x3 matrices.
_Q = np.zeros((5, 3, 3), np.float32)
_Q[0, 0, 1] = _Q[0, 1, 0] = _s2  # xy
_Q[1, 1, 2] = _Q[1, 2, 1] = _s2  # yz
_Q[2, 0, 2] = _Q[2, 2, 0] = _s2  # xz
_Q[3, 0, 0], _Q[3, 1, 1] = _s2, -_s2  # x² − y²
_Q[4, 0, 0] = _Q[4, 1, 1] = -_s6
_Q[4, 2, 2] = 2 * _s6  # 2z² − x² − y²

Q = jnp.asarray(_Q)  # [5,3,3]

DIM = {0: 1, 1: 3, 2: 5}


def to_matrix(t5: jax.Array) -> jax.Array:
    """[..., 5] -> [..., 3, 3] symmetric traceless."""
    return jnp.einsum("...k,kab->...ab", t5, Q)


def to_vec5(m: jax.Array) -> jax.Array:
    """[..., 3, 3] -> [..., 5] (projects onto the symmetric-traceless part)."""
    return jnp.einsum("...ab,kab->...k", m, Q)


def spherical_harmonics(r: jax.Array) -> Dict[int, jax.Array]:
    """r: [..., 3] displacement -> {l: [..., 2l+1]} of the unit direction.
    Constant normalisation factors only (they fold into learned weights)."""
    n = r / jnp.clip(jnp.linalg.norm(r, axis=-1, keepdims=True), 1e-9)
    y0 = jnp.ones(n.shape[:-1] + (1,), n.dtype)
    y1 = n
    outer = n[..., :, None] * n[..., None, :]
    eye = jnp.eye(3, dtype=n.dtype)
    y2 = to_vec5(outer - eye / 3.0)
    return {0: y0, 1: y1, 2: y2}


# ---------------------------------------------------------------------------
# Tensor-product coupling paths  tp[l1][l2] -> {l_out: fn(a, b)}
# a: [..., d1] feature; b: [..., d2] (broadcastable); out [..., d_out].
# ---------------------------------------------------------------------------


def _p000(a, b):
    return a * b


def _p011(a, b):
    return a * b  # scalar [..,1] × vector [..,3]


def _p022(a, b):
    return a * b


def _p101(a, b):
    return a * b[..., :1] if b.shape[-1] == 1 else a * b


def _p110(a, b):
    return jnp.sum(a * b, axis=-1, keepdims=True)


def _p111(a, b):
    return jnp.cross(a, b)


def _p112(a, b):
    outer = 0.5 * (a[..., :, None] * b[..., None, :] + b[..., :, None] * a[..., None, :])
    tr = (jnp.sum(a * b, axis=-1) / 3.0)[..., None, None]
    return to_vec5(outer - tr * jnp.eye(3, dtype=a.dtype))


def _p121(a, b):
    """vector ⊗ 5-vec -> vector: M(b) · a."""
    return jnp.einsum("...ab,...b->...a", to_matrix(b), a)


def _p122(a, b):
    """vector ⊗ 5-vec -> 5-vec: sym(ε a M)."""
    M = to_matrix(b)
    vxM = jnp.einsum("acd,...c,...db->...ab", _eps(), a, M)
    sym = 0.5 * (vxM + jnp.swapaxes(vxM, -1, -2))
    return to_vec5(sym)


def _p220(a, b):
    return jnp.sum(a * b, axis=-1, keepdims=True)  # Frobenius (basis orthonormal)


def _p221(a, b):
    Ma, Mb = to_matrix(a), to_matrix(b)
    comm = Ma @ Mb - Mb @ Ma
    return jnp.stack(
        [comm[..., 1, 2] - comm[..., 2, 1],
         comm[..., 2, 0] - comm[..., 0, 2],
         comm[..., 0, 1] - comm[..., 1, 0]],
        axis=-1,
    ) * 0.5


def _p222(a, b):
    Ma, Mb = to_matrix(a), to_matrix(b)
    anti = 0.5 * (Ma @ Mb + Mb @ Ma)
    tr = jnp.trace(anti, axis1=-2, axis2=-1)[..., None, None] / 3.0
    return to_vec5(anti - tr * jnp.eye(3, dtype=a.dtype))


def _eps():
    e = np.zeros((3, 3, 3), np.float32)
    e[0, 1, 2] = e[1, 2, 0] = e[2, 0, 1] = 1
    e[0, 2, 1] = e[2, 1, 0] = e[1, 0, 2] = -1
    return jnp.asarray(e)


def _swap(fn):
    return lambda a, b: fn(b, a)


# (l_feat, l_sh) -> {l_out: fn(feat, sh)}
PATHS = {
    (0, 0): {0: _p000},
    (0, 1): {1: _p011},
    (0, 2): {2: _p022},
    (1, 0): {1: _p101},
    (1, 1): {0: _p110, 1: _p111, 2: _p112},
    (1, 2): {1: _p121, 2: _p122},
    (2, 0): {2: lambda a, b: a * b},
    (2, 1): {1: _swap(_p121), 2: _swap(_p122)},
    (2, 2): {0: _p220, 1: _p221, 2: _p222},
}

N_PATHS = sum(len(v) for v in PATHS.values())  # 15


def path_list():
    """Deterministic ordering of (l_feat, l_sh, l_out)."""
    out = []
    for (lf, ls), outs in sorted(PATHS.items()):
        for lo in sorted(outs):
            out.append((lf, ls, lo, PATHS[(lf, ls)][lo]))
    return out


def rotate_features(feats: Dict[int, jax.Array], R: jax.Array) -> Dict[int, jax.Array]:
    """Apply a rotation to an irrep feature dict (for equivariance tests)."""
    out = {}
    if 0 in feats:
        out[0] = feats[0]
    if 1 in feats:
        out[1] = jnp.einsum("ab,...b->...a", R, feats[1])
    if 2 in feats:
        M = to_matrix(feats[2])
        out[2] = to_vec5(jnp.einsum("ac,...cd,bd->...ab", R, M, R))
    return out
