"""NequIP [arXiv:2101.03164] — E(3)-equivariant message passing, TPU-adapted.

Per interaction layer, per edge (j -> i):
  Y_l(r̂_ij)            spherical harmonics of the edge direction
  R(|r_ij|)            radial MLP on an RBF expansion x cutoff envelope,
                       emitting one weight per (coupling path x channel)
  m_ij^{l_out}         = Σ_paths w_path ⊙ CG(feat_j^{l_in} ⊗ Y^{l_sh})
  agg_i                = segment_sum over incoming edges   <- THE scatter op
  feat_i               = gate( self_interact(feat_i) + agg_i )

Message passing is jax.ops.segment_sum over an edge index (JAX is BCOO-only
— the scatter IS the system, per kernel taxonomy §GNN). Layers are scanned;
graphs batch by flattening with graph ids. Non-molecular assigned shapes
synthesize 3-D positions and project node features to l=0 channels.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef
from repro.models.gnn import irreps
from repro.models.gnn.irreps import DIM, N_PATHS, path_list, spherical_harmonics


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def param_defs(cfg: GNNConfig, d_feat: Optional[int] = None, n_classes: int = 1) -> Dict:
    C, L, R = cfg.d_hidden, cfg.n_layers, cfg.n_rbf
    rad_hidden = 32
    defs: Dict = {}
    if d_feat:  # Cora/products-style continuous node features -> scalars
        defs["feat_proj"] = ParamDef((d_feat, C), (None, None), jnp.float32, "fan_in")
    else:
        defs["species"] = ParamDef((cfg.n_species, C), (None, None), jnp.float32, "embed")
    defs["layers"] = {
        "rad_w1": ParamDef((L, R, rad_hidden), ("layers", "rbf", None), jnp.float32, "fan_in"),
        "rad_b1": ParamDef((L, rad_hidden), ("layers", None), jnp.float32, "zeros"),
        "rad_w2": ParamDef((L, rad_hidden, N_PATHS * C), ("layers", None, None), jnp.float32, "fan_in"),
        # per-l self interactions (channel mixing) + residual weight
        "self_0": ParamDef((L, C, C), ("layers", None, None), jnp.float32, "fan_in"),
        "self_1": ParamDef((L, C, C), ("layers", None, None), jnp.float32, "fan_in"),
        "self_2": ParamDef((L, C, C), ("layers", None, None), jnp.float32, "fan_in"),
        # gates for l=1,2 from scalar channels
        "gate_w": ParamDef((L, C, 2 * C), ("layers", None, None), jnp.float32, "fan_in"),
        "gate_b": ParamDef((L, 2 * C), ("layers", None), jnp.float32, "zeros"),
    }
    defs["out_w1"] = ParamDef((C, C), (None, None), jnp.float32, "fan_in")
    defs["out_b1"] = ParamDef((C,), (None,), jnp.float32, "zeros")
    defs["out_w2"] = ParamDef((C, n_classes), (None, None), jnp.float32, "fan_in")
    defs["out_b2"] = ParamDef((n_classes,), (None,), jnp.float32, "zeros")
    return defs


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------


def radial_basis(dist: jax.Array, cfg: GNNConfig) -> jax.Array:
    """Gaussian RBF x smooth cosine cutoff. dist: [E] -> [E, n_rbf]."""
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = cfg.n_rbf / cfg.cutoff
    rbf = jnp.exp(-gamma * jnp.square(dist[:, None] - mu[None]))
    fc = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    return rbf * fc[:, None]


def interaction_layer(feats, lp, edge_src, edge_dst, sh, rbf, n_nodes, cfg, rules):
    """One NequIP interaction. feats: {l: [N,C,2l+1]}; lp: this layer's params."""
    C = cfg.d_hidden
    # radial MLP -> per-edge path weights [E, n_paths, C]
    h = jax.nn.silu(rbf @ lp["rad_w1"] + lp["rad_b1"])
    w = (h @ lp["rad_w2"]).reshape(-1, N_PATHS, C)

    # messages: gather source features, couple with SH, weight, accumulate
    gathered = {}
    if cfg.hoist_gathers:
        # §Perf: one [E,C,d] gather per l (3 total) instead of one per
        # coupling path (15) — 5x fewer cross-shard node-feature reads.
        for l in range(cfg.l_max + 1):
            gathered[l] = jnp.take(feats[l], edge_src, axis=0)

    msgs = {l: 0.0 for l in range(cfg.l_max + 1)}
    for p_idx, (lf, ls, lo, fn) in enumerate(path_list()):
        if lf > cfg.l_max or ls > cfg.l_max or lo > cfg.l_max:
            continue
        src_feat = gathered.get(lf)
        if src_feat is None:
            src_feat = jnp.take(feats[lf], edge_src, axis=0)  # [E,C,2lf+1]
        y = sh[ls][:, None, :]  # [E,1,2ls+1]
        coupled = fn(src_feat, y)  # [E,C,2lo+1]
        msgs[lo] = msgs[lo] + coupled * w[:, p_idx, :, None]

    new = {}
    for l in range(cfg.l_max + 1):
        agg = jax.ops.segment_sum(msgs[l], edge_dst, num_segments=n_nodes)
        agg = constrain(agg, ("nodes", None, None), rules)
        mixed = jnp.einsum("ncd,ce->ned", feats[l], lp[f"self_{l}"])
        new[l] = mixed + agg

    # gate nonlinearity
    s = new[0][..., 0]  # [N,C]
    gates = jax.nn.sigmoid(s @ lp["gate_w"] + lp["gate_b"])  # [N,2C]
    out = {0: jax.nn.silu(s)[..., None]}
    if cfg.l_max >= 1:
        out[1] = new[1] * gates[:, :C, None]
    if cfg.l_max >= 2:
        out[2] = new[2] * gates[:, C:, None]
    # residual
    return {l: out[l] + feats[l] for l in out}


# ---------------------------------------------------------------------------
# Forward / losses
# ---------------------------------------------------------------------------


def forward(params, graph, cfg: GNNConfig, rules):
    """graph: {positions [N,3], edge_src [E], edge_dst [E],
    species [N] | features [N,d_feat], (edge_mask [E], node_mask [N])}.
    Returns per-node output [N, n_out]."""
    pos = graph["positions"]
    src, dst = graph["edge_src"], graph["edge_dst"]
    n_nodes = pos.shape[0]

    r = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)  # j -> i
    dist = jnp.linalg.norm(r + 1e-12, axis=-1)
    sh = spherical_harmonics(r)
    if "edge_mask" in graph:
        m = graph["edge_mask"][:, None].astype(pos.dtype)
        sh = {l: y * m for l, y in sh.items()}
    sh = {l: constrain(y, ("edges", None), rules) for l, y in sh.items()}
    rbf = radial_basis(dist, cfg)

    C = cfg.d_hidden
    if "features" in graph:
        s0 = graph["features"] @ params["feat_proj"]
    else:
        s0 = jnp.take(params["species"], graph["species"], axis=0)
    feats = {0: s0[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n_nodes, C, DIM[l]), s0.dtype)
    feats = {l: constrain(f, ("nodes", None, None), rules) for l, f in feats.items()}

    def body(feats, lp):
        out = interaction_layer(feats, lp, src, dst, sh, rbf, n_nodes, cfg, rules)
        return out, ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    feats, _ = jax.lax.scan(body_fn, feats, params["layers"])

    s = feats[0][..., 0]
    h = jax.nn.silu(s @ params["out_w1"] + params["out_b1"])
    return h @ params["out_w2"] + params["out_b2"]


def node_class_loss(params, batch, cfg: GNNConfig, rules):
    """Full-batch / sampled node classification (Cora, Reddit, products)."""
    out = forward(params, batch, cfg, rules)  # [N, n_classes]
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        loss = jnp.sum(nll) / jnp.clip(jnp.sum(mask), 1)
    else:
        loss = jnp.mean(nll)
    return loss, {"nll": loss}


def energy_loss(params, batch, cfg: GNNConfig, rules):
    """Batched molecular energy regression: per-node contributions summed
    per graph via segment_sum over graph ids."""
    out = forward(params, batch, cfg, rules)[:, 0]  # [N]
    if "node_mask" in batch:
        out = jnp.where(batch["node_mask"], out, 0.0)
    n_graphs = batch["energies"].shape[0]
    e = jax.ops.segment_sum(out, batch["graph_ids"], num_segments=n_graphs)
    loss = jnp.mean(jnp.square(e - batch["energies"]))
    return loss, {"mse": loss}
