"""Host-side fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

Produces static-shape padded subgraphs so the jitted train step never
re-specializes: seeds x (1 + f1 + f1*f2) node slots, seeds x (f1 + f1*f2)
edge slots, with masks for padding. CSR adjacency is built once on the
host (numpy); sampling is vectorized numpy — this runs in the input
pipeline workers, not on device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        src_s = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=src_s, n_nodes=n_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int, rng) -> np.ndarray:
        """[B] -> [B, fanout] sampled in-neighbors (with replacement;
        isolated nodes self-loop)."""
        start = self.indptr[nodes]
        deg = self.indptr[nodes + 1] - start
        r = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(nodes), fanout))
        idx = start[:, None] + r
        out = self.indices[np.minimum(idx, len(self.indices) - 1)]
        return np.where(deg[:, None] > 0, out, nodes[:, None])


def sample_subgraph(
    g: CSRGraph, seeds: np.ndarray, fanout: Tuple[int, ...], rng
) -> Dict[str, np.ndarray]:
    """Layer-wise fanout sampling -> flat padded subgraph arrays.

    Returns local-id arrays: node_ids [N_sub] (global ids for feature
    fetch), edge_src/edge_dst [E_sub] (local), seed_mask [N_sub].
    Shapes depend only on (len(seeds), fanout) — static under jit.
    """
    frontiers = [seeds]
    edges_src_g, edges_dst_g = [], []
    for f in fanout:
        cur = frontiers[-1]
        nbrs = g.sample_neighbors(cur, f, rng)  # [B, f] global
        edges_src_g.append(nbrs.reshape(-1))
        edges_dst_g.append(np.repeat(cur, f))
        frontiers.append(nbrs.reshape(-1))

    node_ids = np.concatenate(frontiers)  # duplicates allowed (static shape)
    # edges reference the frontier layout directly (no dedup -> static shapes):
    offs = np.cumsum([0] + [len(f) for f in frontiers])
    edge_src_l, edge_dst_l = [], []
    for li, f in enumerate(fanout):
        n_dst = len(frontiers[li])
        src_slots = offs[li + 1] + np.arange(n_dst * f)
        dst_slots = offs[li] + np.repeat(np.arange(n_dst), f)
        edge_src_l.append(src_slots)
        edge_dst_l.append(dst_slots)

    return {
        "node_ids": node_ids.astype(np.int32),
        "edge_src": np.concatenate(edge_src_l).astype(np.int32),
        "edge_dst": np.concatenate(edge_dst_l).astype(np.int32),
        "seed_mask": (np.arange(len(node_ids)) < len(seeds)),
    }


def subgraph_sizes(n_seeds: int, fanout: Tuple[int, ...]) -> Tuple[int, int]:
    """Static (n_nodes, n_edges) of a sampled subgraph."""
    n_nodes, n_edges, layer = n_seeds, 0, n_seeds
    for f in fanout:
        n_edges += layer * f
        layer *= f
        n_nodes += layer
    return n_nodes, n_edges
