"""DIN [arXiv:1706.06978]: target attention over the behaviour sequence.

Behaviour unit = item_emb ⊕ category_emb (2·de). Attention features per
(candidate, step): [h, c, h−c, h·c] -> MLP(80,40) -> masked softmax ->
weighted sum. Tower: [user, cand, pooled] -> MLP(200,80) -> logit.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.distributed.sharding import constrain
from repro.models.recsys.embedding import field_lookup, named_table_defs
from repro.models.recsys.rec_layers import bce_with_logits, mlp_apply, mlp_defs


def param_defs(cfg: RecSysConfig) -> Dict:
    de = cfg.embed_dim
    du = 2 * de  # behaviour-unit dim
    defs: Dict = {"tables": named_table_defs(cfg)}
    defs.update(mlp_defs("attn", 4 * du, cfg.attn_mlp_dims))
    tower_in = de + du + du  # user + candidate + pooled
    defs.update(mlp_defs("tower", tower_in, cfg.mlp_dims))
    return defs


def _behaviour_emb(params, batch, cfg, rules, hist: bool):
    t = params["tables"]
    if hist:
        it = field_lookup(t, cfg, "hist_item", batch["hist_item"], rules)
        ca = field_lookup(t, cfg, "hist_category", batch["hist_category"], rules)
    else:
        it = field_lookup(t, cfg, "item", batch["item"], rules)
        ca = field_lookup(t, cfg, "category", batch["category"], rules)
    return jnp.concatenate([it, ca], axis=-1)  # [..., 2de]


def target_attention(params, hist, cand, hist_mask, cfg):
    """hist: [B,L,du]; cand: [B,du] -> pooled [B,du]."""
    B, L, du = hist.shape
    c = jnp.broadcast_to(cand[:, None, :], hist.shape)
    feats = jnp.concatenate([hist, c, hist - c, hist * c], axis=-1)  # [B,L,4du]
    att = mlp_apply(params, "attn", feats, len(cfg.attn_mlp_dims))[..., 0]  # [B,L]
    att = jnp.where(hist_mask, att, -1e30)
    w = jax.nn.softmax(att.astype(jnp.float32), axis=-1).astype(hist.dtype)
    return jnp.einsum("bl,bld->bd", w, hist)


def logits(params, batch, cfg: RecSysConfig, rules):
    user = field_lookup(params["tables"], cfg, "user", batch["user"], rules)
    hist = _behaviour_emb(params, batch, cfg, rules, hist=True)  # [B,L,du]
    cand = _behaviour_emb(params, batch, cfg, rules, hist=False)  # [B,du]
    mask = jnp.arange(hist.shape[1])[None] < batch["hist_len"][:, None]
    pooled = target_attention(params, hist, cand, mask, cfg)
    x = jnp.concatenate([user, cand, pooled], axis=-1)
    out = mlp_apply(params, "tower", x, len(cfg.mlp_dims))[:, 0]
    return constrain(out, ("batch",), rules)


def loss(params, batch, cfg: RecSysConfig, rules):
    lg = logits(params, batch, cfg, rules)
    b = bce_with_logits(lg, batch["label"])
    return b, {"bce": b}


def serve(params, batch, cfg: RecSysConfig, rules):
    return jax.nn.sigmoid(logits(params, batch, cfg, rules))


def retrieval(params, query, cand_ids, cfg: RecSysConfig, rules):
    """One user, N candidate items: history encoded once, target attention
    batched over candidates (the N dim is sharded over the mesh)."""
    t = params["tables"]
    user = field_lookup(t, cfg, "user", query["user"], rules)[0]  # [de]
    hist = _behaviour_emb(params, query, cfg, rules, hist=True)[0]  # [L,du]
    mask = jnp.arange(hist.shape[0])[None] < query["hist_len"][:, None]  # [1,L]

    it = jnp.take(t["item"], cand_ids, axis=0)
    ca_ids = query["cand_category"]
    ca = jnp.take(t["category"], ca_ids, axis=0)
    cand = jnp.concatenate([it, ca], axis=-1)  # [N,du]
    cand = constrain(cand, ("candidates", None), rules)

    N = cand.shape[0]
    histN = jnp.broadcast_to(hist[None], (N,) + hist.shape)
    pooled = target_attention(params, histN, cand, jnp.broadcast_to(mask, (N, hist.shape[0])), cfg)
    userN = jnp.broadcast_to(user[None], (N, user.shape[0]))
    x = jnp.concatenate([userN, cand, pooled], axis=-1)
    scores = mlp_apply(params, "tower", x, len(cfg.mlp_dims))[:, 0]
    return constrain(scores, ("candidates",), rules)
