"""AutoInt [arXiv:1810.11921]: multi-head self-attention over field embeddings.

Layer l: Q,K,V projections of the [B, F, d_l] field matrix, softmax over the
field axis, residual via a linear map, ReLU. After n layers the flattened
field matrix feeds a linear scorer. This is the assigned arch where the
paper's C1 (grouped/low-rank projections) and C5 (int8) apply most directly.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef
from repro.models.recsys.embedding import unified_lookup, unified_offsets, unified_table_def
from repro.models.recsys.rec_layers import bce_with_logits


def _dims(cfg: RecSysConfig):
    d_out = cfg.n_heads * cfg.d_attn
    ins = [cfg.embed_dim] + [d_out] * (cfg.n_attn_layers - 1)
    return ins, d_out


def param_defs(cfg: RecSysConfig) -> Dict:
    ins, d_out = _dims(cfg)
    defs: Dict = {"table": unified_table_def(cfg)}
    for l, d_in in enumerate(ins):
        defs[f"attn{l}"] = {
            "wq": ParamDef((d_in, d_out), (None, None), jnp.float32, "fan_in"),
            "wk": ParamDef((d_in, d_out), (None, None), jnp.float32, "fan_in"),
            "wv": ParamDef((d_in, d_out), (None, None), jnp.float32, "fan_in"),
            "w_res": ParamDef((d_in, d_out), (None, None), jnp.float32, "fan_in"),
        }
    F = len(cfg.fields)
    defs["w_out"] = ParamDef((F * d_out, 1), (None, None), jnp.float32, "fan_in")
    defs["b_out"] = ParamDef((1,), (None,), jnp.float32, "zeros")
    return defs


def _interact(params, e, cfg: RecSysConfig):
    """e: [B, F, d0] -> [B, F, d_out] through the attention stack."""
    H, da = cfg.n_heads, cfg.d_attn
    x = e
    for l in range(cfg.n_attn_layers):
        p = params[f"attn{l}"]
        B, F, _ = x.shape
        q = (x @ p["wq"]).reshape(B, F, H, da)
        k = (x @ p["wk"]).reshape(B, F, H, da)
        v = (x @ p["wv"]).reshape(B, F, H, da)
        s = jnp.einsum("bfhd,bghd->bhfg", q, k) / jnp.sqrt(da)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, H * da)
        x = jax.nn.relu(o + x @ p["w_res"])
    return x


def logits(params, batch, cfg: RecSysConfig, rules):
    e = unified_lookup(params["table"], batch["sparse_idx"], cfg, rules)
    x = _interact(params, e, cfg)
    B = x.shape[0]
    out = x.reshape(B, -1) @ params["w_out"] + params["b_out"]
    return constrain(out[:, 0], ("batch",), rules)


def loss(params, batch, cfg: RecSysConfig, rules):
    lg = logits(params, batch, cfg, rules)
    b = bce_with_logits(lg, batch["label"])
    return b, {"bce": b}


def serve(params, batch, cfg: RecSysConfig, rules):
    return jax.nn.sigmoid(logits(params, batch, cfg, rules))


def retrieval(params, query, cand_ids, cfg: RecSysConfig, rules):
    """Broadcast the 38 user-field embeddings; swap the candidate field's
    embedding per candidate; full attention stack over [N, F, d]."""
    cand_field = max(range(len(cfg.fields)), key=lambda i: cfg.fields[i].vocab)
    offs = unified_offsets(cfg)
    e = unified_lookup(params["table"], query["sparse_idx"], cfg, rules)[0]  # [F,d]
    v_c = jnp.take(params["table"], cand_ids + int(offs[cand_field]), axis=0)
    v_c = constrain(v_c, ("candidates", None), rules)
    N = v_c.shape[0]
    eN = jnp.broadcast_to(e[None], (N,) + e.shape)
    eN = eN.at[:, cand_field, :].set(v_c)
    eN = constrain(eN, ("candidates", None, None), rules)
    x = _interact(params, eN, cfg)
    scores = (x.reshape(N, -1) @ params["w_out"] + params["b_out"])[:, 0]
    return constrain(scores, ("candidates",), rules)
