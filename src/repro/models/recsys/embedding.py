"""Sparse embedding substrate for the recsys family.

JAX has no native EmbeddingBag and no CSR sparse — the lookup-and-reduce is
built here from `jnp.take` + `jax.ops.segment_sum`, as part of the system
(kernel_taxonomy §RecSys). Two layouts:

  * unified table — all equal-dim fields concatenated into ONE [sum_vocab, d]
    table with static per-field offsets: a single gather serves a whole
    example row ([B, n_fields] indices). This is the production layout
    (FBGEMM TBE-style) and makes the table the explicit hot path; rows are
    sharded over the `model` mesh axis.
  * named tables — per-field tables for heterogeneous dims (user 16-d vs
    item 64-d in taobao_ssa), with `shares=` aliasing (history reuses the
    item table).

A Pallas VMEM-tiled version of the bag lookup lives in
kernels/embedding_bag; the functions here are the pure-jnp system path and
the kernel's oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FieldSpec, RecSysConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef


# ---------------------------------------------------------------------------
# Unified-table layout (equal-dim fields: fm / autoint)
# ---------------------------------------------------------------------------


def unified_offsets(cfg: RecSysConfig) -> np.ndarray:
    """Static row offsets of each field inside the unified table."""
    offs = np.zeros(len(cfg.fields), np.int64)
    acc = 0
    for i, f in enumerate(cfg.fields):
        offs[i] = acc
        acc += f.vocab
    return offs


def _pad_rows(rows: int, multiple: int = 512) -> int:
    """Row-sharded tables must divide the full (pod x data x model) mesh;
    pad rows up — padding rows are never addressed by real ids."""
    return -(-rows // multiple) * multiple


def unified_table_def(cfg: RecSysConfig, extra_dim: int = 0) -> ParamDef:
    rows = _pad_rows(cfg.table_rows())
    d = (extra_dim or cfg.embed_dim)
    return ParamDef((rows, d), ("rows", None), jnp.float32, "embed")


def _take_rows(table, rows):
    """Gather rows from a table in any representation (fp32 dense or C5
    int8-quantized {"q": int8 [V,d], "s": f32 [V]} with per-row scales —
    dequantization happens *after* the gather, so HBM traffic is 1/4)."""
    if isinstance(table, dict):
        q = jnp.take(table["q"], rows, axis=0)
        s = jnp.take(table["s"], rows, axis=0)
        return q.astype(jnp.float32) * s[..., None]
    return jnp.take(table, rows, axis=0)


def unified_lookup(table, sparse_idx, cfg: RecSysConfig, rules):
    """sparse_idx: [B, n_fields] per-field local ids -> [B, n_fields, d]."""
    offs = jnp.asarray(unified_offsets(cfg), jnp.int32)
    rows = sparse_idx + offs[None, :]
    out = _take_rows(table, rows)
    return constrain(out, ("batch", None, None), rules)


# ---------------------------------------------------------------------------
# EmbeddingBag: multi-hot gather + segment reduce
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jax.Array,
    idx: jax.Array,
    mask: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent.

    table: [V, d]; idx: [B, nnz] int32; mask: [B, nnz] (1 = valid).
    Implemented as flat gather + segment_sum over row ids so the reduce is
    expressed with the canonical JAX scatter primitive (not just a masked
    sum) — this is the reference the Pallas kernel is tested against.
    """
    B, nnz = idx.shape
    flat = _take_rows(table, idx.reshape(-1))  # [B*nnz, d]
    if mask is not None:
        flat = flat * mask.reshape(-1, 1).astype(flat.dtype)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), nnz)
    out = jax.ops.segment_sum(flat, seg, num_segments=B)
    if combiner == "mean":
        denom = (
            jnp.clip(mask.sum(axis=1), 1)[:, None].astype(out.dtype)
            if mask is not None
            else jnp.full((B, 1), nnz, out.dtype)
        )
        out = out / denom
    return out


# ---------------------------------------------------------------------------
# Named per-field tables (din / dien / taobao_ssa)
# ---------------------------------------------------------------------------


def named_table_defs(cfg: RecSysConfig) -> Dict[str, ParamDef]:
    defs = {}
    for f in cfg.owned_fields():
        d = cfg.field_dim(f)
        defs[f.name] = ParamDef((_pad_rows(f.vocab), d), ("rows", None), jnp.float32, "embed")
    return defs


def table_for(params_tables, cfg: RecSysConfig, field_name: str):
    f = {f.name: f for f in cfg.fields}[field_name]
    return params_tables[f.shares or f.name]


def field_lookup(params_tables, cfg: RecSysConfig, field_name: str, idx, rules):
    """Single- or multi-hot lookup for one named field."""
    t = table_for(params_tables, cfg, field_name)
    out = _take_rows(t, idx)
    axes = ("batch",) + (None,) * (out.ndim - 1)
    return constrain(out, axes, rules)
