"""Factorization Machine [Rendle ICDM'10].

logit = w0 + Σᵢ wᵢ + ½ Σ_d [(Σᵢ vᵢ)² − Σᵢ vᵢ²]_d   (the O(nk) sum-square trick)

The first-order term is an EmbeddingBag (dim-1) over the unified table; the
second-order term's fused form is also provided as a Pallas kernel
(kernels/fm_interaction) with this module as its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef
from repro.models.recsys.embedding import (
    embedding_bag,
    unified_lookup,
    unified_offsets,
    unified_table_def,
)
from repro.models.recsys.rec_layers import bce_with_logits


def param_defs(cfg: RecSysConfig):
    return {
        "table": unified_table_def(cfg),  # [rows, k] second-order factors
        "linear": unified_table_def(cfg, extra_dim=1),  # [rows, 1] first-order
        "bias": ParamDef((), (), jnp.float32, "zeros"),
    }


def fm_interaction(e: jax.Array) -> jax.Array:
    """e: [B, F, k] -> [B] second-order term via the sum-square identity."""
    s = jnp.sum(e, axis=1)  # Σ vᵢxᵢ
    sq = jnp.sum(jnp.square(e), axis=1)  # Σ (vᵢxᵢ)²
    return 0.5 * jnp.sum(jnp.square(s) - sq, axis=-1)


def logits(params, batch, cfg: RecSysConfig, rules):
    idx = batch["sparse_idx"]  # [B, F] local ids
    e = unified_lookup(params["table"], idx, cfg, rules)  # [B,F,k]
    offs = jnp.asarray(unified_offsets(cfg), jnp.int32)
    rows = idx + offs[None, :]
    first = embedding_bag(params["linear"], rows)[:, 0]  # [B]
    out = params["bias"] + first + fm_interaction(e)
    return constrain(out, ("batch",), rules)


def loss(params, batch, cfg: RecSysConfig, rules):
    lg = logits(params, batch, cfg, rules)
    return bce_with_logits(lg, batch["label"]), {"bce": bce_with_logits(lg, batch["label"])}


def serve(params, batch, cfg: RecSysConfig, rules):
    return jax.nn.sigmoid(logits(params, batch, cfg, rules))


def retrieval(params, query, cand_ids, cfg: RecSysConfig, rules):
    """Score one query against N candidates of the designated candidate
    field (largest-vocab field). FM factorizes: score(c) = const +
    ⟨Σ_{f≠c} v_f, v_c⟩ + w_c, so it is one [N,k] @ [k] batched dot."""
    cand_field = max(range(len(cfg.fields)), key=lambda i: cfg.fields[i].vocab)
    offs = unified_offsets(cfg)

    idx = query["sparse_idx"]  # [1, F] — candidate slot ignored
    e = unified_lookup(params["table"], idx, cfg, rules)[0]  # [F,k]
    mask = jnp.arange(e.shape[0]) != cand_field
    e_user = jnp.sum(e * mask[:, None], axis=0)  # [k]

    rows = cand_ids + int(offs[cand_field])
    v_c = jnp.take(params["table"], rows, axis=0)  # [N,k]
    v_c = constrain(v_c, ("candidates", None), rules)
    w_c = jnp.take(params["linear"], rows, axis=0)[:, 0]

    dot = v_c @ e_user
    # (e_u+v_c)² − (sq_u+v_c²) = (e_u²−sq_u) + 2⟨e_u,v_c⟩ — v_c² cancels.
    sq_u = jnp.sum(jnp.square(e * mask[:, None]), axis=0)
    const = 0.5 * jnp.sum(jnp.square(e_user) - sq_u)
    scores = const + dot + w_c
    return constrain(scores, ("candidates",), rules)
