"""The paper's Baseline (§V): sequential self-attention ranker on Taobao.

hist units (item⊕cat sum -> 64-d) + learned positions -> 2 pre-LN encoder
blocks (4-head self-attention + FFN 64->256->64) -> masked mean pool ->
tower([user16, cand64, pool64, pool*cand]) -> logit.

Every projection is a compressible linear (core/lightweight.py), so the
full §III ladder — grouped/low-rank (C1), pruning masks (C4), int8 (C5) —
re-represents this model without touching this file. The teacher's
attention maps are exposed for the C3 KL distillation loss (Formula 3).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.core.lightweight import linear
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef
from repro.models.recsys.embedding import _take_rows, field_lookup, named_table_defs
from repro.models.recsys.rec_layers import bce_with_logits, mlp_apply, mlp_defs


def param_defs(cfg: RecSysConfig) -> Dict:
    d = cfg.d_attn  # 64
    L = cfg.seq_len
    defs: Dict = {"tables": named_table_defs(cfg)}
    defs["pos"] = ParamDef((L, d), (None, None), jnp.float32, "normal")
    for l in range(cfg.n_attn_layers):
        defs[f"enc{l}"] = {
            "ln1": ParamDef((d,), (None,), jnp.float32, "ones"),
            "wq": ParamDef((d, d), (None, None), jnp.float32, "fan_in"),
            "wk": ParamDef((d, d), (None, None), jnp.float32, "fan_in"),
            "wv": ParamDef((d, d), (None, None), jnp.float32, "fan_in"),
            "wo": ParamDef((d, d), (None, None), jnp.float32, "fan_in"),
            "ln2": ParamDef((d,), (None,), jnp.float32, "ones"),
            "w1": ParamDef((d, 4 * d), (None, None), jnp.float32, "fan_in"),
            "w2": ParamDef((4 * d, d), (None, None), jnp.float32, "fan_in"),
        }
    user_dim = cfg.field_dim([f for f in cfg.fields if f.name == "user"][0])
    tower_in = user_dim + d + d + d
    defs.update(mlp_defs("tower", tower_in, cfg.mlp_dims))
    return defs


def _ln(x, scale):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale


def _encoder_block(p, x, mask, n_heads: int, *, window: int = 0):
    """Pre-LN MHA + FFN. Returns (x, attention probs [B,H,L,L]) — the probs
    feed the C3 distillation KL. `window`>0 applies the paper's C2 local
    attention mask (|i-j| < window) at the model level."""
    B, L, d = x.shape
    dh = d // n_heads
    h = _ln(x, p["ln1"])
    q = linear(p["wq"], h).reshape(B, L, n_heads, dh)
    k = linear(p["wk"], h).reshape(B, L, n_heads, dh)
    v = linear(p["wv"], h).reshape(B, L, n_heads, dh)
    s = jnp.einsum("blhd,bmhd->bhlm", q, k) / jnp.sqrt(dh)
    valid = mask[:, None, None, :]  # key mask
    if window:
        ij = jnp.abs(jnp.arange(L)[:, None] - jnp.arange(L)[None, :]) < window
        valid = valid & ij[None, None]
    s = jnp.where(valid, s, -1e30)
    probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhlm,bmhd->blhd", probs.astype(v.dtype), v).reshape(B, L, d)
    x = x + linear(p["wo"], o)
    h2 = _ln(x, p["ln2"])
    x = x + linear(p["w2"], jax.nn.relu(linear(p["w1"], h2)))
    return x, probs


def encode_history(params, batch, cfg: RecSysConfig, rules, collect_attn=False):
    """-> (pooled [B,d], attn list per layer)."""
    t = params["tables"]
    it = field_lookup(t, cfg, "hist_item", batch["hist_item"], rules)
    ca = field_lookup(t, cfg, "hist_category", batch["hist_category"], rules)
    x = it + ca + params["pos"][None]
    mask = jnp.arange(x.shape[1])[None] < batch["hist_len"][:, None]
    window = cfg_window(cfg)
    attns = []
    for l in range(cfg.n_attn_layers):
        x, probs = _encoder_block(params[f"enc{l}"], x, mask, cfg.n_heads, window=window)
        if collect_attn:
            attns.append(probs)
    m = mask[..., None].astype(x.dtype)
    pooled = jnp.sum(x * m, axis=1) / jnp.clip(jnp.sum(m, axis=1), 1.0)
    return pooled, attns


def cfg_window(cfg) -> int:
    # C2 sparse attention window, carried via an optional attribute so the
    # base config dataclass stays family-generic.
    return getattr(cfg, "attn_window", 0) or 0


def _tower_logits(params, user, cand, pooled, cfg):
    x = jnp.concatenate([user, cand, pooled, pooled * cand], axis=-1)
    return mlp_apply(params, "tower", x, len(cfg.mlp_dims))[:, 0]


def logits_and_attn(params, batch, cfg: RecSysConfig, rules, collect_attn=False):
    t = params["tables"]
    user = field_lookup(t, cfg, "user", batch["user"], rules)
    it = field_lookup(t, cfg, "item", batch["item"], rules)
    ca = field_lookup(t, cfg, "category", batch["category"], rules)
    cand = it + ca
    pooled, attns = encode_history(params, batch, cfg, rules, collect_attn)
    out = _tower_logits(params, user, cand, pooled, cfg)
    return constrain(out, ("batch",), rules), attns


def logits(params, batch, cfg, rules):
    return logits_and_attn(params, batch, cfg, rules)[0]


def loss(params, batch, cfg: RecSysConfig, rules):
    lg = logits(params, batch, cfg, rules)
    b = bce_with_logits(lg, batch["label"])
    return b, {"bce": b}


def serve(params, batch, cfg: RecSysConfig, rules):
    return jax.nn.sigmoid(logits(params, batch, cfg, rules))


def retrieval(params, query, cand_ids, cfg: RecSysConfig, rules):
    """History encoding is candidate-independent here — encode once, then
    batched tower over N candidates."""
    t = params["tables"]
    user = field_lookup(t, cfg, "user", query["user"], rules)[0]
    pooled, _ = encode_history(params, query, cfg, rules)
    pooled = pooled[0]

    it = _take_rows(t["item"], cand_ids)
    ca = _take_rows(t["category"], query["cand_category"])
    cand = it + ca
    cand = constrain(cand, ("candidates", None), rules)
    N = cand.shape[0]
    scores = _tower_logits(
        params,
        jnp.broadcast_to(user[None], (N, user.shape[0])),
        cand,
        jnp.broadcast_to(pooled[None], (N, pooled.shape[0])),
        cfg,
    )
    return constrain(scores, ("candidates",), rules)
