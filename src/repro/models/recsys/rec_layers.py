"""Small shared layers for the recsys towers."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef


def mlp_defs(name: str, in_dim: int, dims: Tuple[int, ...], out_dim: int = 1) -> Dict:
    """MLP tower ParamDefs: dims hidden layers + linear head to out_dim."""
    defs = {}
    prev = in_dim
    for i, d in enumerate(dims):
        defs[f"{name}_w{i}"] = ParamDef((prev, d), (None, None), jnp.float32, "fan_in")
        defs[f"{name}_b{i}"] = ParamDef((d,), (None,), jnp.float32, "zeros")
        defs[f"{name}_a{i}"] = ParamDef((d,), (None,), jnp.float32, "zeros")  # PReLU
        prev = d
    defs[f"{name}_wout"] = ParamDef((prev, out_dim), (None, None), jnp.float32, "fan_in")
    defs[f"{name}_bout"] = ParamDef((out_dim,), (None,), jnp.float32, "zeros")
    return defs


def prelu(x, a):
    return jnp.where(x >= 0, x, a * x)


def mlp_apply(params: Dict, name: str, x, n_layers: int):
    """All matmuls go through the compressible-linear dispatch so the C4/C5
    ladder (masked / int8 / low-rank reps) applies to every tower."""
    from repro.core.lightweight import linear

    for i in range(n_layers):
        x = linear(params[f"{name}_w{i}"], x) + params[f"{name}_b{i}"]
        x = prelu(x, params[f"{name}_a{i}"])
    return linear(params[f"{name}_wout"], x) + params[f"{name}_bout"]


def bce_with_logits(logits, labels):
    """Numerically stable binary cross entropy."""
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
