"""Uniform recsys model API: dispatch by cfg.interaction."""
from __future__ import annotations

from repro.configs.base import RecSysConfig
from repro.models.recsys import autoint, dien, din, fm, taobao_ssa

_MODULES = {
    "fm": fm,
    "self_attn": autoint,
    "target_attn": din,
    "augru": dien,
    "self_attn_seq": taobao_ssa,
}


def module_for(cfg: RecSysConfig):
    return _MODULES[cfg.interaction]


def param_defs(cfg):
    return module_for(cfg).param_defs(cfg)


def loss(params, batch, cfg, rules):
    return module_for(cfg).loss(params, batch, cfg, rules)


def serve(params, batch, cfg, rules):
    return module_for(cfg).serve(params, batch, cfg, rules)


def retrieval(params, query, cand_ids, cfg, rules):
    return module_for(cfg).retrieval(params, query, cand_ids, cfg, rules)
