"""DIEN [arXiv:1809.03672]: GRU interest extraction + AUGRU interest evolution.

Stage 1: standard GRU over behaviour units -> interest states h_t.
Stage 2: attention score a_t = softmax(h_t W_a cand); AUGRU scales the
update gate by a_t:  u_t' = a_t * u_t;  h_t = (1-u_t')∘h_{t-1} + u_t'∘h̃_t.
The recurrence runs as `jax.lax.scan` over time (the AUGRU cell is also
provided as a Pallas kernel candidate in kernels/, see DESIGN.md).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef
from repro.models.recsys.embedding import field_lookup, named_table_defs
from repro.models.recsys.rec_layers import bce_with_logits, mlp_apply, mlp_defs


def _gru_defs(name: str, d_in: int, g: int) -> Dict:
    return {
        f"{name}_wx": ParamDef((d_in, 3 * g), (None, None), jnp.float32, "fan_in"),
        f"{name}_wh": ParamDef((g, 3 * g), (None, None), jnp.float32, "fan_in"),
        f"{name}_b": ParamDef((3 * g,), (None,), jnp.float32, "zeros"),
    }


def param_defs(cfg: RecSysConfig) -> Dict:
    de = cfg.embed_dim
    du = 2 * de
    g = cfg.gru_dim
    defs: Dict = {"tables": named_table_defs(cfg)}
    defs.update(_gru_defs("gru1", du, g))
    defs.update(_gru_defs("augru", g, g))
    defs["w_att"] = ParamDef((g, du), (None, None), jnp.float32, "fan_in")
    defs.update(mlp_defs("tower", de + du + g, cfg.mlp_dims))
    return defs


def _gru_cell(params, name, h, x, a=None):
    """Gates in [r, u, c] layout; a (optional) scales the update gate."""
    g = h.shape[-1]
    zx = x @ params[f"{name}_wx"] + params[f"{name}_b"]
    zh = h @ params[f"{name}_wh"]
    r = jax.nn.sigmoid(zx[..., :g] + zh[..., :g])
    u = jax.nn.sigmoid(zx[..., g : 2 * g] + zh[..., g : 2 * g])
    c = jnp.tanh(zx[..., 2 * g :] + r * zh[..., 2 * g :])
    if a is not None:
        u = a[..., None] * u  # AUGRU: attentional update gate
    return (1.0 - u) * h + u * c


def _run_gru(params, name, xs, mask, g, att=None):
    """xs: [B,L,d] time scan; mask: [B,L]; att: [B,L] or None -> [B,L,g] states."""
    B, L, _ = xs.shape

    def step(h, inp):
        if att is None:
            x_t, m_t = inp
            h_new = _gru_cell(params, name, h, x_t)
        else:
            x_t, m_t, a_t = inp
            h_new = _gru_cell(params, name, h, x_t, a_t)
        h = jnp.where(m_t[:, None], h_new, h)
        return h, h

    xs_t = jnp.moveaxis(xs, 1, 0)  # [L,B,d]
    mask_t = jnp.moveaxis(mask, 1, 0)
    inputs = (xs_t, mask_t) if att is None else (xs_t, mask_t, jnp.moveaxis(att, 1, 0))
    h0 = jnp.zeros((B, g), xs.dtype)
    h_last, hs = jax.lax.scan(step, h0, inputs)
    return h_last, jnp.moveaxis(hs, 0, 1)  # [B,L,g]


def _behaviour_emb(params, batch, cfg, rules, hist: bool):
    t = params["tables"]
    if hist:
        it = field_lookup(t, cfg, "hist_item", batch["hist_item"], rules)
        ca = field_lookup(t, cfg, "hist_category", batch["hist_category"], rules)
    else:
        it = field_lookup(t, cfg, "item", batch["item"], rules)
        ca = field_lookup(t, cfg, "category", batch["category"], rules)
    return jnp.concatenate([it, ca], axis=-1)


def _interest(params, hist, mask, cand, cfg):
    """hist [B,L,du], cand [B,du] -> final evolved interest [B,g]."""
    g = cfg.gru_dim
    _, h1 = _run_gru(params, "gru1", hist, mask, g)  # [B,L,g]
    att_logits = jnp.einsum("blg,gd,bd->bl", h1, params["w_att"], cand)
    att_logits = jnp.where(mask, att_logits, -1e30)
    att = jax.nn.softmax(att_logits.astype(jnp.float32), axis=-1).astype(h1.dtype)
    h_final, _ = _run_gru(params, "augru", h1, mask, g, att=att)
    return h_final


def logits(params, batch, cfg: RecSysConfig, rules):
    user = field_lookup(params["tables"], cfg, "user", batch["user"], rules)
    hist = _behaviour_emb(params, batch, cfg, rules, hist=True)
    cand = _behaviour_emb(params, batch, cfg, rules, hist=False)
    mask = jnp.arange(hist.shape[1])[None] < batch["hist_len"][:, None]
    interest = _interest(params, hist, mask, cand, cfg)
    x = jnp.concatenate([user, cand, interest], axis=-1)
    out = mlp_apply(params, "tower", x, len(cfg.mlp_dims))[:, 0]
    return constrain(out, ("batch",), rules)


def loss(params, batch, cfg: RecSysConfig, rules):
    lg = logits(params, batch, cfg, rules)
    b = bce_with_logits(lg, batch["label"])
    return b, {"bce": b}


def serve(params, batch, cfg: RecSysConfig, rules):
    return jax.nn.sigmoid(logits(params, batch, cfg, rules))


def retrieval(params, query, cand_ids, cfg: RecSysConfig, rules):
    """GRU stage-1 runs once; candidate-dependent AUGRU batched over N."""
    t = params["tables"]
    user = field_lookup(t, cfg, "user", query["user"], rules)[0]
    hist = _behaviour_emb(params, query, cfg, rules, hist=True)  # [1,L,du]
    L = hist.shape[1]
    mask = jnp.arange(L)[None] < query["hist_len"][:, None]  # [1,L]

    it = jnp.take(t["item"], cand_ids, axis=0)
    ca = jnp.take(t["category"], query["cand_category"], axis=0)
    cand = jnp.concatenate([it, ca], axis=-1)
    cand = constrain(cand, ("candidates", None), rules)
    N = cand.shape[0]

    g = cfg.gru_dim
    _, h1 = _run_gru(params, "gru1", hist, mask, g)  # [1,L,g]
    att_logits = jnp.einsum("lg,gd,nd->nl", h1[0], params["w_att"], cand)
    att_logits = jnp.where(mask, att_logits, -1e30)
    att = jax.nn.softmax(att_logits.astype(jnp.float32), axis=-1).astype(h1.dtype)

    h1N = jnp.broadcast_to(h1, (N, L, g))
    maskN = jnp.broadcast_to(mask, (N, L))
    h_final, _ = _run_gru(params, "augru", h1N, maskN, g, att=att)

    userN = jnp.broadcast_to(user[None], (N, user.shape[0]))
    x = jnp.concatenate([userN, cand, h_final], axis=-1)
    scores = mlp_apply(params, "tower", x, len(cfg.mlp_dims))[:, 0]
    return constrain(scores, ("candidates",), rules)
