"""Synthetic data generators with real learnable structure.

Taobao-like behaviour logs (paper §V.A: 1M users / 200K items / seq 100 /
candidate set 50): users have latent category preferences; histories are
drawn from them; labels come from a ground-truth logistic model on
user-item affinity + recency-weighted history match. A model that learns
gets HR@K well above the 1/50 floor — so the Fig-6 accuracy-retention
experiment is meaningful, not noise.

Also: Criteo-like click logs (39 fields, Zipf ids, hidden crossing weights),
random geometric graphs / molecule batches for the GNN smoke tests, and the
serving stack's lookup workloads — Zipf id streams for the caches plus
Poisson `update_event_stream`s of Zipf-hot row publishes that exercise the
shard tier's versioned invalidation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import RecSysConfig


@dataclasses.dataclass
class TaobaoWorld:
    """Ground truth for the synthetic marketplace."""

    n_users: int
    n_items: int
    n_cats: int
    dim: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.item_cat = rng.integers(0, self.n_cats, self.n_items)
        self.user_pref = rng.normal(size=(self.n_users, self.dim)).astype(np.float32)
        self.cat_vec = rng.normal(size=(self.n_cats, self.dim)).astype(np.float32)
        self.item_pop = rng.zipf(1.3, self.n_items).astype(np.float64)
        self.item_pop /= self.item_pop.sum()

    def affinity(self, users: np.ndarray, items: np.ndarray) -> np.ndarray:
        return np.einsum(
            "ud,ud->u", self.user_pref[users], self.cat_vec[self.item_cat[items]]
        )


def taobao_batches(
    cfg: RecSysConfig,
    batch: int,
    steps: int,
    *,
    world: Optional[TaobaoWorld] = None,
    seed: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Behaviour-log batches matching the din/dien/taobao_ssa input spec."""
    fields = {f.name: f for f in cfg.fields}
    n_users = fields["user"].vocab
    n_items = fields["item"].vocab
    n_cats = fields["category"].vocab
    world = world or TaobaoWorld(n_users, n_items, n_cats)
    L = cfg.seq_len
    rng = np.random.default_rng(seed + 1)

    for _ in range(steps):
        users = rng.integers(0, n_users, batch)
        # history: preference-tilted popularity sampling
        cand_pool = rng.integers(0, n_items, (batch, 4 * L))
        aff = np.einsum(
            "ud,ukd->uk",
            world.user_pref[users],
            world.cat_vec[world.item_cat[cand_pool]],
        )
        topk = np.argsort(-aff, axis=1)[:, :L]
        hist = np.take_along_axis(cand_pool, topk, axis=1)
        hist_len = rng.integers(L // 4, L + 1, batch)
        pad_mask = np.arange(L)[None] >= hist_len[:, None]
        hist = np.where(pad_mask, 0, hist)

        # candidate: half drawn FROM the history (re-engagement — the
        # behaviourally learnable signal DIN-style target attention is
        # built for), half uniform; label = history relevance + affinity
        from_hist = rng.random(batch) < 0.5
        pick = rng.integers(0, np.maximum(hist_len, 1))
        cand = np.where(from_hist, hist[np.arange(batch), pick],
                        rng.integers(0, n_items, batch))
        cand_cat = world.item_cat[cand]
        overlap = np.mean(
            (world.item_cat[hist] == cand_cat[:, None]) & ~pad_mask, axis=1
        ) * (L / np.maximum(hist_len, 1))
        logits = (
            2.5 * overlap
            + 0.5 * world.affinity(users, cand)
            + 0.3 * rng.normal(size=batch)
        )
        label = (logits > np.median(logits)).astype(np.float32)

        yield {
            "user": users.astype(np.int32),
            "item": cand.astype(np.int32),
            "category": cand_cat.astype(np.int32),
            "hist_item": hist.astype(np.int32),
            "hist_category": world.item_cat[hist].astype(np.int32),
            "hist_len": hist_len.astype(np.int32),
            "label": label,
        }


def taobao_eval_candidates(
    cfg: RecSysConfig, n_queries: int, n_cand: int = 50, *, seed: int = 10,
    world: Optional[TaobaoWorld] = None,
) -> Dict[str, np.ndarray]:
    """Ranking-eval set (paper: candidate set 50, 1 positive): returns a
    flat batch of n_queries*n_cand rows + the positive index per query."""
    fields = {f.name: f for f in cfg.fields}
    world = world or TaobaoWorld(
        fields["user"].vocab, fields["item"].vocab, fields["category"].vocab
    )
    rng = np.random.default_rng(seed)
    base = next(taobao_batches(cfg, n_queries, 1, world=world, seed=seed))

    # positive = an item from the user's history (re-engagement target);
    # negatives uniform — HR@K measures retrieving the behavioural signal
    cands = rng.integers(0, fields["item"].vocab, (n_queries, n_cand))
    pos_idx = rng.integers(0, n_cand, n_queries).astype(np.int32)
    pick = rng.integers(0, np.maximum(base["hist_len"], 1))
    pos_items = base["hist_item"][np.arange(n_queries), pick]
    cands[np.arange(n_queries), pos_idx] = pos_items

    flat = {
        k: np.repeat(base[k], n_cand, axis=0)
        for k in ("user", "hist_item", "hist_category", "hist_len")
    }
    flat["item"] = cands.reshape(-1).astype(np.int32)
    flat["category"] = world.item_cat[flat["item"]].astype(np.int32)
    return {"batch": flat, "pos_idx": pos_idx, "n_cand": n_cand}


def zipf_id_stream(
    n: int, vocab: int, alpha: float = 1.1, *, seed: int = 0
) -> np.ndarray:
    """Zipf(alpha)-popular ID stream over [0, vocab): id k has rank k+1,
    so the hottest ids are the smallest integers and p(k) ∝ (k+1)^-alpha.
    This is the canonical embedding-lookup workload (DeepRecSys-style
    skew): the serving caches, bench_serving experiment 6 and the cache
    examples all draw from it. Deterministic under (n, vocab, alpha,
    seed) — replay yields the identical array."""
    if vocab < 1:
        raise ValueError(f"vocab must be >= 1, got {vocab}")
    rng = np.random.default_rng(seed)
    p = np.arange(1, vocab + 1, dtype=np.float64) ** -float(alpha)
    p /= p.sum()
    return rng.choice(vocab, size=int(n), p=p).astype(np.int64)


def update_event_stream(
    rate_per_s: float, horizon_s: float, vocab: int,
    rows_per_event: int = 32, *, alpha: float = 1.1, seed: int = 0,
) -> Iterator[Tuple[float, Tuple[int, ...]]]:
    """Lazy, time-sorted (t, ids) stream of embedding-table updates for
    `EventLoop.add_stream("shard_update", ...)`: Poisson event times
    (exponential gaps at `rate_per_s`) up to `horizon_s`, each event
    publishing `rows_per_event` Zipf(alpha)-hot row ids over [0, vocab).
    Hot rows update most often — exactly the rows the caches hold — so
    this is the adversarial workload for the shard tier's versioned
    invalidation (serving/shard.py): without it, staleness climbs with
    the update rate. Deterministic under the argument tuple, and lazy
    like the arrival streams: one pending event, not a materialised
    list."""
    if rate_per_s <= 0.0:
        return
    rng = np.random.default_rng(seed)
    p = np.arange(1, vocab + 1, dtype=np.float64) ** -float(alpha)
    p /= p.sum()
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_per_s)
        if t >= horizon_s:
            return
        ids = rng.choice(vocab, size=int(rows_per_event), p=p)
        yield t, tuple(int(i) for i in ids)


def bimodal_cost_mix(
    rank_cost: int = 512, rank_frac: float = 0.1, *,
    point_cost: int = 1, spread: float = 0.0, modes: int = 3,
) -> Tuple[Tuple[int, float], ...]:
    """Weighted (cost, weight) mix for `poisson_arrivals(cost_mix=...)`
    modelling the DeepRecSys bimodal query-size distribution: a
    POINTWISE mode (`point_cost` items — one user/item probe) carrying
    `1 - rank_frac` of the traffic and a RANKING mode at `rank_cost`
    candidates carrying the rest. `spread` > 0 widens the ranking mode
    into `modes` sizes over [rank_cost*(1-spread), rank_cost*(1+spread)]
    with binomial-shaped weights — real candidate sets are not all
    exactly 512 — which exercises the size-aware router's class
    decision at more than one point on the curve. Pure and
    deterministic: same arguments, same tuple.

        bimodal_cost_mix()                      -> ((1, 0.9), (512, 0.1))
        bimodal_cost_mix(spread=0.25, modes=3)  -> pointwise + ranking
                                                   at 384/512/640
    """
    if not 0.0 <= rank_frac <= 1.0:
        raise ValueError(f"rank_frac must be in [0, 1], got {rank_frac}")
    mix = []
    if rank_frac < 1.0:
        mix.append((int(point_cost), 1.0 - rank_frac))
    if rank_frac > 0.0:
        if spread <= 0.0 or modes <= 1:
            mix.append((int(rank_cost), rank_frac))
        else:
            sizes = np.linspace(rank_cost * (1.0 - spread),
                                rank_cost * (1.0 + spread), int(modes))
            # binomial-shaped weights: the central size dominates
            w = np.array([float(math.comb(modes - 1, k))
                          for k in range(int(modes))])
            w = w / w.sum() * rank_frac
            mix.extend((max(int(round(s)), 1), float(wk))
                       for s, wk in zip(sizes, w))
    return tuple(mix)


def criteo_batches(
    cfg: RecSysConfig, batch: int, steps: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Criteo-like batches for fm/autoint: Zipf ids, labels from hidden
    per-field-pair crossing weights (so FM-family models can fit them)."""
    rng = np.random.default_rng(seed)
    F = len(cfg.fields)
    vocabs = np.array([f.vocab for f in cfg.fields])
    hid = rng.normal(size=(F, 8)).astype(np.float32) * 0.5
    id_vec = rng.normal(size=(64, 8)).astype(np.float32)

    for _ in range(steps):
        u = rng.random((batch, F))
        idx = np.floor((vocabs[None, :]) * u ** 3).astype(np.int64)  # zipf-ish
        idx = np.minimum(idx, vocabs[None, :] - 1)
        e = id_vec[idx % 64] * hid[None, :, :]
        s = e.sum(axis=1)
        logits = 0.5 * (np.square(s).sum(-1) - np.square(e).sum(axis=(1, 2)))
        label = (logits > np.median(logits)).astype(np.float32)
        yield {"sparse_idx": idx.astype(np.int32), "label": label}


def random_graph(
    n_nodes: int, avg_degree: int, *, d_feat: Optional[int] = None,
    n_classes: int = 7, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Random geometric graph in R^3 with community-ish labels."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 2.0
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, n_edges)
    # bias edges toward spatial proximity: sample candidates, keep closest
    cand = rng.integers(0, n_nodes, (n_edges, 4))
    d = np.linalg.norm(pos[cand] - pos[src][:, None], axis=-1)
    dst = cand[np.arange(n_edges), np.argmin(d, axis=1)]
    g = {
        "positions": pos,
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "labels": (np.linalg.norm(pos, axis=1) * n_classes / 4).astype(np.int32) % n_classes,
    }
    if d_feat:
        w = rng.normal(size=(3, d_feat)).astype(np.float32)
        g["features"] = (pos @ w + 0.1 * rng.normal(size=(n_nodes, d_feat))).astype(
            np.float32
        )
    else:
        g["species"] = rng.integers(0, 16, n_nodes).astype(np.int32)
    return g


def molecule_batch(
    n_graphs: int, n_nodes: int = 30, n_edges: int = 64, *, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Flattened batch of small molecules; energies from a pairwise
    Lennard-Jones-ish ground truth (learnable by NequIP)."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_graphs, n_nodes, 3)).astype(np.float32) * 1.5
    species = rng.integers(0, 8, (n_graphs, n_nodes)).astype(np.int32)
    src = rng.integers(0, n_nodes, (n_graphs, n_edges))
    dst = rng.integers(0, n_nodes, (n_graphs, n_edges))
    d = np.linalg.norm(
        pos[np.arange(n_graphs)[:, None], src] - pos[np.arange(n_graphs)[:, None], dst],
        axis=-1,
    )
    energy = np.sum(np.exp(-d) - 0.1 * d, axis=1).astype(np.float32)

    off = (np.arange(n_graphs) * n_nodes)[:, None]
    return {
        "positions": pos.reshape(-1, 3),
        "species": species.reshape(-1),
        "edge_src": (src + off).reshape(-1).astype(np.int32),
        "edge_dst": (dst + off).reshape(-1).astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32),
        "energies": energy,
    }


def lm_token_batches(
    vocab: int, batch: int, seq: int, steps: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-chain token streams (learnable bigram structure)."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition: each token has 8 likely successors
    succ = rng.integers(0, vocab, (vocab, 8))
    for _ in range(steps):
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(seq):
            pick = succ[toks[:, t], rng.integers(0, 8, batch)]
            rand = rng.integers(0, vocab, batch)
            use_rand = rng.random(batch) < 0.1
            toks[:, t + 1] = np.where(use_rand, rand, pick)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
