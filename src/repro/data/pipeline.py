"""Host-side input pipeline: deterministic seeding, prefetch, per-host
sharding. At 1000-node scale every host materializes only its slice of the
global batch; here the host count comes from jax.process_count() (1 in this
container — the slicing logic is the same)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator

import jax
import numpy as np


def host_shard(batch: Dict[str, np.ndarray], *, process_index=None, process_count=None):
    """Slice the leading axis to this host's shard."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    if pc == 1:
        return batch

    def sl(x):
        n = x.shape[0]
        per = n // pc
        return x[pi * per : (pi + 1) * per]

    return {k: sl(v) for k, v in batch.items()}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch (overlaps host data gen with device step)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        yield item


def device_put_batches(it: Iterator, sharding=None) -> Iterator:
    for batch in it:
        if sharding is None:
            yield {k: jax.numpy.asarray(v) for k, v in batch.items()}
        else:
            yield {k: jax.device_put(v, sharding) for k, v in batch.items()}


def seeded_batches(make: Callable[[int], Iterator], start_step: int) -> Iterator:
    """Deterministic resume: the generator is re-created at the restart
    step so replayed data matches what the failed run would have seen."""
    return make(start_step)
