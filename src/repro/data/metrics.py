"""Ranking metrics for the paper's Fig 6: HitRate@K, NDCG@K, MRR."""
from __future__ import annotations

from typing import Dict

import numpy as np


def ranking_metrics(scores: np.ndarray, pos_idx: np.ndarray, k: int = 50) -> Dict[str, float]:
    """scores: [n_queries, n_cand]; pos_idx: [n_queries] index of the
    positive candidate. Returns HR@k, NDCG@k, MRR."""
    n, m = scores.shape
    order = np.argsort(-scores, axis=1)
    rank = np.empty_like(order)
    rows = np.arange(n)[:, None]
    rank[rows, order] = np.arange(m)[None, :]
    pos_rank = rank[np.arange(n), pos_idx]  # 0-based

    hr = float(np.mean(pos_rank < k))
    ndcg = float(np.mean(np.where(pos_rank < k, 1.0 / np.log2(pos_rank + 2.0), 0.0)))
    mrr = float(np.mean(1.0 / (pos_rank + 1.0)))
    return {"hit_rate": hr, "ndcg": ndcg, "mrr": mrr}


def auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Binary AUC (rank-sum)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels > 0.5].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
