"""Oracle for the windowed-attention kernel: dense masked attention."""
from __future__ import annotations

from repro.core.sparse_attention import windowed_attention


def local_attention_ref(q, k, v, *, window: int, causal: bool = False):
    """q,k,v: [BH, L, dh] -> [BH, L, dh] (per-head layout)."""
    out = windowed_attention(
        q[:, None], k[:, None], v[:, None], window, causal=causal
    )
    return out[:, 0]
