"""Jitted wrapper: windowed attention over [B, H, L, dh] tensors."""
from __future__ import annotations

import jax

from repro.kernels.local_attention.local_attention import local_attention
from repro.kernels.local_attention.ref import local_attention_ref


def windowed_attention_op(q, k, v, *, window: int, causal: bool = False,
                          bq: int = 128, bk: int = 128):
    """q,k,v: [B,H,L,dh]. Kernel path when L tiles evenly; oracle otherwise."""
    B, H, L, dh = q.shape
    qf = q.reshape(B * H, L, dh)
    kf = k.reshape(B * H, L, dh)
    vf = v.reshape(B * H, L, dh)
    if L % bq or L % bk:
        out = local_attention_ref(qf, kf, vf, window=window, causal=causal)
    else:
        out = local_attention(
            qf, kf, vf, window=window, causal=causal, bq=bq, bk=bk,
            interpret=jax.default_backend() == "cpu",
        )
    return out.reshape(B, H, L, dh)
