"""Pallas TPU kernel: sliding-window attention (paper C2, Formula 4).

Each query block attends only key blocks within the window — compute and
HBM traffic are O(L·w·d) instead of O(L²d). Flash-style streaming softmax:
(running max, normalizer, weighted accumulator) live in VMEM scratch across
the relative-key-block sweep; normalization happens once at the last step.

Grid: (BH, L/bq, n_rel) with n_rel = 2·wb+1 (bidirectional) or wb+1
(causal) relative key blocks, wb = ceil(window/bk). Out-of-range and
out-of-window positions are masked inside the kernel; the key index_map
clamps to valid blocks (masked anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, wb: int, n_rel: int, window: int, causal: bool,
            n_kb: int):
    qb = pl.program_id(1)
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # relative key blocks: causal [qb-wb, qb], bidirectional [qb-wb, qb+wb]
    kb = qb - wb + r

    dh = q_ref.shape[-1]
    q = q_ref[0] * (dh ** -0.5)  # [bq, dh]
    k = k_ref[0]  # [bk, dh]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bk]

    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (jnp.abs(qpos - kpos) < window) & (kb >= 0) & (kb < n_kb)
    if causal:
        valid &= kpos <= qpos
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(r == n_rel - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("window", "causal", "bq", "bk", "interpret")
)
def local_attention(
    q, k, v, *, window: int, causal: bool = False, bq: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """q,k,v: [BH, L, dh]; L % bq == 0 == L % bk. O(L·window·dh) per head."""
    BH, L, dh = q.shape
    wb = -(-window // bk)
    n_rel = wb + 1 if causal else 2 * wb + 1
    n_kb = L // bk
    grid = (BH, L // bq, n_rel)

    def k_index(bh, qb, r):
        kb = qb - wb + r
        return (bh, jnp.clip(kb, 0, n_kb - 1), 0)

    return pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, wb=wb, n_rel=n_rel, window=window,
            causal=causal, n_kb=n_kb,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qb, r: (bh, qb, 0)),
            pl.BlockSpec((1, bk, dh), k_index),
            pl.BlockSpec((1, bk, dh), k_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qb, r: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
