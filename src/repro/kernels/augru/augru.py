"""Pallas TPU kernel: AUGRU (attention-update-gate GRU) recurrence.

DIEN's interest-evolution layer is a strict sequential recurrence over the
behaviour sequence (T=100): XLA cannot parallelize it over T, so per-step
launch/HBM overhead dominates the stock lowering. This kernel keeps the
hidden state in VMEM across the whole T loop: one grid step per batch
block, `jax.lax.fori_loop` over time inside the kernel, the recurrent
matmul [bb, g] x [g, 3g] hitting the MXU each step, and only (zx, att,
mask) streaming in once.

Grid: (B/bb,). VMEM: zx block [bb,T,3g], wh [g,3g], h scratch [bb,g].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(zx_ref, att_ref, mask_ref, wh_ref, h0_ref, out_ref, h_ref, *, T: int, g: int):
    h_ref[...] = h0_ref[...]
    wh = wh_ref[...]

    def step(t, _):
        h = h_ref[...]
        z_t = zx_ref[:, t, :]  # [bb, 3g]
        a_t = att_ref[:, t]  # [bb]
        m_t = mask_ref[:, t]
        zh = jax.lax.dot_general(
            h, wh, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        r = jax.nn.sigmoid(z_t[:, :g] + zh[:, :g])
        u = jax.nn.sigmoid(z_t[:, g : 2 * g] + zh[:, g : 2 * g])
        c = jnp.tanh(z_t[:, 2 * g :] + r * zh[:, 2 * g :])
        u = a_t[:, None] * u
        h_new = (1.0 - u) * h + u * c
        h_ref[...] = jnp.where(m_t[:, None], h_new, h)
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    out_ref[...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def augru(zx, wh, h0, att, mask, *, bb: int = 128, interpret: bool = False):
    """zx: f32 [B,T,3g]; wh: [g,3g]; h0: [B,g]; att,mask: [B,T] -> [B,g]."""
    B, T, g3 = zx.shape
    g = g3 // 3
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        functools.partial(_kernel, T=T, g=g),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, T, 3 * g), lambda b: (b, 0, 0)),
            pl.BlockSpec((bb, T), lambda b: (b, 0)),
            pl.BlockSpec((bb, T), lambda b: (b, 0)),
            pl.BlockSpec((g, 3 * g), lambda b: (0, 0)),
            pl.BlockSpec((bb, g), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((bb, g), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, g), zx.dtype),
        scratch_shapes=[pltpu.VMEM((bb, g), jnp.float32)],
        interpret=interpret,
    )(zx, att.astype(zx.dtype), mask.astype(zx.dtype) > 0, wh, h0)
