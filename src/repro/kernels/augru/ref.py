"""Oracle for the AUGRU kernel: DIEN's attention-gated GRU recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def augru_ref(zx, wh, h0, att, mask):
    """zx: [B,T,3g] precomputed input projections (x@Wx + b, gates [r,u,c]);
    wh: [g,3g]; h0: [B,g]; att: [B,T] attention scalars; mask: [B,T].
    Returns final hidden [B,g].

    h_t = (1 - a_t·u_t) ∘ h_{t-1} + a_t·u_t ∘ tanh(zc + r ∘ (h Whc))
    """
    g = h0.shape[-1]

    def step(h, inp):
        z_t, a_t, m_t = inp
        zh = h @ wh
        r = jax.nn.sigmoid(z_t[:, :g] + zh[:, :g])
        u = jax.nn.sigmoid(z_t[:, g : 2 * g] + zh[:, g : 2 * g])
        c = jnp.tanh(z_t[:, 2 * g :] + r * zh[:, 2 * g :])
        u = a_t[:, None] * u
        h_new = (1.0 - u) * h + u * c
        h = jnp.where(m_t[:, None], h_new, h)
        return h, ()

    h, _ = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(zx, 1, 0), jnp.moveaxis(att, 1, 0), jnp.moveaxis(mask, 1, 0)),
    )
    return h
