"""Jitted wrapper for the AUGRU kernel."""
from __future__ import annotations

import jax

from repro.kernels.augru.augru import augru
from repro.kernels.augru.ref import augru_ref


def augru_op(zx, wh, h0, att, mask, *, bb: int = 128):
    B = zx.shape[0]
    if B % bb:
        return augru_ref(zx, wh, h0, att, mask)
    return augru(zx, wh, h0, att, mask, bb=bb,
                 interpret=jax.default_backend() == "cpu")
