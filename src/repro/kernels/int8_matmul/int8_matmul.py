"""Pallas TPU kernel: W8A8 int8 x int8 -> int32 matmul + dequant epilogue.

The MXU executes int8 pairs at 2x bf16 rate on v5e (394 vs 197 TOPS) — this
kernel is the paper's C5 'zero-copy integer inference' adapted to TPU:
int8 tiles stream HBM->VMEM (4x less traffic than f32), accumulate in an
int32 VMEM scratch across the K grid dimension, and the per-row/per-column
scales are applied once in the epilogue at the last K step.

Grid: (M/bm, N/bn, K/bk), K innermost so the scratch accumulator lives
across the K sweep of one (m, n) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, as_ref, bs_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = as_ref[...][:, None] * bs_ref[...][None, :]
        out_ref[...] = acc_ref[...].astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(
    a_q, b_q, a_scale, b_scale, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """a_q int8 [M,K] (M,K multiples of bm,bk); b_q int8 [K,N]."""
    M, K = a_q.shape
    _, N = b_q.shape
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bm,), lambda m, n, k: (m,)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_q, b_q, a_scale, b_scale)
