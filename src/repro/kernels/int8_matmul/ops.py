"""Jitted public wrapper: quantized linear y = x_q @ w_q with dequant.

On CPU hosts the Pallas body runs under interpret=True (bit-exact
semantics); on TPU it compiles to the MXU int8 path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.int8_matmul import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref, quantize_activations


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def quantized_linear(x, w_rep, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """x: f32 [M, K]; w_rep: {"q": int8 [K,N], "s": f32 [N]} (C5 storage rep).
    Dynamically quantizes activations per-row and runs the int8 kernel when
    shapes tile evenly; falls back to the oracle otherwise."""
    M, K = x.shape
    N = w_rep["q"].shape[1]
    x_q, x_s = quantize_activations(x)
    if M % bm or N % bn or K % bk:
        return int8_matmul_ref(x_q, w_rep["q"], x_s, w_rep["s"])
    return int8_matmul(
        x_q, w_rep["q"], x_s, w_rep["s"], bm=bm, bn=bn, bk=bk,
        interpret=_interpret(),
    )
