"""Pure-jnp oracle for the W8A8 int8 matmul with dequant epilogue."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def int8_matmul_ref(a_q, b_q, a_scale, b_scale):
    """a_q: int8 [M,K]; b_q: int8 [K,N]; a_scale: f32 [M]; b_scale: f32 [N].
    Returns f32 [M,N] = (a_q·b_q in int32) * a_scale[:,None] * b_scale[None,:].
    """
    acc = lax.dot_general(
        a_q, b_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * a_scale[:, None] * b_scale[None, :]


def quantize_activations(x):
    """Per-row dynamic int8 quantization of activations (C5 'dynamic-range-
    aware quantization along the Value branch')."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)
