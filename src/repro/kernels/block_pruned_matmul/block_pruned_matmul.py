"""Pallas TPU kernel: block-sparse (structured-pruned) weight matmul.

The TPU-meaningful reading of the paper's C4 (DESIGN.md §5): weights are
pruned at (block x block) granularity, and this kernel SKIPS pruned blocks
— both the HBM->VMEM DMA cost and the MXU work scale with surviving blocks
(~60% MAC reduction at 40% block sparsity matches the paper's claim).

The skip is expressed with @pl.when on a scalar from the prefetched block
mask: under `interpret=True` the branch is evaluated per grid step, on TPU
it predicates the DMA + MXU issue.

Grid: (M/bm, N/bn, K/bk); block-mask blocks are aligned to (bk, bn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(mask_ref, x_ref, w_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)
    n = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[k, n] != 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == n_k - 1)
    def _write():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_pruned_matmul(
    x, w, block_mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """x: [M,K] f32; w: [K,N] f32; block_mask: [K//bk, N//bn] int32."""
    M, K = x.shape
    _, N = w.shape
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # block mask is scalar-prefetched (SMEM)
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k, mask: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k, mask: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, mask: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(block_mask, x, w)
