"""Oracle for the block-pruned matmul (C4 structured pruning compute path)."""
from __future__ import annotations

import jax.numpy as jnp


def block_pruned_matmul_ref(x, w, block_mask, *, block: int):
    """x: f32 [M,K]; w: f32 [K,N]; block_mask: f32/bool [K//block, N//block].
    y = x @ (w ⊙ expand(block_mask))."""
    K, N = w.shape
    mask = jnp.broadcast_to(
        block_mask.astype(w.dtype)[:, None, :, None],
        (K // block, block, N // block, block),
    ).reshape(K, N)
    return x @ (w * mask)
