"""Jitted wrapper for the block-pruned matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_pruned_matmul.block_pruned_matmul import block_pruned_matmul
from repro.kernels.block_pruned_matmul.ref import block_pruned_matmul_ref


def pruned_linear(x, w, block_mask, *, block: int = 128):
    """y = x @ (w ⊙ mask_blocks); kernel path when shapes tile at `block`."""
    M, K = x.shape
    N = w.shape[1]
    if M % block or K % block or N % block:
        return block_pruned_matmul_ref(x, w, block_mask, block=block)
    return block_pruned_matmul(
        x, w, block_mask.astype(jnp.int32), bm=block, bn=block, bk=block,
        interpret=jax.default_backend() == "cpu",
    )


def density(block_mask) -> float:
    """Surviving-block fraction — the kernel's MAC/DMA cost multiplier."""
    import numpy as np

    return float(np.mean(np.asarray(block_mask) != 0))
