"""Oracle for the embedding-bag kernel: the system's segment_sum path."""
from __future__ import annotations

from repro.models.recsys.embedding import embedding_bag as _bag


def embedding_bag_ref(table, idx, weights=None):
    """table: [V,d]; idx: [B,nnz]; weights: [B,nnz] (None = all ones)."""
    return _bag(table, idx, mask=weights, combiner="sum")
