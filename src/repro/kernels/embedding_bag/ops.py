"""Jitted wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def embedding_bag_op(table, idx, weights=None):
    """table [V,d], idx [B,nnz], weights [B,nnz] or None -> [B,d]."""
    if weights is None:
        weights = jnp.ones(idx.shape, table.dtype)
    return embedding_bag(
        table, idx, weights.astype(table.dtype),
        interpret=jax.default_backend() == "cpu",
    )
