"""Pallas TPU kernel: EmbeddingBag (ragged gather + segment-sum).

The recsys hot path (kernel taxonomy §RecSys): tables are 10⁶-10⁹ rows in
HBM; only the looked-up rows may move. The row ids are SCALAR-PREFETCHED
(pltpu.PrefetchScalarGridSpec) so the BlockSpec index_map can address the
table by data value — each grid step DMAs exactly one [1, d] row into VMEM.
Consecutive grid steps of the same bag revisit one output block, which
therefore stays resident in VMEM while the bag accumulates (init at entry
j==0, add for j>0). Per-entry weights ride along in a second prefetched
operand — this is how per-sample-weighted EmbeddingBag (and the FM
first-order term) runs without a second pass.

Grid: (B * nnz,) — entry-per-step; bags are contiguous runs of nnz steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, row_ref, out_ref, *, nnz: int):
    i = pl.program_id(0)
    j = i % nnz

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...] * w_ref[i]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table, idx, weights, *, interpret: bool = False):
    """table: f32 [V, d]; idx: int32 [B, nnz]; weights: f32 [B, nnz].
    Returns f32 [B, d] — Σ_j weights[b,j] * table[idx[b,j]]."""
    B, nnz = idx.shape
    V, d = table.shape
    flat_idx = idx.reshape(-1)
    flat_w = weights.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # flat_idx, flat_w live in SMEM
        grid=(B * nnz,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_s, w_s: (idx_s[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_s, w_s: (i // nnz, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, nnz=nnz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(flat_idx, flat_w, table)
