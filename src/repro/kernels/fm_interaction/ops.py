"""Jitted wrapper for the fused FM interaction."""
from __future__ import annotations

import jax

from repro.kernels.fm_interaction.fm_interaction import fm_interaction_kernel
from repro.kernels.fm_interaction.ref import fm_interaction_ref


def fm_interaction_op(e, *, bb: int = 256):
    B = e.shape[0]
    if B % bb:
        return fm_interaction_ref(e)
    return fm_interaction_kernel(e, bb=bb, interpret=jax.default_backend() == "cpu")
