"""Pallas TPU kernel: fused FM 2-way interaction (Rendle's sum-square trick).

½‖Σ_f e_bf‖² − ½Σ_f‖e_bf‖² per example, fused in one VMEM pass over the
[bb, F, k] block — the unfused jnp path materializes both the field sum and
the squared tensor in HBM; here they never leave VMEM. Batch is the only
grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(e_ref, out_ref):
    e = e_ref[...]  # [bb, F, k]
    s = jnp.sum(e, axis=1)  # [bb, k]
    sq = jnp.sum(jnp.square(e), axis=1)  # [bb, k]
    out_ref[...] = 0.5 * jnp.sum(jnp.square(s) - sq, axis=-1)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def fm_interaction_kernel(e, *, bb: int = 256, interpret: bool = False):
    """e: f32 [B, F, k], B % bb == 0 -> [B]."""
    B, F, k = e.shape
    return pl.pallas_call(
        _kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, F, k), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((bb,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(e)
