"""Oracle for the fused FM second-order kernel."""
from __future__ import annotations

from repro.models.recsys.fm import fm_interaction


def fm_interaction_ref(e):
    """e: [B, F, k] -> [B]: ½ Σ_d [(Σ_f e)² − Σ_f e²]."""
    return fm_interaction(e)
