"""Logical-axis sharding rules.

Params and activations are annotated with *logical* axis names; a rule table
maps logical names -> mesh axes per architecture family. The same model code
lowers on the single-pod (data, model) mesh, the multi-pod (pod, data, model)
mesh, and the 1-device CPU mesh used by smoke tests (all rules -> None).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# LM family. Two weight layouts resolved via ParamDef.axes / .serve_axes:
#   TRAIN  — FSDP: weights fully sharded over (pod, data, model) on one dim
#            ("fsdp"); activations token-sharded: batch->(pod,data) AND
#            seq->model (context parallelism), so GQA kv-head counts never
#            have to divide the mesh.
#   SERVE  — Megatron-TP: row-parallel inputs ("tp_in") / col-parallel ff
#            ("ff"), vocab->model, decode KV cache seq-sharded on model
#            (flash-decode split-K); long_500k shards KV seq on
#            (data, model) since batch=1.
LM_RULES: Mapping[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": "model",  # context-parallel tokens (train/prefill)
    "kv_seq": "model",  # decode split-K over the KV cache
    "long_kv_seq": ("data", "model"),  # 500k decode, batch=1
    "fsdp": ("pod", "data", "model"),
    "tp_in": "model",  # row-parallel contraction dim (serving)
    "ff": "model",  # col-parallel hidden dim (serving)
    "embed": None,
    "kv_heads": None,
    "head_dim": None,
    "vocab": "model",
    "experts": "model",
    "expert_dp": ("pod", "data"),  # FSDP dim of expert weights (EP train)
    "moe_in": "data",  # serve-time contraction dim of expert weights
    "expert_cap": ("pod", "data"),
    "layers": None,
    "rbf": None,
}

# RecSys: embedding-table rows are the memory -> shard rows on model;
# batch on (pod, data); dense towers replicated.
RECSYS_RULES: Mapping[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "rows": "model",
    "embed": None,
    "seq": None,
    "fields": None,
    "mlp": None,
    "candidates": ("pod", "data", "model"),  # retrieval: 1M candidates, full mesh
    "layers": None,
}

# GNN: nodes/edges partitioned over (pod, data); channels on model at
# ogb_products scale (set by the launcher via rule override).
GNN_RULES: Mapping[str, MeshAxes] = {
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "graphs": ("pod", "data"),
    "feat": None,
    "channels": None,
    "irrep": None,
    "rbf": None,
    "layers": None,
    "batch": ("pod", "data"),
}

FAMILY_RULES = {"lm": LM_RULES, "recsys": RECSYS_RULES, "gnn": GNN_RULES}


def adapt_rules(rules: Mapping[str, MeshAxes], mesh: Mesh) -> Mapping[str, MeshAxes]:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 1-pod,
    everything on the 1-device test mesh). Also threads the mesh itself
    (under "__mesh__") for shard_map-based modules."""
    names = set(mesh.axis_names)

    def fix(ax: MeshAxes) -> MeshAxes:
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in names else None
        kept = tuple(a for a in ax if a in names)
        return kept if kept else None

    out = {k: fix(v) for k, v in rules.items()}
    out["__mesh__"] = mesh  # type: ignore[assignment]
    return out


def pspec(axes: Sequence[Optional[str]], rules: Mapping[str, MeshAxes]) -> P:
    """logical axes tuple -> PartitionSpec via the rule table."""
    out = []
    used: set = set()

    def dedup(ax: MeshAxes) -> MeshAxes:
        # a mesh axis may appear at most once in a PartitionSpec
        if ax is None:
            return None
        if isinstance(ax, str):
            return None if ax in used else (used.add(ax) or ax)
        kept = tuple(a for a in ax if a not in used)
        used.update(kept)
        if not kept:
            return None
        # canonical form: when dedup collapses the rule to its trailing
        # (minor-most) axis, emit the bare axis rather than a 1-tuple; a
        # surviving leading axis keeps the tuple so the spec still shows
        # where the rule was truncated
        if len(kept) == 1 and kept[0] == ax[-1]:
            return kept[0]
        return kept

    for name in axes:
        if name is None:
            out.append(None)
            continue
        if name not in rules:
            raise KeyError(f"no sharding rule for logical axis {name!r}")
        out.append(dedup(rules[name]))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_map_compat(body, *, mesh: Mesh, in_specs, out_specs, check=False):
    """jax.shard_map across jax versions: 0.4.x ships it as
    jax.experimental.shard_map with the replication checker named check_rep;
    newer jax hangs it off the top-level namespace with check_vma."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map  # jax 0.4.x

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]], rules) -> NamedSharding:
    return NamedSharding(mesh, pspec(axes, rules))


def constrain(x, axes: Sequence[Optional[str]], rules) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, pspec(axes, rules))
    except (ValueError, RuntimeError):
        return x


def tree_pspecs(axes_tree, rules):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: pspec(axes, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
