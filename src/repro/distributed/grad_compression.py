"""Int8 gradient compression with error feedback (beyond-paper distributed
trick, C5 applied to the training collective).

Data-parallel gradient all-reduce moves grad_bytes x 2(n-1)/n over ICI per
step. Quantizing the summand to int8 with per-block scales cuts that ~4x
(fp32) / ~2x (bf16); the local quantization residual is carried into the
next step (error feedback — Seide et al. 2014; 1-bit Adam lineage), which
keeps SGD/Adam convergence intact (verified in tests against uncompressed
training loss).

Implementation: shard_map over the data axes — inside, each device
quantizes (grad_shard + residual), all_reduces the int8 codes as int32
(psum of int8 would overflow at 512 devices; codes are summed in int32 and
rescaled), and keeps the residual locally.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 256


def _q(x):
    """Block-wise symmetric int8 quantization: (codes f32-storable, scale)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return codes, scale, x.shape, pad


def _dq(codes, scale, shape, pad):
    flat = (codes * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_psum_mean(grad: jax.Array, residual: jax.Array, axis_names) -> Tuple[jax.Array, jax.Array]:
    """One leaf: error-feedback int8 all-reduce-mean over `axis_names`.
    Returns (averaged grad, new residual). Call INSIDE shard_map/pmap."""
    g = grad.astype(jnp.float32) + residual
    codes, scale, shape, pad = _q(g)
    # codes are small ints in f32; psum exact up to 2^24 >> 127*512
    codes_sum = jax.lax.psum(codes, axis_names)
    scale_sum = jax.lax.psum(scale, axis_names)  # conservative shared scale
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
    mean = _dq(codes_sum / n, scale_sum / n, shape, pad)
    new_residual = g - _dq(codes, scale, shape, pad)
    return mean.astype(grad.dtype), new_residual


def make_compressed_allreduce(axis_names):
    """Tree-level API for use inside shard_map'd train steps."""

    def apply(grads: Any, residuals: Any) -> Tuple[Any, Any]:
        pairs = jax.tree.map(
            lambda g, r: compress_psum_mean(g, r, axis_names), grads, residuals
        )
        means = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return means, res

    return apply


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
