"""Expert-parallel MoE dispatch via shard_map + all_to_all (production path).

GSPMD cannot partition data-dependent gather/scatter dispatch well — the
auto-sharded path (models/moe.py) compiles with involuntary full
rematerialization: ~TB-scale collectives per step (measured; see
EXPERIMENTS.md §Perf). This module is the explicit scheme every production
MoE system uses:

  local top-k routing
   -> sort entries by target expert group (model-axis device)
   -> capacity-bounded send buffers            [n_groups, C_pair, D]
   -> all_to_all over the expert axis          (the ONLY big collective)
   -> local per-expert grouping (second sort)  [E_loc, C_e, D]
   -> batched expert FFN (weights gathered over the dp axes, FSDP-style)
   -> inverse scatter -> all_to_all back -> weighted combine.

Everything is differentiable (a2a/all_gather/scatter all have transposes),
runs under jax.checkpoint inside the layer scan, and degenerates gracefully
on a 1-device mesh (smoke tests compare it against the dense oracle).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import pspec


def _axis_size(axis) -> int:
    try:
        if hasattr(jax.lax, "axis_size"):
            return jax.lax.axis_size(axis)
        return jax.lax.psum(1, axis)  # jax 0.4.x: constant-folds to the size
    except NameError:
        return 1


def _sort_dispatch(keys: jax.Array, n_bins: int, capacity: int):
    """entries -> (slot, kept): slot = bin*capacity + rank within bin
    (rank >= capacity dropped). keys: [N] int32 in [0, n_bins)."""
    order = jnp.argsort(keys)
    sorted_keys = keys[order]
    first = jnp.searchsorted(sorted_keys, sorted_keys, side="left")
    rank = jnp.arange(keys.shape[0]) - first
    kept = rank < capacity
    slot_sorted = jnp.where(kept, sorted_keys * capacity + rank, n_bins * capacity)
    # scatter slot back to entry order
    slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)
    kept_e = jnp.zeros(keys.shape, bool).at[order].set(kept)
    return slot, kept_e


def _capacity(n: int, bins: int, cf: float) -> int:
    return max(4, math.ceil(n / bins * cf))


def moe_ffn_ep(x, layer_params, cfg, rules) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE FFN. x: [B, S, D] (global). Returns (out, aux)."""
    ep_axis = rules.get("experts")
    dp_axes = rules.get("batch") or ()
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    if ep_axis is None:
        from repro.models.moe import moe_ffn

        return moe_ffn(x, layer_params, cfg)
    seq_axis = rules.get("seq")

    x_spec = P(dp_axes if dp_axes else None, seq_axis, None)
    w_spec = P(ep_axis, dp_axes if dp_axes else None, None)
    r_spec = P(None, None)
    out_specs = (x_spec, P())

    has_shared = "shared_gate" in layer_params
    shared_specs = {}
    if has_shared:
        # shared expert: dense FFN, weights FSDP over dp axes on dim 0
        shared_specs = {
            "shared_gate": P(dp_axes if dp_axes else None, None),
            "shared_up": P(dp_axes if dp_axes else None, None),
            "shared_down": P(dp_axes if dp_axes else None, None),
        }
    in_specs = (
        x_spec,
        {
            "router": r_spec,
            "w_gate": w_spec,
            "w_up": w_spec,
            "w_down": w_spec,
            **shared_specs,
        },
    )

    E, K, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor

    def body(x_loc, p_loc):
        n_groups = _axis_size(ep_axis)
        E_loc = E // n_groups
        B_loc, S_loc, D = x_loc.shape
        T_loc = B_loc * S_loc
        xt = x_loc.reshape(T_loc, D)

        # ---- routing (router weights replicated) ----
        logits = jnp.einsum("td,de->te", xt, p_loc["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, K)  # [T_loc, K]
        gate = gate / jnp.clip(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), 1), 0)
        aux = E * jnp.sum(me * ce)
        if dp_axes or ep_axis:
            aux = jax.lax.pmean(aux, tuple(dp_axes) + (ep_axis,))

        # ---- stage 1: entries -> expert-group send buffers ----
        flat_e = eidx.reshape(-1)  # [T_loc*K]
        group = flat_e // E_loc
        C_pair = _capacity(T_loc * K, n_groups, cf)
        slot, kept = _sort_dispatch(group, n_groups, C_pair)
        tok = jnp.arange(T_loc * K) // K

        send_x = jnp.zeros((n_groups * C_pair, D), x_loc.dtype)
        send_x = send_x.at[slot].set(xt[tok], mode="drop")
        send_e = jnp.full((n_groups * C_pair,), -1, jnp.int32)
        send_e = send_e.at[slot].set((flat_e - group * E_loc).astype(jnp.int32), mode="drop")

        # ---- all_to_all to expert owners ----
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_groups, C_pair, D), ep_axis, 0, 0, tiled=False
        ).reshape(n_groups * C_pair, D)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(n_groups, C_pair), ep_axis, 0, 0, tiled=False
        ).reshape(n_groups * C_pair)

        # ---- stage 2: group received tokens by local expert ----
        R = n_groups * C_pair
        keys2 = jnp.where(recv_e >= 0, recv_e, E_loc)  # padding -> drop bin
        C_e = _capacity(R, E_loc, cf)
        slot2, kept2 = _sort_dispatch(keys2, E_loc, C_e)
        slot2 = jnp.where(recv_e >= 0, slot2, E_loc * C_e)

        buf = jnp.zeros((E_loc * C_e, D), x_loc.dtype)
        buf = buf.at[slot2].set(recv_x, mode="drop")
        buf = buf.reshape(E_loc, C_e, D)

        # ---- expert FFN; weights FSDP-gathered over the dp axes ----
        def full(w):
            if dp_axes:
                return jax.lax.all_gather(w, tuple(dp_axes), axis=1, tiled=True)
            return w

        g = jnp.einsum("ecd,edf->ecf", buf, full(p_loc["w_gate"]))
        u = jnp.einsum("ecd,edf->ecf", buf, full(p_loc["w_up"]))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, full(p_loc["w_down"]))
        out_buf = out_buf.reshape(E_loc * C_e, D)

        # ---- inverse: buffer -> recv layout -> a2a back -> combine ----
        back = jnp.where(
            (slot2 < E_loc * C_e)[:, None],
            jnp.take(out_buf, jnp.clip(slot2, 0, E_loc * C_e - 1), axis=0),
            0.0,
        )  # [R, D]
        ret = jax.lax.all_to_all(
            back.reshape(n_groups, C_pair, D), ep_axis, 0, 0, tiled=False
        ).reshape(n_groups * C_pair, D)

        entry_out = jnp.where(
            kept[:, None],
            jnp.take(ret, jnp.clip(slot, 0, n_groups * C_pair - 1), axis=0),
            0.0,
        )  # [T_loc*K, D]
        out = jnp.sum(
            entry_out.reshape(T_loc, K, D) * gate[..., None].astype(x_loc.dtype), axis=1
        )

        if has_shared:

            def full0(w):  # shared weights FSDP-sharded on dim 0
                if dp_axes:
                    return jax.lax.all_gather(w, tuple(dp_axes), axis=0, tiled=True)
                return w

            sg, su, sd = (full0(p_loc[k]) for k in
                          ("shared_gate", "shared_up", "shared_down"))
            hg = jax.nn.silu((xt @ sg).astype(jnp.float32)).astype(x_loc.dtype)
            out = out + (hg * (xt @ su)) @ sd

        return out.reshape(B_loc, S_loc, D), aux

    mesh = rules.get("__mesh__")
    # check_vma=False: under some layouts (e.g. TP train, seq unsharded) the
    # router aux is invariant along the expert axis and the VMA checker
    # rejects the (correct) pmean over it.
    from repro.distributed.sharding import shard_map_compat

    fn = shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check=False)
    moe_in = {k: layer_params[k] for k in
              ("router", "w_gate", "w_up", "w_down") if k in layer_params}
    if has_shared:
        moe_in.update({k: layer_params[k] for k in
                       ("shared_gate", "shared_up", "shared_down")})
    return fn(x, moe_in)
