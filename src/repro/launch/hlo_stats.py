"""Trip-count-aware HLO statistics.

`compiled.cost_analysis()` counts while-loop bodies ONCE — under
scan-over-layers that under-reports flops/bytes/collectives by ~L x. This
module parses the post-optimization HLO text, builds the computation call
graph (fusion/call/while/conditional/reduce...), extracts loop trip counts
from loop-condition constants, and accumulates:

  * dot flops      : 2 x prod(output dims) x prod(contracting dims)
  * HBM bytes      : per top-level op, operand + output buffer sizes
                     (fusion internals excluded — they do not materialize)
  * collective link bytes per kind (ring model, see analysis.py)

all scaled by the product of enclosing trip counts. Also reports the
top-k flop-heaviest computations for perf iteration.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s+=\s+(?P<type>.*?)\s+(?P<op>[a-z][\w\-]*)\("
)
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "after-all", "partition-id", "replica-id",
    "iota",
}


def _shape_elems_bytes(type_str: str) -> Tuple[List[List[int]], int]:
    shapes, total = [], 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in ds:
            n *= d
        shapes.append(ds)
        total += n * _DTYPE_BYTES[dt]
    return shapes, total


@dataclasses.dataclass
class Op:
    name: str
    op: str
    type_str: str
    line: str
    out_bytes: int
    out_shapes: List[List[int]]
    callees: List[str]
    operands: List[str]


def _parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = []
            if mc.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        type_str = mo.group("type")
        shapes, out_bytes = _shape_elems_bytes(type_str)
        callm = _CALL_ATTR_RE.findall(line)
        callees = []
        for grp in callm:
            callees.extend(x.strip().lstrip("%") for x in grp.split(","))
        args = line[mo.end():]
        args = re.split(r"\),\s*[a-z_]+=", args + ")")[0]
        operands = _OPERAND_RE.findall(args)
        comps[cur].append(
            Op(mo.group("name"), mo.group("op"), type_str, line, out_bytes,
               shapes, callees, operands)
        )
    comps["__entry__"] = comps.get(entry, [])  # type: ignore
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(cond_ops: List[Op]) -> int:
    """Largest integer constant in the loop-condition computation — for
    scan/fori loops the bound appears as compare(counter, constant(N))."""
    best = 1
    for op in cond_ops:
        for m in _CONST_CMP_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, sizes: Dict[str, int], shapes: Dict[str, List[List[int]]]) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract_dims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs = op.operands[0] if op.operands else None
    lhs_shape = shapes.get(lhs, [[]])[0] if lhs else []
    out_elems = 1
    for s in op.out_shapes[0] if op.out_shapes else []:
        out_elems *= s
    k = 1
    for d in contract_dims:
        if d < len(lhs_shape):
            k *= lhs_shape[d]
    return 2.0 * out_elems * k


def analyze(hlo: str) -> Dict:
    comps = _parse_computations(hlo)
    entry_name = comps.pop("__entry_name__")  # type: ignore
    comps.pop("__entry__")

    # per-computation symbol tables
    sym_bytes: Dict[str, Dict[str, int]] = {}
    sym_shapes: Dict[str, Dict[str, List[List[int]]]] = {}
    for cname, ops in comps.items():
        sym_bytes[cname] = {o.name: o.out_bytes for o in ops}
        sym_shapes[cname] = {o.name: o.out_shapes for o in ops}

    # accumulate multipliers over the call graph (iterative worklist)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    order = [entry_name]
    seen = {entry_name}
    # BFS respecting that callee multipliers add from all callers
    work = [entry_name]
    while work:
        cname = work.pop()
        m = mult[cname]
        for op in comps.get(cname, []):
            if not op.callees:
                continue
            if op.op == "while":
                body, cond = None, None
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                targets = [(body, m * trips), (cond, m * (trips + 1))]
            else:
                targets = [(c, m) for c in op.callees]
            for tgt, tm in targets:
                if tgt is None or tgt not in comps:
                    continue
                mult[tgt] += tm
                work.append(tgt)

    # computations whose ops materialize buffers: reached through ENTRY /
    # while / call / conditional edges only. Fusion bodies and
    # reduce/scatter/sort `to_apply` scalar lambdas do not touch HBM
    # themselves — their traffic is accounted at the call site.
    sequential = {entry_name}
    work2 = [entry_name]
    while work2:
        cname = work2.pop()
        for op in comps.get(cname, []):
            if op.op in ("while", "call", "conditional"):
                for tgt in op.callees:
                    if tgt in comps and tgt not in sequential:
                        sequential.add(tgt)
                        work2.append(tgt)

    # accumulate stats
    flops = 0.0
    bytes_hbm = 0.0
    coll = {k: 0.0 for k in _COLL_OPS}
    coll_counts = {k: 0 for k in _COLL_OPS}
    per_comp_flops: Dict[str, float] = defaultdict(float)

    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        local_bytes = sym_bytes[cname]
        local_shapes = sym_shapes[cname]
        for op in ops:
            if op.op in ("dot", "convolution"):
                f = _dot_flops(op, local_bytes, local_shapes) * m
                flops += f
                per_comp_flops[cname] += f
            base = op.op[:-6] if op.op.endswith("-start") else op.op
            if base in _COLL_OPS:
                in_b = sum(local_bytes.get(o, 0) for o in op.operands)
                out_b = op.out_bytes
                if base == "all-gather":
                    coll[base] += out_b * m
                elif base == "all-reduce":
                    coll[base] += 2 * in_b * m
                else:
                    coll[base] += in_b * m
                coll_counts[base] += max(int(m), 1)
            if cname in sequential and op.op not in _SKIP_BYTES_OPS:
                if op.op in ("gather", "dynamic-slice"):
                    # HBM traffic of a gather is the TOUCHED rows (output)
                    # plus indices — not the whole table operand.
                    idx_b = sum(local_bytes.get(o, 0) for o in op.operands[1:])
                    bytes_hbm += (2 * op.out_bytes + idx_b) * m
                elif op.op in ("scatter", "dynamic-update-slice"):
                    # read-modify-write of the touched region: ~2x update
                    upd_b = sum(local_bytes.get(o, 0) for o in op.operands[1:])
                    bytes_hbm += (2 * upd_b + op.out_bytes * 0) * m
                else:
                    in_b = sum(local_bytes.get(o, 0) for o in op.operands)
                    bytes_hbm += (in_b + op.out_bytes) * m

    top = sorted(per_comp_flops.items(), key=lambda kv: -kv[1])[:12]
    coll["total"] = sum(coll[k] for k in _COLL_OPS)
    return {
        "flops": flops,
        "bytes_hbm": bytes_hbm,
        "collectives": coll,
        "collective_counts": coll_counts,
        "top_computations": [(n, f) for n, f in top],
        "n_computations": len(comps),
    }
