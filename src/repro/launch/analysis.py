"""Roofline analysis from compiled artifacts (no hardware required).

Inputs: `compiled.cost_analysis()` (FLOPs, bytes), `compiled.as_text()`
(post-SPMD HLO -> per-device collective bytes), `compiled.memory_analysis()`.

Collective cost model (ring algorithms, per-device bytes over the slowest
link): all-gather -> output bytes x (n-1)/n ~= output bytes;
all-reduce -> 2x input; reduce-scatter -> input; all-to-all -> input;
collective-permute -> input.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link, one direction

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s+=\s+(?P<type>.*?)\s+(?P<op>[a-z][\w\-]*)\("
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes in an HLO type string
    (handles tuples like (f32[8,4], bf16[2])). Scalars like f32[] count 0-dim."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op kind (link-bytes model above).

    Two passes: (1) symbol table %name -> output bytes for every
    instruction; (2) for each collective, input bytes = sum of operand
    sizes resolved through the table. HLO dumps reference operands by
    name only, so the table is required.
    """
    sizes: Dict[str, int] = {}
    defs = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group("name"), m.group("type"), m.group("op")
        sizes[name] = _shape_bytes(type_str)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_OPS:
            args = line[m.end():]
            args = args.split(", replica_groups")[0].split(", channel_id")[0]
            operands = _OPERAND_RE.findall(args)
            defs.append((base, name, operands))

    out: Dict[str, float] = {k: 0.0 for k in _COLL_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    seen_started = set()
    for op, name, operands in defs:
        if name in seen_started:
            continue  # -done twin of an async pair
        seen_started.add(name)
        in_bytes = sum(sizes.get(o, 0) for o in operands)
        out_bytes = sizes.get(name, 0)
        if op == "all-gather":
            out[op] += out_bytes
        elif op == "all-reduce":
            out[op] += 2 * in_bytes
        else:
            out[op] += in_bytes
        counts[op] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    coll_bytes: float  # per-device collective link bytes
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_chips": self.n_chips,
        }


def model_flops_lm(cfg, tokens: int, kind: str) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (+ attention)."""
    n = cfg.active_param_count()
    mult = 6 if kind == "train" else 2
    return mult * n * tokens


def mfu_ratio(model_flops: float, hlo_flops_total: float) -> float:
    """MODEL_FLOPS / HLO_FLOPs — fraction of compiled compute that is
    'useful'; <1 means remat/dispatch overhead, >1 means the analytic
    count overestimates (e.g. MoE dropping)."""
    return model_flops / max(hlo_flops_total, 1.0)
