"""Serving launcher: builds the Table-I variant ladder for a recsys arch,
calibrates per-variant latency on REAL jitted executables, and runs the
elastic engine against a traffic profile.

`python -m repro.launch.serve --arch taobao_ssa --profile spike`
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.compression_loop import LadderConfig, run_ladder, variant_stats
from repro.core.serving.engine import ElasticEngine, EngineConfig, poisson_arrivals
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import LatencyModel, ReplicaSpec
from repro.data import synthetic
from repro.distributed.sharding import FAMILY_RULES, adapt_rules
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_data, reduced_config
from repro.models.common import init_params
from repro.models.recsys import api as rec_api

PROFILES = {
    "steady": lambda t: 300.0,
    "spike": lambda t: 150.0 if t < 15 else (1200.0 if t < 40 else 200.0),
    "ramp": lambda t: 50.0 + 20.0 * t,
}


def calibrate_variant(params, cfg, rules, batch_maker) -> LatencyModel:
    fixed = {b: batch_maker(b) for b in (1, 8, 32, 128, 512)}
    jitted = jax.jit(lambda p, b: rec_api.serve(p, b, cfg, rules))

    def run(b):
        jax.block_until_ready(jitted(params, fixed[b]))

    return LatencyModel.calibrate(run, sizes=(1, 8, 32, 128, 512), reps=3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="taobao_ssa")
    ap.add_argument("--profile", default="spike", choices=sorted(PROFILES))
    ap.add_argument("--horizon", type=float, default=60.0)
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--variants", default="baseline,quantized,pruned,pruned_quantized,distilled")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    mesh = make_test_mesh()
    rules = adapt_rules(FAMILY_RULES["recsys"], mesh)
    params = init_params(rec_api.param_defs(cfg), jax.random.key(0))

    # brief pretrain so the ladder compresses a real model
    from repro.training.optimizer import get_optimizer
    from repro.training.train_loop import make_train_step

    data = make_data(cfg, 256)
    opt = get_optimizer("adamw", 1e-3)
    step = jax.jit(make_train_step(lambda p, b: rec_api.loss(p, b, cfg, rules), opt))
    state = opt.init(params)
    for i, b in zip(range(args.train_steps), data(0)):
        params, state, _ = step(params, state, b)

    ladder = run_ladder(
        params, cfg, rules, lambda: data(1),
        LadderConfig(finetune_steps=10, qat_steps=10, distill_steps=15),
    )

    def batch_maker_for(vcfg):
        def mk(bs):
            gen = data(2)
            b = next(gen)
            out = {k: v[:bs] for k, v in b.items() if k != "label"}
            return out
        return mk

    results = {}
    for name in args.variants.split(","):
        v = ladder[name]
        lat = calibrate_variant(v["params"], v["cfg"], rules, batch_maker_for(v["cfg"]))
        spec = ReplicaSpec(name, lat, cold_start_s=5.0, warm_start_s=0.2)
        eng = ElasticEngine(
            spec,
            EngineConfig(n_replicas=2, autoscale=True, slo_p99_s=0.1),
            tiers={"tier0": TierPolicy(2000, 200), "tier1": TierPolicy(2000, 200)},
        )
        arrivals = poisson_arrivals(PROFILES[args.profile], args.horizon, seed=0)
        res = eng.run(arrivals, until=args.horizon)
        results[name] = {
            "p50_ms": res["p50"] * 1e3,
            "p99_ms": res["p99"] * 1e3,
            "throughput": res["throughput"],
            "rejected": res["rejected"],
            "latency_1": lat(1) * 1e3,
            "latency_512": lat(512) * 1e3,
        }
        print(f"{name:18s} p50={res['p50']*1e3:7.1f}ms p99={res['p99']*1e3:7.1f}ms "
              f"thpt={res['throughput']:7.0f}/s svc(512)={lat(512)*1e3:6.1f}ms")

    stats = variant_stats(ladder)
    print(json.dumps({"serving": results, "stats": stats}, indent=2, default=str))


if __name__ == "__main__":
    main()
