"""Mesh construction. Functions only — importing this module never touches
jax device state."""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16x16 (256 chips) single-pod, 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "run under launch/dryrun.py which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n], axis_types=(AxisType.Auto,) * len(axes)
    )


def make_test_mesh():
    """1-device mesh for smoke tests and CPU benchmarks."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(AxisType.Auto, AxisType.Auto)
    )
