"""Mesh construction. Functions only — importing this module never touches
jax device state."""
from __future__ import annotations

import math

import jax

# jax >= 0.5 exposes jax.sharding.AxisType and make_mesh grows an axis_types
# kwarg; on 0.4.x the attribute raises (deprecation shim turns the lookup
# into an AttributeError at import time). Resolve it once here so every
# caller builds meshes through a version-tolerant path.
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except (ImportError, AttributeError):  # jax 0.4.x
    AxisType = None


def compat_make_mesh(shape, axis_names, *, devices=None):
    """jax.make_mesh that passes axis_types only where the installed jax
    supports it (explicit-sharding AxisType landed after 0.4.x)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16x16 (256 chips) single-pod, 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "run under launch/dryrun.py which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return compat_make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh():
    """1-device mesh for smoke tests and CPU benchmarks."""
    return compat_make_mesh((1, 1), ("data", "model"))
