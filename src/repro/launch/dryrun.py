import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init). This
# module is the ONLY place the 512-device platform is forced — tests and
# benchmarks see the real 1-device CPU.

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.launch.cells import all_cells, build_cell  # noqa: E402
from repro.launch.hlo_stats import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool, optimizer: str,
             overrides=None, tag: str = "", accum: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = build_cell(arch, shape, mesh, optimizer=optimizer, overrides=overrides,
                      accum=accum)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            cell.fn, in_shardings=cell.in_shardings, out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        print(f"memory_analysis: {mem}", flush=True)  # proves it fits
        print(f"cost_analysis: flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')} (per-device, loop bodies "
              f"counted once — see hlo_stats for trip-count-corrected totals)",
              flush=True)
        hlo = compiled.as_text()
    stats = analyze(hlo)  # trip-count-aware (cost_analysis counts loop bodies once)

    rec = {
        "cell": cell.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "optimizer": optimizer,
        "tag": tag,
        "meta": cell.meta,
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes_hbm"],
        "raw_cost_analysis": {
            "flops": cost.get("flops", -1.0),
            "bytes_accessed": cost.get("bytes accessed", -1.0),
        },
        "collectives": stats["collectives"],
        "collective_counts": stats["collective_counts"],
        "top_computations": stats["top_computations"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
        },
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
        "hlo_bytes": len(hlo),
    }
    return rec


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> Path:
    mesh_tag = "multi" if multi_pod else "single"
    suffix = f"__{tag}" if tag else ""
    return RESULTS / f"{arch}__{shape}__{mesh_tag}{suffix}.json"


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod AOT dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--sweep", action="store_true", help="run all 40 cells")
    ap.add_argument("--tag", default="", help="variant tag for the output file")
    ap.add_argument("--overrides", default="", help="JSON dict of config overrides")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument("--accum", type=int, default=1, help="gradient accumulation")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.sweep:
        # one subprocess per cell: a pathological compile cannot kill the sweep
        cells = all_cells()
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = 0
        for mesh_kind in meshes:
            for arch, shape in cells:
                out = cell_path(arch, shape, mesh_kind == "multi", args.tag)
                if out.exists() and not args.force:
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                    "--optimizer", args.optimizer,
                ]
                if args.tag:
                    cmd += ["--tag", args.tag]
                if args.overrides:
                    cmd += ["--overrides", args.overrides]
                print(f"[sweep] {arch}:{shape} ({mesh_kind})", flush=True)
                r = subprocess.run(cmd)
                failures += r.returncode != 0
        print(f"[sweep] done, {failures} failures", flush=True)
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape required outside --sweep"
    multi = args.mesh == "multi"
    overrides = json.loads(args.overrides) if args.overrides else None
    out = cell_path(args.arch, args.shape, multi, args.tag)
    try:
        rec = run_cell(args.arch, args.shape, multi, args.optimizer,
                       overrides=overrides, tag=args.tag, accum=args.accum)
        out.write_text(json.dumps(rec, indent=2, default=str))
        print(
            f"OK {rec['cell']} [{rec['mesh']}] flops={rec['flops']:.3e} "
            f"bytes={rec['bytes_accessed']:.3e} "
            f"coll={rec['collectives']['total']:.3e} "
            f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
            f"compile={rec['timings']['compile_s']:.1f}s",
            flush=True,
        )
        return 0
    except Exception:
        err = {"cell": f"{args.arch}:{args.shape}", "mesh": args.mesh,
               "error": traceback.format_exc()}
        out.with_suffix(".err.json").write_text(json.dumps(err, indent=2))
        print(f"FAIL {args.arch}:{args.shape} [{args.mesh}]", flush=True)
        traceback.print_exc()
        return 1


if __name__ == "__main__":
    sys.exit(main())
