"""Dry-run cell builder: (arch x shape x mesh) -> (fn, abstract args, shardings).

Every one of the 40 assigned cells is constructed here from ShapeDtypeStructs
(weak-type-correct, zero allocation). The same builders feed the roofline
benchmarks and the smoke tests (at reduced scale with real arrays).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecSysConfig, get_config
from repro.configs.shapes import FAMILY_SHAPES, GNNShape, LMShape, RecSysShape
from repro.distributed.sharding import FAMILY_RULES, adapt_rules, pspec
from repro.models import transformer as tf
from repro.models.common import abstract_params, param_pspecs
from repro.models.gnn import nequip
from repro.models.gnn.sampler import subgraph_sizes
from repro.models.recsys import api as rec_api
from repro.training import optimizer as opt_lib
from repro.training.train_loop import make_train_step


class Cell(NamedTuple):
    name: str
    fn: Any  # the pure step function
    args: Tuple  # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict  # model flops info etc.
    donate: Tuple = ()  # argnums donated (train: params+opt_state alias in place)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, spec):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

# per-shape-kind n_chunks for the dry run (bounded live logits)


def _int8_lm_defs(defs):
    """C5 on the LM serve path: 2-D+ weight matrices -> int8 {"q","s"}
    (per-out-channel scales); embedding/lm_head -> per-row scales. Norms and
    biases stay fp. Abstract analogue of core/quantization for the dry-run."""
    from repro.models.common import ParamDef, is_def

    def visit(path, d):
        if not is_def(d) or len(d.shape) < 2 or d.dtype == jnp.int8:
            return d
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1] if keys else ""
        if "moe" in keys:  # MoE expert einsums keep bf16 (EP path)
            return d
        if name.startswith(("attn_norm", "ffn_norm", "b")) or "norm" in name:
            return d
        if name in ("embed", "lm_head"):
            scale_axes = (d.mode_axes(True)[0],)
            scale_shape = (d.shape[0],)
        else:
            scale_axes = (None,)
            scale_shape = (d.shape[-1],)
            if len(d.shape) == 3:  # layer-stacked: per (layer, out_channel)
                scale_shape = (d.shape[0], d.shape[-1])
                scale_axes = (d.mode_axes(True)[0], None)
        return {
            "q": ParamDef(d.shape, d.axes, jnp.int8, "zeros", serve_axes=d.serve_axes),
            "s": ParamDef(scale_shape, scale_axes, jnp.float32, "ones"),
        }

    return jax.tree_util.tree_map_with_path(visit, defs, is_leaf=is_def)


def _lm_cell(cfg: LMConfig, shape: LMShape, mesh: Mesh, rules, optimizer: str,
             accum: int = 1) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    defs = tf.param_defs(cfg)

    if shape.kind == "train":
        params = abstract_params(defs)
        if cfg.train_layout == "tp":
            # §Perf experiment: Megatron-TP weights (serve layout) during
            # training — no per-layer weight all-gathers; activations are
            # batch-sharded only (seq stays unsharded on the model axis).
            rules = {**rules, "seq": None}
            p_specs = param_pspecs(defs, rules, serve=True)
        else:
            p_specs = param_pspecs(defs, rules, serve=False)
        opt = opt_lib.get_optimizer(optimizer)
        opt_state = opt_lib.abstract_state(optimizer, params)
        o_specs = opt_lib.state_pspecs(optimizer, p_specs, params)
        batch = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        b_specs = {k: pspec(("batch", "seq"), rules) for k in batch}

        loss_fn = lambda p, b: tf.loss(p, b, cfg, rules)
        step = make_train_step(loss_fn, opt, grad_accum=accum)
        metric_specs = {"ce": P(), "aux": P(), "grad_norm": P(), "loss": P()}
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=step,
            args=(params, opt_state, batch),
            in_shardings=_ns(mesh, (p_specs, o_specs, b_specs)),
            out_shardings=_ns(mesh, (p_specs, o_specs, metric_specs)),
            meta={"tokens": B * S, "kind": "train"},
            donate=(0, 1),
        )

    if cfg.int8_serve:
        defs = _int8_lm_defs(defs)
    params = abstract_params(defs)
    p_specs = param_pspecs(defs, rules, serve=True)

    if shape.kind == "prefill":
        tokens = _sds((B, S), jnp.int32)
        t_spec = pspec(("batch", "seq"), rules)
        fn = lambda p, t: tf.prefill(p, t, cfg, rules)
        cache_spec = pspec(tf.cache_axes(cfg, long_context=False), rules)
        out_spec = (
            NamedSharding(mesh, pspec(("batch", None), rules)),
            (NamedSharding(mesh, cache_spec), NamedSharding(mesh, cache_spec)),
        )
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(params, tokens),
            in_shardings=_ns(mesh, (p_specs, t_spec)),
            out_shardings=out_spec,
            meta={"tokens": B * S, "kind": "prefill"},
        )

    assert shape.kind == "decode"
    long_ctx = S >= 100_000
    if long_ctx:
        # batch=1: batch axes cannot shard; all parallelism goes to the
        # KV sequence (split-K decode over (data, model)).
        rules = {**rules, "batch": None}
    hd = cfg.resolved_head_dim
    cshape = tf.cache_shape(cfg, B, S)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = (_sds(cshape, dt), _sds(cshape, dt))
    c_spec = pspec(tf.cache_axes(cfg, long_context=long_ctx), rules)
    token = _sds((B,), jnp.int32)
    pos = _sds((B,), jnp.int32)
    fn = lambda p, c, t, q: tf.decode(p, c, t, q, cfg, rules)
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(params, cache, token, pos),
        in_shardings=_ns(
            mesh, (p_specs, (c_spec, c_spec), pspec(("batch",), rules), pspec(("batch",), rules))
        ),
        out_shardings=(
            NamedSharding(mesh, pspec(("batch", None), rules)),
            (NamedSharding(mesh, c_spec), NamedSharding(mesh, c_spec)),
        ),
        meta={"tokens": B, "kind": "decode", "kv_len": S},
        donate=(1,),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _rec_batch_specs(cfg: RecSysConfig, B: int, rules, with_label=True):
    if cfg.interaction in ("fm", "self_attn"):
        batch = {"sparse_idx": _sds((B, len(cfg.fields)), jnp.int32)}
        specs = {"sparse_idx": pspec(("batch", None), rules)}
    else:
        L = cfg.seq_len
        batch = {
            "user": _sds((B,), jnp.int32),
            "item": _sds((B,), jnp.int32),
            "category": _sds((B,), jnp.int32),
            "hist_item": _sds((B, L), jnp.int32),
            "hist_category": _sds((B, L), jnp.int32),
            "hist_len": _sds((B,), jnp.int32),
        }
        specs = {
            k: pspec(("batch",) + (None,) * (len(v.shape) - 1), rules)
            for k, v in batch.items()
        }
    if with_label:
        batch["label"] = _sds((B,), jnp.float32)
        specs["label"] = pspec(("batch",), rules)
    return batch, specs


def _full_mesh_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def _quantize_table_defs(defs):
    """§Perf/C5: re-declare row-sharded tables as int8 + per-row scales
    (the abstract-params analogue of core/quantization.quantize_table)."""
    from repro.models.common import ParamDef, is_def

    def visit(d):
        if is_def(d) and len(d.shape) == 2 and d.axes and d.axes[0] == "rows":
            return {
                "q": ParamDef(d.shape, d.axes, jnp.int8, "zeros"),
                "s": ParamDef((d.shape[0],), (d.axes[0],), jnp.float32, "ones"),
            }
        return d

    return jax.tree.map(visit, defs, is_leaf=is_def)


def _rec_cell(cfg: RecSysConfig, shape: RecSysShape, mesh: Mesh, rules, optimizer: str) -> Cell:
    if cfg.serve_full_mesh and shape.kind == "serve":
        rules = {**rules, "batch": _full_mesh_axes(mesh)}
    defs = rec_api.param_defs(cfg)
    if cfg.quantized:
        defs = _quantize_table_defs(defs)
    params = abstract_params(defs)
    p_specs = param_pspecs(defs, rules)

    if shape.kind == "train":
        opt = opt_lib.get_optimizer(optimizer)
        opt_state = opt_lib.abstract_state(optimizer, params)
        o_specs = opt_lib.state_pspecs(optimizer, p_specs, params)
        batch, b_specs = _rec_batch_specs(cfg, shape.batch, rules)
        loss_fn = lambda p, b: rec_api.loss(p, b, cfg, rules)
        step = make_train_step(loss_fn, opt)
        metric_specs = {"bce": P(), "grad_norm": P(), "loss": P()}
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=step,
            args=(params, opt_state, batch),
            in_shardings=_ns(mesh, (p_specs, o_specs, b_specs)),
            out_shardings=_ns(mesh, (p_specs, o_specs, metric_specs)),
            meta={"examples": shape.batch, "kind": "train"},
            donate=(0, 1),
        )

    if shape.kind == "serve":
        batch, b_specs = _rec_batch_specs(cfg, shape.batch, rules, with_label=False)
        fn = lambda p, b: rec_api.serve(p, b, cfg, rules)
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(params, batch),
            in_shardings=_ns(mesh, (p_specs, b_specs)),
            out_shardings=NamedSharding(mesh, pspec(("batch",), rules)),
            meta={"examples": shape.batch, "kind": "serve"},
        )

    assert shape.kind == "retrieval"
    # candidate axis shards over the full mesh -> pad 1,000,000 -> next
    # multiple of 512 (the padded tail scores garbage ids, discarded host-side)
    N = -(-shape.n_candidates // 512) * 512
    # single-query scoring: the query batch (B=1) cannot shard — all
    # parallelism goes to the candidate axis.
    rules = {**rules, "batch": None}
    query, q_specs = _rec_batch_specs(cfg, 1, rules, with_label=False)
    if cfg.interaction not in ("fm", "self_attn"):
        query["cand_category"] = _sds((N,), jnp.int32)
        q_specs["cand_category"] = pspec(("candidates",), rules)
    cand = _sds((N,), jnp.int32)
    fn = lambda p, q, c: rec_api.retrieval(p, q, c, cfg, rules)
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(params, query, cand),
        in_shardings=_ns(mesh, (p_specs, q_specs, pspec(("candidates",), rules))),
        out_shardings=NamedSharding(mesh, pspec(("candidates",), rules)),
        meta={"examples": N, "kind": "retrieval"},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_CLASSES = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47, "molecule": 1}


def _pad512(n: int) -> int:
    """Natural graph sizes rarely divide the mesh; inputs are padded with
    masked nodes/edges (models honour edge_mask / node_mask / label_mask)."""
    return -(-n // 512) * 512


def _gnn_cell(cfg: GNNConfig, shape: GNNShape, mesh: Mesh, rules, optimizer: str) -> Cell:
    if cfg.full_mesh_graph:
        full = _full_mesh_axes(mesh)
        rules = {**rules, "nodes": full, "edges": full}
    n_classes = _GNN_CLASSES[shape.name]

    if shape.kind == "minibatch":
        n_nodes, n_edges = subgraph_sizes(shape.batch_nodes, shape.fanout)
    elif shape.kind == "batched_small":
        n_nodes = shape.n_nodes * shape.graph_batch
        n_edges = shape.n_edges * shape.graph_batch
    else:
        n_nodes, n_edges = shape.n_nodes, shape.n_edges
    n_nodes, n_edges = _pad512(n_nodes), _pad512(n_edges)

    defs = nequip.param_defs(cfg, d_feat=shape.d_feat, n_classes=n_classes)
    params = abstract_params(defs)
    p_specs = param_pspecs(defs, rules)

    graph: Dict[str, Any] = {
        "positions": _sds((n_nodes, 3), jnp.float32),
        "edge_src": _sds((n_edges,), jnp.int32),
        "edge_dst": _sds((n_edges,), jnp.int32),
    }
    g_specs: Dict[str, P] = {
        "positions": pspec(("nodes", None), rules),
        "edge_src": pspec(("edges",), rules),
        "edge_dst": pspec(("edges",), rules),
    }
    if shape.d_feat:
        graph["features"] = _sds((n_nodes, shape.d_feat), jnp.float32)
        g_specs["features"] = pspec(("nodes", None), rules)
    else:
        graph["species"] = _sds((n_nodes,), jnp.int32)
        g_specs["species"] = pspec(("nodes",), rules)

    graph["edge_mask"] = _sds((n_edges,), jnp.bool_)
    g_specs["edge_mask"] = pspec(("edges",), rules)
    if shape.kind == "batched_small":
        graph["graph_ids"] = _sds((n_nodes,), jnp.int32)
        graph["energies"] = _sds((shape.graph_batch,), jnp.float32)
        graph["node_mask"] = _sds((n_nodes,), jnp.bool_)
        g_specs["graph_ids"] = pspec(("nodes",), rules)
        g_specs["energies"] = pspec(("batch",), rules)
        g_specs["node_mask"] = pspec(("nodes",), rules)
        loss_fn = lambda p, b: nequip.energy_loss(p, b, cfg, rules)
        metric_names = ("mse",)
    else:
        graph["labels"] = _sds((n_nodes,), jnp.int32)
        g_specs["labels"] = pspec(("nodes",), rules)
        graph["label_mask"] = _sds((n_nodes,), jnp.bool_)
        g_specs["label_mask"] = pspec(("nodes",), rules)
        loss_fn = lambda p, b: nequip.node_class_loss(p, b, cfg, rules)
        metric_names = ("nll",)

    opt = opt_lib.get_optimizer(optimizer)
    opt_state = opt_lib.abstract_state(optimizer, params)
    o_specs = opt_lib.state_pspecs(optimizer, p_specs, params)
    step = make_train_step(loss_fn, opt)
    metric_specs = {m: P() for m in metric_names}
    metric_specs.update({"grad_norm": P(), "loss": P()})
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=step,
        args=(params, opt_state, graph),
        in_shardings=_ns(mesh, (p_specs, o_specs, g_specs)),
        out_shardings=_ns(mesh, (p_specs, o_specs, metric_specs)),
        meta={"nodes": n_nodes, "edges": n_edges, "kind": "train"},
        donate=(0, 1),
    )


# ---------------------------------------------------------------------------
# Entry
# ---------------------------------------------------------------------------


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    optimizer: str = "adamw",
    overrides: Optional[Dict] = None,
    accum: int = 1,
) -> Cell:
    cfg = get_config(arch, **(overrides or {}))
    family = cfg.family
    rules = adapt_rules(FAMILY_RULES[family], mesh)
    shape = FAMILY_SHAPES[family][shape_name]

    if family == "lm":
        # long_500k runs the paper's C2 sparse attention (DESIGN.md §3)
        if shape_name == "long_500k" and not (overrides or {}).get("sparse_attention") is False:
            cfg = dataclasses.replace(cfg, sparse_attention=True)
        return _lm_cell(cfg, shape, mesh, rules, optimizer, accum=accum)
    if family == "recsys":
        return _rec_cell(cfg, shape, mesh, rules, optimizer)
    if family == "gnn":
        return _gnn_cell(cfg, shape, mesh, rules, optimizer)
    raise ValueError(family)


def all_cells():
    """The 40 assigned (arch, shape) names."""
    from repro.configs.base import ARCH_NAMES

    out = []
    for arch in ARCH_NAMES:
        if arch == "taobao_ssa":
            continue  # the paper's own model is extra, not one of the 40
        fam = get_config(arch).family
        for shape_name in FAMILY_SHAPES[fam]:
            out.append((arch, shape_name))
    return out
