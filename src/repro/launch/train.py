"""Training launcher: `python -m repro.launch.train --arch taobao_ssa ...`

Runs a REAL training loop on this host (CPU, reduced config) or AOT-lowers
at production scale (--dry). Wires: config -> model -> optimizer ->
fault-tolerant loop (checkpoint/resume) -> metrics log.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_config
from repro.data import synthetic
from repro.distributed.sharding import FAMILY_RULES, adapt_rules
from repro.launch.mesh import make_test_mesh
from repro.models.common import init_params
from repro.training import checkpoint
from repro.training.fault_tolerance import FTConfig, ResilientTrainer
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


def reduced_config(cfg):
    """Shrink any arch config to CPU-trainable scale (smoke/driver runs)."""
    if cfg.family == "lm":
        return dataclasses.replace(
            cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4),
            d_ff=256, vocab_size=512, head_dim=32,
            n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
            top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        )
    if cfg.family == "recsys":
        fields = tuple(
            dataclasses.replace(f, vocab=min(f.vocab, 5000)) for f in cfg.fields
        )
        return dataclasses.replace(cfg, fields=fields)
    return cfg  # nequip is already small


def make_loss(cfg, rules):
    if cfg.family == "lm":
        from repro.models import transformer as tf

        return lambda p, b: tf.loss(p, b, cfg, rules)
    if cfg.family == "recsys":
        from repro.models.recsys import api

        return lambda p, b: api.loss(p, b, cfg, rules)
    from repro.models.gnn import nequip

    return lambda p, b: nequip.node_class_loss(p, b, cfg, rules)


def make_data(cfg, batch: int, seed_base: int = 0):
    if cfg.family == "lm":
        return lambda step: (
            {k: jax.numpy.asarray(v) for k, v in b.items()}
            for b in synthetic.lm_token_batches(
                cfg.vocab_size, batch, 128, 10**9, seed=seed_base + step
            )
        )
    if cfg.family == "recsys":
        if cfg.interaction in ("fm", "self_attn"):
            gen = lambda step: synthetic.criteo_batches(cfg, batch, 10**9, seed=seed_base + step)
        else:
            gen = lambda step: synthetic.taobao_batches(cfg, batch, 10**9, seed=seed_base + step)
        return lambda step: (
            {k: jax.numpy.asarray(v) for k, v in b.items()} for b in gen(step)
        )
    def graphs(step):
        i = step
        while True:
            g = synthetic.random_graph(512, 8, n_classes=7, seed=seed_base + i)
            yield {k: jax.numpy.asarray(v) for k, v in g.items()}
            i += 1
    return graphs


def param_defs_for(cfg):
    if cfg.family == "lm":
        from repro.models import transformer as tf

        return tf.param_defs(cfg)
    if cfg.family == "recsys":
        from repro.models.recsys import api

        return api.param_defs(cfg)
    from repro.models.gnn import nequip

    return nequip.param_defs(cfg, n_classes=7)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="taobao_ssa")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    mesh = make_test_mesh()
    rules = adapt_rules(FAMILY_RULES[cfg.family], mesh)

    params = init_params(param_defs_for(cfg), jax.random.key(0))
    opt = get_optimizer(args.optimizer, args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(make_loss(cfg, rules), opt))

    ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}"
    trainer = ResilientTrainer(
        step_fn,
        FTConfig(ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every),
        make_batches=make_data(cfg, args.batch),
    )
    t0 = time.time()
    params, opt_state, restarts, last = trainer.run(params, opt_state, args.steps)
    dt = time.time() - t0
    print(
        json.dumps(
            {"arch": args.arch, "steps": last, "restarts": restarts,
             "wall_s": round(dt, 2), "steps_per_s": round(last / dt, 2)}
        )
    )


if __name__ == "__main__":
    main()
