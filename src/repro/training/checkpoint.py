"""Chunked, atomic, elastically-resharding checkpoints.

Layout: <dir>/step_<N>/
    manifest.msgpack   — treedef, per-leaf shape/dtype/chunking, step, config
    leaf_<i>_<c>.npy   — row-chunked leaf data (chunks cap host memory and
                          map 1:1 onto per-host shards at restore)
Writes go to step_<N>.tmp/ then os.replace() — a crashed writer never
corrupts the latest checkpoint (fault-tolerance requirement). Restore takes
a target sharding tree and device_puts each leaf under it: the SAME
checkpoint restores onto a different mesh (elastic 512 -> 256 proven in
tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_CHUNK_BYTES = 256 * 2**20


def _leaf_chunks(arr: np.ndarray):
    if arr.ndim == 0 or arr.nbytes <= _CHUNK_BYTES:
        return [arr]
    rows_per = max(1, _CHUNK_BYTES // max(arr[0:1].nbytes, 1))
    return [arr[i : i + rows_per] for i in range(0, arr.shape[0], rows_per)]


def save(ckpt_dir: str | Path, step: int, tree: Any, extra: Optional[Dict] = None):
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree.flatten(tree)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        chunks = _leaf_chunks(arr)
        for c, chunk in enumerate(chunks):
            np.save(tmp / f"leaf_{i:05d}_{c:04d}.npy", chunk)
        meta.append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype), "n_chunks": len(chunks)}
        )

    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": meta,
        "extra": extra or {},
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.msgpack").exists()
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | Path,
    step: int,
    like: Any,
    shardings: Any = None,
) -> Any:
    """`like` supplies the treedef (params/opt-state pytree of arrays or
    ShapeDtypeStructs). `shardings` (optional, same structure) device_puts
    each leaf under the TARGET mesh — reshard-on-restore."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = msgpack.unpackb((path / "manifest.msgpack").read_bytes())
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, target tree {len(leaves_like)}"
    )
    shard_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves_like)
    )

    out = []
    for i, (m, s) in enumerate(zip(manifest["leaves"], shard_leaves)):
        chunks = [
            np.load(path / f"leaf_{i:05d}_{c:04d}.npy") for c in range(m["n_chunks"])
        ]
        arr = chunks[0] if len(chunks) == 1 else np.concatenate(chunks, axis=0)
        if s is not None:
            out.append(jax.device_put(arr, s))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
