"""Fault-tolerant training controller: heartbeats, straggler policy,
auto-resume, elastic re-meshing.

On a real cluster each host runs a worker agent; here the controller logic
is host-side Python around the jitted train step, with failures injected by
tests (the policies are what matters — they are mesh-size agnostic):

  * periodic chunked-atomic checkpoints (training/checkpoint.py),
  * heartbeat watchdog: a worker missing `dead_after` beats is declared
    failed -> restore latest checkpoint on the surviving mesh (elastic
    re-shard: 512 -> 256 drops the 'pod' axis, data re-spans survivors),
  * straggler mitigation: per-step worker durations tracked in a rolling
    window; a worker slower than `straggler_factor` x median for
    `straggler_patience` windows is evicted (same path as failure) — the
    drop-slowest policy that bounds tail latency at 1000+ nodes,
  * resume: data iterator is seeded + step-indexed, so restarts replay
    from the checkpoint step without skew.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, Optional

import numpy as np

from repro.training import checkpoint


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    heartbeat_s: float = 10.0
    dead_after: int = 3  # missed beats
    straggler_factor: float = 2.0
    straggler_patience: int = 3


class HeartbeatMonitor:
    def __init__(self, workers, cfg: FTConfig):
        self.cfg = cfg
        self.last_beat: Dict[str, float] = {w: 0.0 for w in workers}
        self.durations: Dict[str, deque] = {w: deque(maxlen=20) for w in workers}
        self.slow_strikes: Dict[str, int] = defaultdict(int)

    def beat(self, worker: str, now: float, step_duration: Optional[float] = None):
        self.last_beat[worker] = now
        if step_duration is not None:
            self.durations[worker].append(step_duration)

    def dead_workers(self, now: float):
        limit = self.cfg.heartbeat_s * self.cfg.dead_after
        return [w for w, t in self.last_beat.items() if now - t > limit]

    def stragglers(self):
        """Workers persistently slower than straggler_factor x median."""
        meds = {
            w: float(np.median(d)) for w, d in self.durations.items() if len(d) >= 5
        }
        if len(meds) < 2:
            return []
        global_med = float(np.median(list(meds.values())))
        out = []
        for w, m in meds.items():
            if m > self.cfg.straggler_factor * global_med:
                self.slow_strikes[w] += 1
                if self.slow_strikes[w] >= self.cfg.straggler_patience:
                    out.append(w)
            else:
                self.slow_strikes[w] = 0
        return out

    def evict(self, worker: str):
        self.last_beat.pop(worker, None)
        self.durations.pop(worker, None)
        self.slow_strikes.pop(worker, None)


class ResilientTrainer:
    """Checkpoint/auto-resume wrapper around a jitted train step."""

    def __init__(self, train_step, cfg: FTConfig, *, make_batches: Callable):
        self.train_step = train_step
        self.cfg = cfg
        self.make_batches = make_batches  # (start_step) -> iterator

    def run(self, params, opt_state, n_steps: int, *, crash_at: Optional[int] = None):
        """Train with periodic checkpoints; `crash_at` injects a failure
        (tests). Returns (params, opt_state, restarts, last_step)."""
        start = checkpoint.latest_step(self.cfg.ckpt_dir)
        restarts = 0
        step0 = 0
        if start is not None:
            params, opt_state = checkpoint.restore(
                self.cfg.ckpt_dir, start, (params, opt_state)
            )
            step0 = start
            restarts += 1

        batches = self.make_batches(step0)
        step = step0
        for step, batch in zip(range(step0, n_steps), batches):
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"injected failure at step {step}")
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            if (step + 1) % self.cfg.ckpt_every == 0:
                checkpoint.save(self.cfg.ckpt_dir, step + 1, (params, opt_state))
        return params, opt_state, restarts, step + 1
