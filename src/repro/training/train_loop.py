"""train_step factories for every architecture family.

The factory returns a pure `train_step(params, opt_state, batch)` suitable
for `jax.jit(..., in_shardings=..., out_shardings=...)` — the same function
is jitted at smoke scale (1 device) and AOT-lowered at production-mesh scale
by launch/dryrun.py.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import Optimizer


def make_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    *,
    grad_accum: int = 1,
    grad_clip: float = 1.0,
) -> Callable:
    """loss_fn(params, batch) -> (scalar, metrics dict)."""

    def one_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            # microbatch over the leading batch axis
            def micro(carry, mb):
                loss_a, grads_a = carry
                loss, metrics, grads = one_grad(params, mb)
                grads_a = jax.tree.map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads_a), metrics

            split = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )
            zero_g = jax.tree.map(lambda p: jnp.zeros_like(p), params)
            (loss, grads), metrics = jax.lax.scan(micro, (0.0, zero_g), split)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = one_grad(params, batch)

        if grad_clip:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
            metrics = {**metrics, "grad_norm": gnorm}

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        return params, opt_state, {**metrics, "loss": loss}

    return train_step


def fit(
    train_step,
    params,
    opt_state,
    batches,
    *,
    log_every: int = 50,
    callback=None,
) -> Tuple[Dict, Dict, list]:
    """Simple host loop for examples/tests; returns (params, state, history)."""
    step_fn = jax.jit(train_step)
    history = []
    for step, batch in enumerate(batches):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or callback:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            if callback:
                callback(step, m)
    return params, opt_state, history
