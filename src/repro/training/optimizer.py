"""Optimizers (mini-optax, self-contained): SGD-M, AdamW, Adam8bit.

Adam8bit stores first/second moments block-wise quantized to int8
(bitsandbytes-style) — 4 bytes/param of optimizer state instead of 8. On a
400B-param model that is the difference between fitting and not fitting
16 GB/chip under full state sharding, and it is squarely in the spirit of
the paper's C5 (dynamic-range quantization applied to the training system).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------


def sgd(lr: float, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, {"mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1**c
        bc2 = 1 - b2**c

        def upd(m_, v_, p):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam8bit — block-wise int8 moments (paper C5 applied to optimizer state)
# ---------------------------------------------------------------------------

_BLOCK = 256


def _quantize_blockwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (f32) -> (int8 codes of x.shape, f32 scales [..., n_blocks]).

    Blocks run along the LAST axis only, so every leading dim — and
    therefore any sharding on it (FSDP weight shards, expert dims) — is
    preserved. A global flatten here destroys GSPMD sharding and triggers
    involuntary full rematerialization (measured: llama4 train temp
    30.9 GiB -> 5.8 TiB with the flattened variant — see §Perf)."""
    *lead, n = x.shape
    pad = (-n) % _BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = x.reshape(*lead, -1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*lead, n + pad)[..., :n]
    return q, scale


def _dequantize_blockwise(q: jax.Array, scale: jax.Array) -> jax.Array:
    *lead, n = q.shape
    pad = (-n) % _BLOCK
    x = q.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = x.reshape(*lead, -1, _BLOCK) * scale[..., None]
    return blocks.reshape(*lead, n + pad)[..., :n]


def _n_blocks(shape) -> Tuple[int, ...]:
    if not shape:
        return (1,)
    return tuple(shape[:-1]) + (-(-shape[-1] // _BLOCK),)


def adam8bit(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        def zq(p):
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(_n_blocks(p.shape), jnp.float32),
            }

        return {
            "m": jax.tree.map(zq, params),
            "v": jax.tree.map(zq, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1 - b1**c
        bc2 = 1 - b2**c

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)

        def leaf_update(g, mq, vq, p):
            g = g.astype(jnp.float32)
            m = b1 * _dequantize_blockwise(mq["q"], mq["s"]) + (1 - b1) * g
            # v is stored as sqrt(v): int8's 1/127 resolution underflows the
            # small-v tail otherwise (tiny v -> code 0 -> 1/eps step blowup)
            v_prev = jnp.square(_dequantize_blockwise(vq["q"], vq["s"]))
            v = b2 * v_prev + (1 - b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            mq2, ms2 = _quantize_blockwise(m)
            vq2, vs2 = _quantize_blockwise(jnp.sqrt(v))
            return -lr * step, {"q": mq2, "s": ms2}, {"q": vq2, "s": vs2}

        new_m, new_v, upds = [], [], []
        for g, mq, vq, p in zip(flat_g, flat_m, flat_v, flat_p):
            if g.ndim >= 3 and g.shape[0] <= 64:
                # layer-stacked leaf: scan the update over the layer dim so
                # the fp32 dequant temporaries are one slice, not the whole
                # stack (llama4 experts: 2 GB -> 85 MB per-device temps)
                upd, m2, v2 = jax.lax.map(
                    lambda args: leaf_update(*args), (g, mq, vq, p)
                )
            else:
                upd, m2, v2 = leaf_update(g, mq, vq, p)
            upds.append(upd)
            new_m.append(m2)
            new_v.append(v2)

        return (
            treedef.unflatten(upds),
            {
                "m": treedef.unflatten(new_m),
                "v": treedef.unflatten(new_v),
                "count": count,
            },
        )

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float = 1e-4, **kw) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adam8bit": adam8bit}[name](lr, **kw)


# ---------------------------------------------------------------------------
# State shape/sharding views (for the AOT dry-run)
# ---------------------------------------------------------------------------


def abstract_state(opt_name: str, abstract_params) -> Any:
    """Optimizer-state ShapeDtypeStructs matching `init` without allocating."""
    opt = get_optimizer(opt_name)
    return jax.eval_shape(opt.init, abstract_params)


def state_pspecs(opt_name: str, params_pspecs, abstract_params=None) -> Any:
    """PartitionSpecs for the optimizer state given param pspecs.

    Moments inherit the param sharding. Adam8bit's [..., n_blocks] scales
    keep every leading-dim sharding and un-shard only the blocked LAST
    axis — `abstract_params` supplies tensor ranks (PartitionSpecs trim
    trailing Nones, so rank is not recoverable from the spec alone).
    """
    from jax.sharding import PartitionSpec as P

    if opt_name == "sgd":
        return {"mu": params_pspecs}
    if opt_name == "adamw":
        return {"m": params_pspecs, "v": params_pspecs, "count": P()}
    if opt_name == "adam8bit":
        def scale_spec(spec: P, ndim: int) -> P:
            parts = list(spec)
            if len(parts) == ndim and parts:
                parts[-1] = None  # only the true last axis loses sharding
            return P(*parts)

        if abstract_params is not None:
            qtree = jax.tree.map(
                lambda spec, p: {"q": spec, "s": scale_spec(spec, len(p.shape))},
                params_pspecs, abstract_params,
                is_leaf=lambda x: isinstance(x, P),
            )
        else:
            qtree = jax.tree.map(
                lambda spec: {"q": spec, "s": spec}, params_pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
        return {"m": qtree, "v": qtree, "count": P()}
    raise ValueError(opt_name)
