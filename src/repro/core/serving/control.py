"""Adaptive serving control plane: online-learned latency + SLO-aware
batch sizing (paper §IV's elastic-scheduling feedback loop, closed).

The routers and pools predict completion times from OFFLINE-calibrated
LatencyModels (replica.py). Calibration drifts — interference, thermal
throttling, a model pushed with a stale ladder — and a static prediction
then misroutes traffic and missizes batches no matter how good the live
queue signals are. DeepRecSys (arXiv 2001.02772) closes exactly this
loop: per-pool batch sizing driven by live SLO headroom, cost estimates
tracked against observed service times. This module is that feedback
layer for the simulator:

    Ewma                 one exponentially-weighted mean, shared by every
                         windowed estimator in the control plane (the
                         latency correction AND the pool's id-rows-per-
                         item average, which used to be a never-decaying
                         lifetime counter)
    OnlineLatencyModel   wraps the calibrated offline LatencyModel and
                         EWMA-corrects it with SEPARATE multiplicative
                         dense and embedding-fetch corrections learned
                         from observed (batch items, miss rows, measured
                         service seconds) samples at each batch_done —
                         `ReplicaPool.dense_latency`, `predicted_latency`
                         and `CostModelRouter.estimate` consult the
                         corrected curve and corrected per-row fetch
    BatchSizeController  per-pool effective `max_batch_items`, widened
                         under SLO headroom (throughput) and narrowed on
                         breach (latency), driven from `scale_tick`
    ControlConfig        opt-in knobs, carried by `PoolSpec.control`

Signal path (pool.py wires it):

    batch_done ──► OnlineLatencyModel.observe(items, miss_rows, measured)
                        │  fetch-free batch:  dense corr = EWMA(meas/dense)
                        │  fetch-carrying:    fetch corr = EWMA(residual/fetch)
                        ▼
    predicted_latency / CostModelRouter.estimate  (corrected curve)

    scale_tick ──► BatchSizeController.tick(p99, slo)
                        │  breach: cap ×= narrow   headroom: cap ×= widen
                        ▼
    ReplicaPool item cap (batch close + next-batch split), traced per tick

Corrections are learned PER PLATFORM CLASS, never blended: each
OnlineLatencyModel belongs to one pool, a pool serves one
`ReplicaSpec.platform` (CPU-class and accelerator-class capacity live
in sibling pools, see replica.py), and no estimator is shared across
pools — so CPU-fleet thermal drift can never contaminate the
accelerator curve the size-aware router splits on. The reporting side
keeps the separation too: pool control summaries carry the platform
tag and `metrics.fleet_control_rollup` maintains per-class
sample-weighted means (`by_platform`) all the way up the
pool -> cell -> fleet chain.

Invariants: everything here is deterministic — corrections depend only on
the observation sequence, the controller only on the (p99, slo) tick
sequence; two identical runs adapt bit-identically (tests replay them).
The correction never flips the curve's sign (clamped positive), and the
controller never leaves [min_batch_items, max_batch_items]. Times are
seconds, batch caps are work ITEMS on the same scale as `Request.cost`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.serving.replica import LatencyModel, MissProfile


class Ewma:
    """Exponentially-weighted mean: the control plane's one windowed
    estimator. The first sample initialises the mean exactly (no bias
    toward a made-up prior); `value` is None until then. An `alpha` of
    1.0 degenerates to last-sample, 0.0 to first-sample-forever."""

    def __init__(self, alpha: float):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"EWMA alpha must be in [0, 1], got {alpha}")
        self.alpha = alpha
        self.value: Optional[float] = None
        self.samples = 0

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (
            self.value + self.alpha * (x - self.value))
        self.samples += 1
        return self.value


@dataclasses.dataclass
class ControlConfig:
    """Per-pool control-plane knobs (`PoolSpec.control`; None = the
    static pre-control behaviour everywhere).

    `online_latency` turns on the EWMA-corrected latency curve;
    `ewma_alpha` is its smoothing factor (also used for the id-rows-per-
    item estimator the miss-cost prediction reads). `adapt_batch` turns
    on SLO-aware batch sizing: each scale tick the pool's effective item
    cap is multiplied by `narrow` while the windowed p99 breaches the
    SLO and by `widen` while p99 sits below `headroom` of it (the band
    between holds the cap steady), clamped to [min_batch_items,
    max_batch_items]. The controller starts from the pool's configured
    `max_batch_items` (or this config's ceiling when the pool had no
    item cap) and only moves on a real p99 signal — initialisation
    never changes the cap. A pool configured TIGHTER than
    `min_batch_items` keeps its own cap as the narrow floor (the floor
    clamp never lifts it); sustained headroom may still widen any pool
    up to this config's ceiling — that band is what opting in
    declares."""

    online_latency: bool = True
    ewma_alpha: float = 0.25
    adapt_batch: bool = True
    min_batch_items: int = 16
    max_batch_items: int = 4096
    widen: float = 1.25
    narrow: float = 0.6
    headroom: float = 0.6


class OnlineLatencyModel:
    """The calibrated offline curve, EWMA-corrected from observation.

    Service time has two physically separate legs — dense compute and
    per-missed-row embedding fetch — that drift INDEPENDENTLY (thermal
    throttling hits the matmuls; a saturated memory bus or a degraded
    shard link hits the fetches), so the model learns two multiplicative
    corrections instead of one conflated ratio (which shard-fetch-
    dominated batches used to drag onto the dense curve and vice versa):

    - a batch with NO fetched rows is a pure dense sample — its
      measured/offline ratio updates the DENSE correction;
    - a batch that fetched rows updates the FETCH correction from the
      residual after the (currently corrected) dense leg and the
      batch's inter-cell transit are subtracted, per predicted fetch
      second, clamped non-negative.

    Each correction is a single multiplicative factor (not per-size
    residuals): that keeps the estimator sample-efficient at every
    batch size at once, because mis-calibration and interference
    overwhelmingly scale a whole leg. `miss_rows` may be an int or a
    replica.MissProfile — transit seconds are the RTT matrix's, known
    exactly, so they are subtracted rather than corrected."""

    def __init__(self, offline: LatencyModel, embed_fetch_s: float = 0.0,
                 alpha: float = 0.25):
        self.offline = offline
        self.embed_fetch_s = embed_fetch_s
        self._dense_corr = Ewma(alpha)
        self._fetch_corr = Ewma(alpha)

    @property
    def correction(self) -> float:
        """Multiplicative observed/offline factor on the DENSE leg (1.0
        until the first fetch-free sample — an unobserved pool trusts
        its calibration). Kept under the pre-split name: every existing
        consumer (trace column, control summary, rollup) read the dense
        curve's correction."""
        return 1.0 if self._dense_corr.value is None else self._dense_corr.value

    @property
    def fetch_correction(self) -> float:
        """Multiplicative observed/offline factor on the per-row
        embedding-fetch leg (1.0 until the first fetch-carrying
        sample)."""
        return 1.0 if self._fetch_corr.value is None else self._fetch_corr.value

    @property
    def samples(self) -> int:
        return self._dense_corr.samples + self._fetch_corr.samples

    def observe(self, items: int, miss_rows, measured_s: float) -> None:
        """One batch_done sample: measured service seconds for a batch
        of `items` work items whose lookups missed `miss_rows` rows (int,
        or a MissProfile carrying the shard tier's decomposition)."""
        if measured_s < 0.0:
            return
        if isinstance(miss_rows, MissProfile):
            fetch_rows, transit_s = miss_rows.fetch_rows, miss_rows.transit_s
        else:
            fetch_rows, transit_s = miss_rows, 0.0
        dense_pred = self.offline(items)
        fetch_pred = fetch_rows * self.embed_fetch_s
        if fetch_pred <= 0.0:
            # pure dense sample (transit without fetched rows cannot occur:
            # transit is charged per remote shard actually fetched from)
            if dense_pred > 0.0:
                self._dense_corr.update(measured_s / dense_pred)
        else:
            residual = measured_s - self.correction * dense_pred - transit_s
            self._fetch_corr.update(max(residual / fetch_pred, 0.0))

    def dense(self, items: int) -> float:
        """Corrected dense service time at `items` work items."""
        return self.correction * self.offline(items)

    @property
    def fetch_s(self) -> float:
        """Corrected per-missed-row embedding-fetch seconds."""
        return self.fetch_correction * self.embed_fetch_s


class BatchSizeController:
    """SLO-aware effective `max_batch_items` (DeepRecSys-style): widen
    under headroom to amortise the per-batch base cost (throughput),
    narrow on breach to bound per-batch service time (latency). Driven
    once per scale tick from the pool's OWN windowed p99; a tick with no
    signal (p99 == 0, empty window) holds the cap — adapting to silence
    would race the first real traffic to the clamp rails."""

    def __init__(self, cfg: ControlConfig, initial: Optional[int] = None):
        self.cfg = cfg
        start = initial if initial is not None else cfg.max_batch_items
        # a pool configured TIGHTER than the controller's default floor
        # keeps its own cap as the narrow floor: initialisation and the
        # floor clamp never lift a static cap without a headroom signal
        self._min = float(min(cfg.min_batch_items, start))
        self._cap = float(min(start, cfg.max_batch_items))

    @property
    def cap(self) -> int:
        """The pool's current effective item cap, in work items."""
        return int(round(self._cap))

    def tick(self, p99: float, slo_s: float) -> int:
        if p99 > slo_s:
            self._cap = max(self._min, self._cap * self.cfg.narrow)
        elif 0.0 < p99 < self.cfg.headroom * slo_s:
            self._cap = min(float(self.cfg.max_batch_items),
                            self._cap * self.cfg.widen)
        return self.cap
