"""Model replicas: compiled executables + calibrated latency models.

A replica serves batches through a real jitted function. For the
discrete-event simulator, per-batch-size service times are CALIBRATED once
by timing the real executable (on this host's CPU) at a ladder of batch
sizes, then interpolated — so the elastic-scheduling experiments reflect
the actual relative costs of the five Table-I variants, not made-up
constants. Cold/warm start costs model XLA compile + weight load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class LatencyModel:
    """Piecewise-linear service time in seconds vs batch size (work items).
    Beyond the last calibrated size the marginal per-item cost of the final
    segment extrapolates linearly — ranking batches (hundreds of candidates
    per request) routinely exceed the calibration ladder, and np.interp's
    clamp would make arbitrarily large batches free."""

    sizes: np.ndarray
    times: np.ndarray

    def __call__(self, batch: int) -> float:
        b = float(batch)
        if len(self.sizes) >= 2 and b > self.sizes[-1]:
            slope = (self.times[-1] - self.times[-2]) / (self.sizes[-1] - self.sizes[-2])
            # timing noise can leave the calibrated tail non-monotonic; a
            # negative slope would make huge batches (and thus busy_until)
            # go negative and corrupt the event clock
            return float(self.times[-1] + max(slope, 0.0) * (b - self.sizes[-1]))
        return float(np.interp(b, self.sizes, self.times))

    @staticmethod
    def calibrate(
        fn: Callable[[int], None],
        sizes: Sequence[int] = (1, 8, 32, 128, 512),
        reps: int = 3,
    ) -> "LatencyModel":
        """fn(batch) runs one real (blocking) inference at that batch size."""
        ts = []
        for b in sizes:
            fn(b)  # compile / warm
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(b)
            ts.append((time.perf_counter() - t0) / reps)
        return LatencyModel(np.asarray(sizes, np.float64), np.asarray(ts))

    @staticmethod
    def analytic(base_s: float, per_item_s: float) -> "LatencyModel":
        sizes = np.array([1, 2048], np.float64)
        return LatencyModel(sizes, base_s + per_item_s * sizes)


@dataclasses.dataclass
class ReplicaSpec:
    variant: str  # which Table-I variant this pool serves
    latency: LatencyModel
    cold_start_s: float = 8.0  # load weights + compile
    warm_start_s: float = 0.25  # pre-initialized pool activation


class Replica:
    def __init__(self, rid: int, spec: ReplicaSpec, ready_at: float):
        self.rid = rid
        self.spec = spec
        self.ready_at = ready_at
        self.busy_until = ready_at
        self.in_flight = 0
        self.served = 0

    def residual(self, now: float) -> float:
        """Seconds of already-committed service left on this replica —
        the pure backlog term, no tie-break fudge (cost-model routing)."""
        return max(self.busy_until - now, 0.0)

    def load(self, now: float) -> float:
        """Router signal: time until free (+ small in-flight tie-break)."""
        return self.residual(now) + 0.001 * self.in_flight

    def start_batch(self, now: float, items: int) -> Tuple[float, float]:
        """Queue one batch of `items` work units; returns (start, done)."""
        start = max(now, self.busy_until, self.ready_at)
        dur = self.spec.latency(items)
        self.busy_until = start + dur
        self.in_flight += 1
        self.served += items
        return start, self.busy_until
