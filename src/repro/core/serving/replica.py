"""Model replicas: compiled executables + calibrated latency models.

A replica serves batches through a real jitted function. For the
discrete-event simulator, per-batch-size service times are CALIBRATED once
by timing the real executable (on this host's CPU) at a ladder of batch
sizes, then interpolated — so the elastic-scheduling experiments reflect
the actual relative costs of the five Table-I variants, not made-up
constants. Cold/warm start costs model XLA compile + weight load.

Service time decomposes into dense compute + sparse memory traffic
(caching layer): `ReplicaSpec.service_time(items, miss_rows)` is the
calibrated dense curve at `items` work items PLUS `embed_fetch_s`
seconds per embedding row the pool's hot-ID cache MISSED — so batch
latency depends on the live hit-rate, not just batch size. Pools with
no cache pay the fetch for every id row their requests carry (the
memory-bound baseline); `embed_fetch_s=0` (the default) reduces to the
pure dense model for traffic that carries no ids.

With the shard tier (serving/shard.py) the miss side splits further:
`miss_rows` may be a `MissProfile` decomposing one batch's L1-missed
rows into shared-L2 hits (free), local-shard fetches (pay
`embed_fetch_s` each) and remote-shard fetches (pay `embed_fetch_s`
each PLUS the batched inter-cell transit in `transit_s`). A plain int
still works everywhere and means "all rows fetched locally" — the
pre-shard behaviour, bit-identical.

Platform classes (DeepRecSys, arXiv 2001.02772; the Facebook
architectural-implications study motivates the curve shapes): a fleet
is rarely one kind of hardware. `ReplicaSpec.platform` names the curve
FAMILY a replica draws from, and the two family constructors capture
the two shapes that matter for query-size-aware scheduling:

    ReplicaSpec.cpu_like(...)          low fixed cost, poor batch
                                       scaling (steep per-item slope) —
                                       cheap for small pointwise
                                       queries, terrible for ranking
    ReplicaSpec.accelerator_like(...)  high fixed cost (kernel launch /
                                       transfer), near-flat batch
                                       scaling — wasteful on tiny
                                       batches, unbeatable at hundreds
                                       of candidates

Plain `ReplicaSpec(...)` keeps `platform="generic"`: every pre-platform
construction behaves exactly as before. The platform tag is what
`router.SizeAwareRouter` keys on to send small queries to CPU-class
capacity and large ranking batches to accelerator-class capacity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class MissProfile:
    """Where one batch's L1-missed embedding rows were served from:
    `l2_hits` absorbed by the shared per-cell L2 cache (no fetch cost),
    `local_rows` fetched from shards homed in the serving cell,
    `remote_rows` fetched from remote-cell shards, and `transit_s` the
    inter-cell RTT those remote fetches paid (one RTT per (batch,
    remote shard) pair — the shard service batches fetches per shard,
    see EmbeddingShardService.fetch)."""

    l2_hits: int = 0
    local_rows: int = 0
    remote_rows: int = 0
    transit_s: float = 0.0

    @property
    def fetch_rows(self) -> int:
        """Rows that reached the shard tier and pay `embed_fetch_s`."""
        return self.local_rows + self.remote_rows

    @property
    def total_rows(self) -> int:
        """All rows the pool's L1 missed (L2 hits + shard fetches)."""
        return self.l2_hits + self.local_rows + self.remote_rows


MissRows = Union[int, MissProfile]


@dataclasses.dataclass
class LatencyModel:
    """Piecewise-linear service time in seconds vs batch size (work items).
    Beyond the last calibrated size the marginal per-item cost of the final
    segment extrapolates linearly — ranking batches (hundreds of candidates
    per request) routinely exceed the calibration ladder, and np.interp's
    clamp would make arbitrarily large batches free."""

    sizes: np.ndarray
    times: np.ndarray

    def __call__(self, batch: int) -> float:
        b = float(batch)
        if len(self.sizes) >= 2 and b > self.sizes[-1]:
            slope = (self.times[-1] - self.times[-2]) / (self.sizes[-1] - self.sizes[-2])
            # timing noise can leave the calibrated tail non-monotonic; a
            # negative slope would make huge batches (and thus busy_until)
            # go negative and corrupt the event clock
            return float(self.times[-1] + max(slope, 0.0) * (b - self.sizes[-1]))
        return float(np.interp(b, self.sizes, self.times))

    @staticmethod
    def calibrate(
        fn: Callable[[int], None],
        sizes: Sequence[int] = (1, 8, 32, 128, 512),
        reps: int = 3,
    ) -> "LatencyModel":
        """fn(batch) runs one real (blocking) inference at that batch size.

        Wall-clock timing of REAL kernels is calibrate()'s whole job —
        it runs offline, never on the simulated path, so the SL001
        determinism rule is suppressed for exactly these two reads.
        """
        ts = []
        for b in sizes:
            fn(b)  # compile / warm
            t0 = time.perf_counter()  # simlint: disable=SL001
            for _ in range(reps):
                fn(b)
            ts.append((time.perf_counter() - t0) / reps)  # simlint: disable=SL001
        return LatencyModel(np.asarray(sizes, np.float64), np.asarray(ts))

    @staticmethod
    def analytic(base_s: float, per_item_s: float) -> "LatencyModel":
        sizes = np.array([1, 2048], np.float64)
        return LatencyModel(sizes, base_s + per_item_s * sizes)


@dataclasses.dataclass
class ReplicaSpec:
    """`latency` is the OFFLINE-calibrated curve — what routers, pools
    and planning math predict from. `true_latency`, when set, is what
    batches actually take: the drift/interference/mis-calibration model
    the control plane (serving/control.py) exists to learn back. None
    (the default) means the calibration is accurate.

    `platform` tags the hardware class the curve was calibrated on —
    "cpu" / "accelerator" via the family constructors below, "generic"
    for everything else. Routers key on it (SizeAwareRouter); nothing
    in the service-time math does, so a tag alone never changes a
    clock."""

    variant: str  # which Table-I variant this pool serves
    latency: LatencyModel
    cold_start_s: float = 8.0  # load weights + compile
    warm_start_s: float = 0.25  # pre-initialized pool activation
    embed_fetch_s: float = 0.0  # per MISSED embedding row (caching layer)
    true_latency: Optional[LatencyModel] = None  # observed curve if drifted
    true_embed_fetch_s: Optional[float] = None  # observed fetch if drifted
    platform: str = "generic"  # hardware class ("cpu"/"accelerator"/"generic")

    @classmethod
    def cpu_like(cls, variant: str, *, base_s: float = 0.002,
                 per_item_s: float = 8e-4, warm_start_s: float = 0.05,
                 cold_start_s: float = 1.0, **kw) -> "ReplicaSpec":
        """A CPU-class replica: LOW fixed cost, POOR batch scaling (the
        per-item slope dominates past a few items). Defaults model a
        general-purpose server core: ~2ms base, ~0.8ms per extra work
        item, fast warm starts (no kernel compile). Override the curve
        or pass `latency=` through **kw for a calibrated one."""
        kw.setdefault("latency", LatencyModel.analytic(base_s, per_item_s))
        return cls(variant, platform="cpu", warm_start_s=warm_start_s,
                   cold_start_s=cold_start_s, **kw)

    @classmethod
    def accelerator_like(cls, variant: str, *, base_s: float = 0.025,
                         per_item_s: float = 3e-5, warm_start_s: float = 0.25,
                         cold_start_s: float = 8.0, **kw) -> "ReplicaSpec":
        """An accelerator-class replica: HIGH fixed cost (launch +
        transfer), NEAR-FLAT batch scaling — a 512-item ranking batch
        costs barely more than a pointwise probe. Defaults: ~25ms base,
        ~0.03ms per item (the curves cross CPU-class around ~30 items),
        slow cold starts (XLA compile + weight load)."""
        kw.setdefault("latency", LatencyModel.analytic(base_s, per_item_s))
        return cls(variant, platform="accelerator", warm_start_s=warm_start_s,
                   cold_start_s=cold_start_s, **kw)

    def service_time(self, items: int, miss_rows: MissRows = 0) -> float:
        """Cache-aware decomposition: ACTUAL dense compute at `items`
        work items (the drifted curve when calibration is off) + the
        embedding-fetch cost of the rows the pool's hot-ID cache missed
        for this batch. A `MissProfile` charges the fetch only for rows
        that reached the shard tier (L2 hits are free) plus the batch's
        inter-cell transit; an int charges every row, with no transit —
        the pre-shard local-table model."""
        dense = self.true_latency if self.true_latency is not None else self.latency
        fetch = (
            self.true_embed_fetch_s
            if self.true_embed_fetch_s is not None
            else self.embed_fetch_s
        )
        if isinstance(miss_rows, MissProfile):
            return dense(items) + miss_rows.fetch_rows * fetch + miss_rows.transit_s
        return dense(items) + miss_rows * fetch


def sustainable_rate(
    spec: ReplicaSpec,
    replicas: int,
    max_wait_s: float,
    ids_per_request: int = 0,
    hit_rate: float = 0.0,
) -> float:
    """Sustainable request rate under timeout batching: batches close
    every `max_wait_s` holding r*max_wait_s requests, and R replicas keep
    up only while b1 + (m + miss_fetch)*r*w <= R*w, i.e.

        r = (R*w - b1) / (w * (m + miss_fetch))

    at the calibrated base b1, marginal per-item cost m (taken over the
    1..32 segment) and miss_fetch = (1 - hit_rate) * ids_per_request *
    embed_fetch_s seconds of embedding traffic per request. This is the
    operating-point model the benchmarks, tests and examples share to
    place offered load relative to a fleet's capacity (cold: hit_rate 0;
    warm: the cache's steady-state hit-rate). Clamped below by 1 rps for
    hosts whose calibrated base exceeds the batching window. A FLAT
    curve with no embedding traffic (marginal + miss_fetch == 0, e.g.
    `LatencyModel.analytic(base, 0.0)` and ids_per_request 0) means
    per-request cost is pure base amortisation: the rate is unbounded
    when the base fits the batching window, else the 1 rps floor —
    never a ZeroDivisionError."""
    b1 = spec.latency(1)
    marginal = (spec.latency(32) - b1) / 31.0
    miss_fetch = (1.0 - hit_rate) * ids_per_request * spec.embed_fetch_s
    denom = max_wait_s * (marginal + miss_fetch)
    if denom <= 0.0:
        return float("inf") if replicas * max_wait_s > b1 else 1.0
    return max((replicas * max_wait_s - b1) / denom, 1.0)


class Replica:
    def __init__(self, rid: int, spec: ReplicaSpec, ready_at: float):
        self.rid = rid
        self.spec = spec
        self.ready_at = ready_at
        self.busy_until = ready_at
        self.in_flight = 0
        self.served = 0

    def residual(self, now: float) -> float:
        """Seconds of already-committed service left on this replica —
        the pure backlog term, no tie-break fudge (cost-model routing)."""
        return max(self.busy_until - now, 0.0)

    def load(self, now: float) -> float:
        """Router signal: time until free (+ small in-flight tie-break)."""
        return self.residual(now) + 0.001 * self.in_flight

    def start_batch(self, now: float, items: int, miss_rows: MissRows = 0) -> Tuple[float, float]:
        """Queue one batch of `items` work units whose embedding lookups
        missed `miss_rows` cache rows (an int, or a MissProfile when the
        shard tier decomposed the misses); returns (start, done)."""
        start = max(now, self.busy_until, self.ready_at)
        dur = self.spec.service_time(items, miss_rows)
        self.busy_until = start + dur
        self.in_flight += 1
        self.served += items
        return start, self.busy_until
