"""Pluggable routing policies: a Router picks the pool a request enters
(at admission) and the replica a closed batch lands on (at dispatch).

Public API
    Router              base class: select_pool(req, pools, now) at
                        admission, select_replica(pool, now) at dispatch
    ROUTERS             name -> class registry (pool-level policies)
    make_router         instantiate by name; accepts an alternate
                        `registry` so higher routing layers (the
                        cell-level policies in federation.py) reuse the
                        same construction/error path

Invariants: all policies are deterministic given their constructor
arguments — the power-of-two sampler draws from its own seeded generator,
so two runs of the same trace through the same policy are bit-identical.
Policies only READ pool signals (`predicted_latency`, `dense_latency`,
`recent_p99`, `queue`, `queued_cost`, `replicas`, `predicted_miss_cost`,
`hit_rate`) — they never mutate pool state. All latency signals are in
seconds; `cost` is in work items. Dense-latency signals go through
`pool.dense_latency`, which serves the ONLINE-corrected curve when the
pool runs a control plane (serving/control.py) — routing decisions track
observed service times, not just the offline calibration.

DeepRecSys (arXiv 2001.02772) motivates the pool-level decision: with
heterogeneous variants live at once, WHERE a query lands matters as much
as how it is batched. CostModelRouter makes that decision from the
calibrated LatencyModels plus live queue state and is the recommended
policy; SLOAwareRouter's p99-threshold heuristic is kept for quality-
tiered head/tail splits. On fleets mixing PLATFORM classes
(`ReplicaSpec.cpu_like` / `.accelerator_like`), SizeAwareRouter is the
recommended policy: it first picks the platform class by QUERY SIZE
(small pointwise -> CPU-class, large ranking -> accelerator-class) and
only then load-balances by the cost-model estimate WITHIN that class —
transient backlog can no longer push a 512-candidate batch onto a
steep CPU curve or flood an accelerator's fixed cost with pointwise
probes. To add a policy: subclass Router, implement
select_pool (and optionally select_replica), and register it in ROUTERS.
The same Router/registry shape repeats one level up: federation.py's
CellPolicy picks the CELL a request enters, through this module's
make_router against its own CELL_POLICIES registry.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.serving.pool import ReplicaPool, Request
from repro.core.serving.replica import Replica


class Router:
    name = "base"

    def select_pool(self, req: Request, pools: Sequence[ReplicaPool], now: float) -> ReplicaPool:
        raise NotImplementedError

    def select_replica(self, pool: ReplicaPool, now: float) -> Replica:
        return min(pool.replicas, key=lambda r: r.load(now))


class LeastLoadedRouter(Router):
    """Global shortest-expected-delay: scan every pool/replica."""

    name = "least_loaded"

    def select_pool(self, req, pools, now):
        return min(pools, key=lambda p: p.predicted_latency(now, req.cost))


class PowerOfTwoRouter(Router):
    """Power-of-two-choices: sample two candidates, take the less loaded.
    O(1) per decision instead of a full scan, with near-best balance
    (Mitzenmacher); the sampler is seeded so simulations stay reproducible."""

    name = "power_of_two"

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def _two(self, n: int) -> Tuple[int, int]:
        i, j = self._rng.choice(n, size=2, replace=False)
        return int(i), int(j)

    def select_pool(self, req, pools, now):
        if len(pools) == 1:
            return pools[0]
        i, j = self._two(len(pools))
        a, b = pools[i], pools[j]
        return a if a.predicted_latency(now, req.cost) <= b.predicted_latency(now, req.cost) else b

    def select_replica(self, pool, now):
        reps = pool.replicas
        if len(reps) == 1:
            return reps[0]
        i, j = self._two(len(reps))
        return reps[i] if reps[i].load(now) <= reps[j].load(now) else reps[j]


class CostModelRouter(Router):
    """Cost-model policy (the recommended default for heterogeneous
    fleets): estimate each pool's completion time for THIS request from
    its calibrated LatencyModel plus live queue state, take the cheapest.

    The estimate charges two terms: (1) residual work already executing,
    amortised over the pool's ready replicas — the whole pool drains its
    committed work in parallel, so total backlog / n is the expected slot
    wait; (2) the service time of the batch this request would join
    (queued-but-unbatched items + its own cost) at the pool's calibrated
    rate. Unlike SLOAwareRouter's p99-threshold heuristic this is
    threshold-free and cost-sensitive: a 512-candidate ranking query
    naturally prefers the pool whose latency curve is flattest at large
    batch, while pointwise traffic spreads by live load. Deterministic —
    no RNG, no thresholds to tune."""

    name = "cost_model"

    def select_pool(self, req, pools, now):
        return min(pools, key=lambda p: self.estimate(p, req.cost, now))

    @staticmethod
    def estimate(pool: ReplicaPool, cost: int, now: float) -> float:
        """slot wait + dense service of the joined batch + predicted
        embedding-miss cost at the pool's LIVE hit-rates — a warm cache
        makes a pool genuinely cheaper than an identical cold one, and
        the router sees it (caching layer, serving/cache.py). With the
        shard tier the miss term carries the same three-way split the
        service clock charges (L1 miss -> shared-L2 hit -> local/remote
        shard fetch with learned per-row transit; see
        `ReplicaPool.predicted_miss_cost`), so routing prefers cells
        whose L2 and local shards are warm. The dense term goes through
        `pool.dense_latency`: with a control plane (serving/control.py)
        that is the ONLINE-corrected curve, so a mis-calibrated or
        drifted spec stops misrouting as soon as observed service times
        disagree with it — and the per-row fetch consults the fetch
        correction the same way."""
        ready = [r for r in pool.replicas if r.ready_at <= now] or pool.replicas
        slot_wait = sum(r.residual(now) for r in ready) / len(ready)
        items = pool.queued_cost + cost
        return slot_wait + pool.dense_latency(items) + pool.predicted_miss_cost(items)


class SizeAwareRouter(CostModelRouter):
    """Query-size-aware routing over heterogeneous platform classes
    (DeepRecSys): decide WHICH class serves this query size, then
    load-balance within the class by the cost-model estimate.

    The plain cost-model estimate is size-sensitive but backlog-coupled:
    under load, a momentarily shorter accelerator queue pulls pointwise
    traffic onto the accelerator's high fixed cost, and an accelerator
    backlog pushes ranking batches onto the steep CPU curve — one
    512-item batch on a CPU-class pool then eats hundreds of
    milliseconds of capacity, the CPU queue explodes, pointwise floods
    the accelerators, and the specialisation collapses in both
    directions. Enforcing class affinity first is DeepRecSys's fix, and
    is what the asserted bench_serving experiment-9 win measures.

    The class decision per query: with an explicit `size_threshold`,
    cost >= threshold prefers accelerator-class pools. Without one (the
    default), the query prefers whichever class serves a batch of ITS
    size cheaper on an idle replica — `pool.dense_latency`, i.e. the
    ONLINE-corrected curve when a control plane is learning one, so the
    split point tracks drift. Pools of other platforms ("generic")
    never join a preferred class; fleets missing either class fall back
    to plain cost-model routing over all pools. Deterministic — no RNG,
    and threshold-free by default."""

    name = "size_aware"

    def __init__(self, size_threshold: Optional[int] = None):
        self.size_threshold = size_threshold

    def select_pool(self, req, pools, now):
        cpu = [p for p in pools if p.spec.platform == "cpu"]
        acc = [p for p in pools if p.spec.platform == "accelerator"]
        if not cpu or not acc:
            return super().select_pool(req, pools, now)
        if self.size_threshold is not None:
            preferred = acc if req.cost >= self.size_threshold else cpu
        else:
            idle_cpu = min(p.dense_latency(req.cost) for p in cpu)
            idle_acc = min(p.dense_latency(req.cost) for p in acc)
            preferred = acc if idle_acc <= idle_cpu else cpu
        return min(preferred, key=lambda p: self.estimate(p, req.cost, now))


class SizeBlindCostModelRouter(CostModelRouter):
    """The DeepRecSys ablation SizeAwareRouter is measured against:
    identical cost-model machinery, but the router does NOT see
    per-query size at admission — every arrival is priced at the
    pointwise unit (cost 1), the way a front door that learns the
    candidate count only after retrieval has to route. On a
    heterogeneous fleet this sends ranking batches to whichever pool
    quotes the cheapest POINTWISE estimate — usually the low-fixed-cost
    CPU class, where one 512-item batch then burns hundreds of
    milliseconds of steep-curve capacity — which is precisely the
    failure query-size awareness exists to prevent (bench_serving
    experiment 9 measures the gap). Dispatch-side batching still sees
    true costs; only the ADMISSION decision is size-oblivious."""

    name = "cost_model_blind"

    def select_pool(self, req, pools, now):
        return min(pools, key=lambda p: self.estimate(p, 1, now))


class SLOAwareRouter(Router):
    """Latency-aware policy for heterogeneous pools: among pools predicted
    to meet the SLO (and not currently breaching it), send head traffic
    (priority requests) to the highest-quality variant and everything else
    to the cheapest; when no pool can meet the SLO, fall back to the global
    shortest expected delay to limit the damage."""

    name = "slo_aware"

    def __init__(self, slo_p99_s: float = 0.1, quality_order: Sequence[str] = ()):
        self.slo_p99_s = slo_p99_s
        self.quality_order = tuple(quality_order)  # pool names, best model first

    def select_pool(self, req, pools, now):
        meeting = [
            p for p in pools
            if p.predicted_latency(now, req.cost) <= self.slo_p99_s
            and p.recent_p99(now) <= self.slo_p99_s
        ]
        if not meeting:
            return min(pools, key=lambda p: p.predicted_latency(now, req.cost))
        if req.priority and self.quality_order:
            by_name = {p.name: p for p in meeting}
            for name in self.quality_order:
                if name in by_name:
                    return by_name[name]
        return min(meeting, key=lambda p: p.dense_latency(req.cost))


ROUTERS: Dict[str, type] = {
    LeastLoadedRouter.name: LeastLoadedRouter,
    PowerOfTwoRouter.name: PowerOfTwoRouter,
    SLOAwareRouter.name: SLOAwareRouter,
    CostModelRouter.name: CostModelRouter,
    SizeAwareRouter.name: SizeAwareRouter,
    SizeBlindCostModelRouter.name: SizeBlindCostModelRouter,
}


def make_router(name: str, registry: Optional[Dict[str, type]] = None, **kwargs):
    """Instantiate a policy by registry name. The default registry is the
    pool-level ROUTERS; federation.py passes its CELL_POLICIES so cell-level
    policies share the same construction and error path."""
    registry = ROUTERS if registry is None else registry
    try:
        return registry[name](**kwargs)
    except KeyError:
        raise KeyError(f"unknown router policy {name!r}; have {sorted(registry)}") from None
