"""SLO monitoring: sliding-window percentiles for the control loops
(autoscaler, rate-limiter shedding) plus full-run history for end-of-run
reporting and per-pool SLO attribution.

Each ReplicaPool owns one SLOMonitor (stage latencies, measured from entry
into that pool), and the engine owns one more for end-to-end latencies —
so an SLO breach is attributable to the pool that caused it, not just
observed at the front door.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


class SLOMonitor:
    def __init__(self, window_s: float = 10.0, slo_s: Optional[float] = None):
        self.window_s = window_s
        self.slo_s = slo_s
        self.lat: Deque[Tuple[float, float]] = deque()  # (finish_time, latency)
        self.history: List[float] = []  # full-run latencies
        self.arrived = 0
        self.rejected = 0
        self.completed = 0
        self.slo_hits = 0

    def record(self, finish: float, latency: float):
        self.completed += 1
        self.lat.append((finish, latency))
        self.history.append(latency)
        if self.slo_s is not None and latency <= self.slo_s:
            self.slo_hits += 1

    def _trim(self, now: float):
        while self.lat and self.lat[0][0] < now - self.window_s:
            self.lat.popleft()

    def percentiles(self, now: float) -> Dict[str, float]:
        """Sliding-window stats — the signal the control loops react to."""
        self._trim(now)
        if not self.lat:
            return {"p50": 0.0, "p99": 0.0, "qps": 0.0}
        arr = np.array([l for _, l in self.lat])
        # before the first window has elapsed the divisor is the time that
        # actually passed — dividing by the full window understates qps and
        # feeds the shed/scale loops a wrong early signal
        elapsed = max(min(now, self.window_s), 1e-9)
        return {
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "qps": len(arr) / elapsed,
        }

    def attainment(self) -> float:
        """Fraction of completed requests inside the SLO (1.0 when none)."""
        if self.slo_s is None or not self.completed:
            return 1.0
        return self.slo_hits / self.completed

    def totals(self) -> Dict[str, float]:
        """Full-run latency stats (not windowed)."""
        if not self.history:
            return {"p50": 0.0, "p99": 0.0, "mean": 0.0,
                    "completed": 0, "attainment": self.attainment()}
        arr = np.asarray(self.history)
        return {
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()),
            "completed": self.completed,
            "attainment": self.attainment(),
        }
