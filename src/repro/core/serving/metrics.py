"""SLO monitor: sliding-window latency percentiles, QPS, rejects."""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Tuple

import numpy as np


class SLOMonitor:
    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self.lat: Deque[Tuple[float, float]] = deque()  # (finish_time, latency)
        self.admitted = 0
        self.rejected = 0
        self.completed = 0

    def record(self, finish: float, latency: float):
        self.completed += 1
        self.lat.append((finish, latency))

    def _trim(self, now: float):
        while self.lat and self.lat[0][0] < now - self.window_s:
            self.lat.popleft()

    def percentiles(self, now: float) -> Dict[str, float]:
        self._trim(now)
        if not self.lat:
            return {"p50": 0.0, "p99": 0.0, "qps": 0.0}
        arr = np.array([l for _, l in self.lat])
        return {
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "qps": len(arr) / self.window_s,
        }
