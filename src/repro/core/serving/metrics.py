"""SLO monitoring: sliding-window percentiles for the control loops
(autoscaler, rate-limiter shedding) plus full-run history for end-of-run
reporting and per-pool SLO attribution.

Each ReplicaPool owns one SLOMonitor (stage latencies, measured from entry
into that pool), the engine owns one for end-to-end latencies, and in a
multi-cell federation each cell keeps its own on top of one fleet-wide
monitor — so an SLO breach is attributable to the pool AND the cell that
caused it, not just observed at the front door.

Spill attribution (federation.py) is kept separate from rejection
accounting: a request handed to a remote cell is `spilled_out` at its
home cell, `spilled_in` at the serving cell, and counted exactly once in
the fleet-wide conservation identity

    injected == completed + rejected + in_flight

where in_flight includes requests in inter-cell transit (paying RTT).
`federated_rollup` sums per-cell summaries into fleet totals and checks
that identity's spill legs (sum of spilled_out == sum of spilled_in once
transit has drained).

The caching layer (serving/cache.py) reports through here too: each
pool's hit/miss/eviction/result-hit counters roll up per system and per
federation via `fleet_cache_rollup`, and every pool traces its live
hit-rate at each scale tick — so a latency regression is attributable to
a cooling cache, not just observed at the front door. The shard tier
(serving/shard.py) extends the same rollup with staleness (serves of a
superseded row version), the cell-shared L2's hits/misses and the
local/remote shard-fetch split — summed pool -> cell -> fleet without
double counting, because L2 and shard counters enter once per cell.

So does the adaptive control plane (serving/control.py):
`fleet_control_rollup` sums per-pool control summaries (learned latency
corrections + observation counts, adaptive-batch participation) per
system and per federation, and every pool traces its effective
`max_batch_items` and latency correction at each scale tick — a p99
recovery is attributable to the controller narrowing batches or the
online model re-learning a drifted calibration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np


class TraceBuffer:
    """Columnar per-tick trace: one growing numpy buffer per column
    (amortised-doubling appends) instead of one Python list append per
    column per tick. Rows append positionally in the declared column
    order; `as_dict()` converts back to plain Python lists, which is
    what summaries expose (tests compare those by value, and int columns
    round-trip as ints)."""

    __slots__ = ("_names", "_bufs", "_n")

    def __init__(self, columns: Sequence[Union[str, Tuple[str, type]]]) -> None:
        self._names: List[str] = []
        self._bufs: List[np.ndarray] = []
        for col in columns:
            name, dtype = col if isinstance(col, tuple) else (col, np.float64)
            self._names.append(name)
            self._bufs.append(np.empty(16, dtype=dtype))
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def append(self, *values: float) -> None:
        """Append one row, positionally in declared column order."""
        if len(values) != len(self._bufs):
            raise ValueError(
                f"expected {len(self._bufs)} values ({self._names}), "
                f"got {len(values)}"
            )
        n = self._n
        if n == len(self._bufs[0]):
            for i, buf in enumerate(self._bufs):
                grown = np.empty(2 * n, dtype=buf.dtype)
                grown[:n] = buf
                self._bufs[i] = grown
        for buf, value in zip(self._bufs, values):
            buf[n] = value
        self._n = n + 1

    def column(self, name: str) -> np.ndarray:
        """Snapshot COPY of one column (length == rows appended so far).
        This used to hand out a live view, which silently detached from
        the buffer at the next amortised-doubling growth — a caller
        holding the view across appends read frozen, stale data with no
        error. A copy costs O(rows) but makes the contract unambiguous:
        what you got is what the column held when you asked
        (tests/test_events.py pins the growth boundary down)."""
        return self._bufs[self._names.index(name)][: self._n].copy()

    def as_dict(self) -> Dict[str, List]:
        """Plain {column: list} — the summary()-facing representation."""
        return {
            name: buf[: self._n].tolist()
            for name, buf in zip(self._names, self._bufs)
        }


@dataclasses.dataclass
class SpillStats:
    """Per-cell cross-cell traffic accounting (federation.py). Cascade
    stage spills are counted in BOTH the total and the cascade_* legs."""

    spilled_out: int = 0  # requests this cell handed to a remote cell
    spilled_in: int = 0  # requests this cell served for a remote home
    cascade_out: int = 0  # subset of spilled_out that were rerank stages
    cascade_in: int = 0  # subset of spilled_in that were rerank stages

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


# Schema key lists shared by the rollups and the Prometheus registry —
# the single source of truth simlint's SL003 checks consumers against.
# SPILL_KEYS mirrors the SpillStats fields (pinned by a test).
FEDERATED_CONSERVED_KEYS = ("arrived", "completed", "rejected", "in_queue",
                            "completed_in_horizon", "final_replicas")
SPILL_KEYS = ("spilled_out", "spilled_in", "cascade_out", "cascade_in")
CACHE_COUNTER_KEYS = ("hits", "misses", "evictions", "result_hits",
                      "staleness", "invalidated", "l2_hits", "l2_misses",
                      "local_fetches", "remote_fetches")
# (key, help) pairs rendered by MetricsRegistry._add_scope
SCOPE_CONSERVED_KEYS = (
    ("arrived", "requests offered to this scope"),
    ("injected", "requests injected fleet-wide"),
    ("completed", "requests fully served"),
    ("rejected", "requests shed by admission"),
    ("in_queue", "requests still queued at summary time"),
    ("in_flight", "requests queued or in inter-cell transit"),
    ("in_transit", "requests paying an inter-cell RTT"),
    ("completed_in_horizon", "completions inside the horizon"),
    ("spilled", "requests spilled out of their entry cell"),
    ("spilled_in", "spilled requests served for a remote home"),
    ("cascade_spilled", "cascade stages handed to a remote cell"),
    ("dropped_events", "loop events that fired with no handler"),
)
SCOPE_GAUGE_KEYS = (
    ("p50", "full-run median latency (seconds)"),
    ("p99", "full-run p99 latency (seconds)"),
    ("mean_latency", "full-run mean latency (seconds)"),
    ("slo_attainment", "fraction completed inside SLO"),
    ("throughput", "in-horizon completions per second"),
    ("final_replicas", "replicas at summary time"),
)


def fleet_cache_rollup(cache_summaries: Iterable[Dict]) -> Dict:
    """Sum per-pool cache summaries (ReplicaPool.cache_summary() dicts)
    into one tally with the aggregate hit-rates — the caching layer's
    contribution to an engine or federation summary. Pools without a
    cache contribute zeros, so the rollup is meaningful whether zero,
    some, or all pools cache. The shard-tier keys (staleness, l2_*,
    local/remote fetches) are zero below the cell level — per-pool
    summaries don't carry them — and sum when cell cache blocks (which
    the engine extends with L2 + shard-fetch counters) roll up through
    `federated_rollup`. Output keys round-trip as input: feeding rollups
    back through re-sums every counter and recomputes the rates (a
    property the tests pin down)."""
    out: Dict = {key: 0 for key in CACHE_COUNTER_KEYS}
    out["transit_s"] = 0.0
    for s in cache_summaries:
        for key in out:
            out[key] += s.get(key, 0)
    seen = out["hits"] + out["misses"]
    out["hit_rate"] = out["hits"] / seen if seen else 0.0
    l2_seen = out["l2_hits"] + out["l2_misses"]
    out["l2_hit_rate"] = out["l2_hits"] / l2_seen if l2_seen else 0.0
    return out


def fleet_control_rollup(control_summaries: Iterable[Dict]) -> Dict:
    """Sum control summaries into one fleet view of the adaptive
    control plane (serving/control.py): how many pools learn their
    latency online / adapt their batch size, total observation samples,
    and the SAMPLE-WEIGHTED mean learned correction (1.0 when nothing
    observed traffic — an unobserved fleet trusts its calibration).
    Accepts per-pool summaries (ReplicaPool.control_summary()) and,
    because the output keys are themselves accepted as input, per-cell
    rollups — `federated_rollup` feeds cells' "control" blocks straight
    back through, and the sample weighting keeps a one-sample cell from
    diluting a heavily observed drifted one. The dense and fetch
    corrections (control.py learns them separately) are both weighted
    by the pool's total sample count.

    Corrections are learned PER PLATFORM CLASS, never blended across
    classes: `by_platform` keeps a sample-weighted mean per class tag
    (pool summaries carry `platform`; cell rollups carry their own
    `by_platform`, which merges class-wise on the way up) — thermal
    drift on the CPU fleet must not look like a mis-calibrated
    accelerator curve in the fleet view. The TOP-LEVEL means remain the
    all-class blend for backward compatibility."""
    out = {"online_pools": 0, "adaptive_batch_pools": 0, "samples": 0}
    corr_sum = 0.0
    fetch_corr_sum = 0.0
    plat: Dict[str, Dict[str, float]] = {}

    def _per_class(platform: str, n: int, corr: float,
                   fetch: float) -> None:
        d = plat.setdefault(platform, {"samples": 0, "corr": 0.0, "fetch": 0.0})
        d["samples"] += n
        d["corr"] += n * corr
        d["fetch"] += n * fetch

    for s in control_summaries:
        out["online_pools"] += s.get(
            "online_pools", int(bool(s.get("online_latency"))))
        out["adaptive_batch_pools"] += s.get(
            "adaptive_batch_pools", int(bool(s.get("adaptive_batch"))))
        n = s.get("samples", 0)
        out["samples"] += n
        corr = s.get("latency_correction",
                     s.get("mean_latency_correction", 1.0))
        fetch = s.get("fetch_correction",
                      s.get("mean_fetch_correction", 1.0))
        corr_sum += n * corr
        fetch_corr_sum += n * fetch
        nested = s.get("by_platform")
        if nested:
            for p, d in nested.items():
                _per_class(p, d.get("samples", 0),
                           d.get("mean_latency_correction", 1.0),
                           d.get("mean_fetch_correction", 1.0))
        else:
            _per_class(s.get("platform", "generic"), n, corr, fetch)
    out["mean_latency_correction"] = (
        corr_sum / out["samples"] if out["samples"] else 1.0)
    out["mean_fetch_correction"] = (
        fetch_corr_sum / out["samples"] if out["samples"] else 1.0)
    out["by_platform"] = {
        p: {
            "samples": int(d["samples"]),
            "mean_latency_correction": (
                d["corr"] / d["samples"] if d["samples"] else 1.0),
            "mean_fetch_correction": (
                d["fetch"] / d["samples"] if d["samples"] else 1.0),
        }
        for p, d in sorted(plat.items())
    }
    return out


def fleet_breakdown_rollup(breakdowns: Iterable[Optional[Dict]]) -> Dict:
    """Sum per-pool / per-cell `latency_breakdown` blocks
    (tracing.BreakdownAccumulator.summary() dicts) into one aggregate:
    counts, per-component seconds and cumulative histogram rows all sum;
    shares recompute against the summed end-to-end latency. Empty input
    (or blocks from systems with no completions) rolls up to a valid
    all-zero block, and — like the cache/control rollups — output blocks
    feed back in as input, so pool -> cell -> fleet is the same helper
    applied twice."""
    out = {"count": 0, "end_to_end_s": 0.0, "component_sum_s": 0.0,
           "components": {}, "histogram_buckets_s": None, "histograms": {}}
    for block in breakdowns:
        if not block:
            continue
        out["count"] += block.get("count", 0)
        out["end_to_end_s"] += block.get("end_to_end_s", 0.0)
        out["component_sum_s"] += block.get("component_sum_s", 0.0)
        for name, v in block.get("components", {}).items():
            out["components"][name] = out["components"].get(name, 0.0) + v
        buckets = block.get("histogram_buckets_s")
        if buckets is not None:
            if out["histogram_buckets_s"] is None:
                out["histogram_buckets_s"] = list(buckets)
            elif list(buckets) != out["histogram_buckets_s"]:
                raise ValueError(
                    "latency_breakdown blocks disagree on histogram buckets: "
                    f"{buckets} vs {out['histogram_buckets_s']}")
        for name, counts in block.get("histograms", {}).items():
            have = out["histograms"].get(name)
            if have is None:
                out["histograms"][name] = list(counts)
            else:
                for i, c in enumerate(counts):
                    have[i] += c
    denom = out["end_to_end_s"] if out["end_to_end_s"] > 0 else 1.0
    out["shares"] = {n: v / denom for n, v in out["components"].items()}
    return out


def federated_rollup(cells: Dict[str, Dict]) -> Dict[str, int]:
    """Sum per-cell summaries (each a ServingSystem.summary() dict plus a
    "spill" sub-dict) into fleet-wide counters. Latency percentiles do NOT
    roll up from per-cell percentiles — the federation keeps its own
    fleet-wide SLOMonitor for those; this merges the conserved counts
    (plus the cells' cache tallies, via fleet_cache_rollup)."""
    out = {key: 0 for key in FEDERATED_CONSERVED_KEYS + SPILL_KEYS}
    dropped = 0
    dropped_kinds: Dict[str, int] = {}
    for summary in cells.values():
        for key in FEDERATED_CONSERVED_KEYS:
            out[key] += summary[key]
        spill = summary.get("spill", {})
        for key in SPILL_KEYS:
            out[key] += spill.get(key, 0)
        # federated cells share ONE EventLoop, so each cell reports the
        # same loop-global drop counters — merge by max, never sum
        # (summing would multiply the drops by the cell count)
        dropped = max(dropped, summary.get("dropped_events", 0))
        for kind, n in (summary.get("dropped_kinds") or {}).items():
            dropped_kinds[kind] = max(dropped_kinds.get(kind, 0), n)
    out["dropped_events"] = dropped
    out["dropped_kinds"] = dropped_kinds
    out["cache"] = fleet_cache_rollup(
        s.get("cache", {}) for s in cells.values()
    )
    # shard staleness must survive above the cell level even when a
    # consumer drops the cache block: mirror it at the top of the rollup
    out["staleness"] = out["cache"]["staleness"]
    # per-cell control planes roll up through the same helper (cells
    # adapt independently; sample weighting keeps the fleet mean honest)
    out["control"] = fleet_control_rollup(
        s.get("control", {}) for s in cells.values()
    )
    out["latency_breakdown"] = fleet_breakdown_rollup(
        s.get("latency_breakdown") for s in cells.values()
    )
    return out


class SLOMonitor:
    """Latency accounting on growing numpy buffers. Finish times arrive
    in event order (the loop clock never goes backwards), so the
    sliding window is just a [lo:n) slice of the full-run buffers:
    `record` is an O(1) array write, the window trim is a searchsorted
    on the monotone finish-time column instead of a per-event deque
    popleft, and percentile inputs are ready-made float64 slices."""

    def __init__(self, window_s: float = 10.0,
                 slo_s: Optional[float] = None) -> None:
        self.window_s = window_s
        self.slo_s = slo_s
        self._fin = np.empty(1024)  # finish times, monotone non-decreasing
        self._lat = np.empty(1024)  # latencies, same order
        self._n = 0
        self._lo = 0  # sliding-window start: window is lat[_lo:_n]
        self.arrived = 0
        self.rejected = 0
        self.completed = 0
        self.slo_hits = 0

    def record(self, finish: float, latency: float) -> None:
        n = self._n
        if n == len(self._lat):
            for name in ("_fin", "_lat"):
                buf = getattr(self, name)
                grown = np.empty(2 * n)
                grown[:n] = buf
                setattr(self, name, grown)
        self._fin[n] = finish
        self._lat[n] = latency
        self._n = n + 1
        self.completed += 1
        if self.slo_s is not None and latency <= self.slo_s:
            self.slo_hits += 1

    def percentiles(self, now: float) -> Dict[str, float]:
        """Sliding-window stats — the signal the control loops react to."""
        cut = now - self.window_s
        lo, n = self._lo, self._n
        if lo < n and self._fin[lo] < cut:
            lo = int(np.searchsorted(self._fin[:n], cut, side="left"))
            self._lo = lo
        if lo >= n:
            return {"p50": 0.0, "p99": 0.0, "qps": 0.0}
        arr = self._lat[lo:n]
        # before the first window has elapsed the divisor is the time that
        # actually passed — dividing by the full window understates qps and
        # feeds the shed/scale loops a wrong early signal
        elapsed = max(min(now, self.window_s), 1e-9)
        return {
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "qps": (n - lo) / elapsed,
        }

    def attainment(self) -> float:
        """Fraction of completed requests inside the SLO (1.0 when none)."""
        if self.slo_s is None or not self.completed:
            return 1.0
        return self.slo_hits / self.completed

    def totals(self) -> Dict[str, float]:
        """Full-run latency stats (not windowed)."""
        if not self._n:
            return {"p50": 0.0, "p99": 0.0, "mean": 0.0,
                    "completed": 0, "attainment": self.attainment()}
        arr = self._lat[: self._n]
        return {
            "p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()),
            "completed": self.completed,
            "attainment": self.attainment(),
        }


class MetricsRegistry:
    """Prometheus text exposition over a finished run's summary dict.

    `MetricsRegistry.from_summary(summary)` accepts either a
    `FederatedSystem.summary()` (has "cells") or a
    `ServingSystem.summary()` (has "pools") and registers the conserved
    counters (arrived/completed/rejected/in-flight/spill legs — the same
    numbers `federated_rollup` sums, fleet-wide AND per cell), the
    cache/shard tallies including `staleness`, the control-plane
    corrections per platform class, the event loop's
    `dropped_events`/`dropped_kinds`, and the latency-breakdown
    component sums + histograms from the `latency_breakdown` blocks.
    `to_prometheus_text()` renders the standard `# HELP`/`# TYPE` +
    labeled-sample exposition format. Purely read-only over the summary:
    building a registry never mutates a running system."""

    def __init__(self, namespace: str = "repro_serving") -> None:
        self.namespace = namespace
        # name -> (type, help, [(labels dict, value)]) in insertion order
        self._metrics: Dict[str, Tuple[str, str, List[Tuple[Dict, float]]]] = {}

    def add(self, name: str, kind: str, help_: str, value: float,
            **labels: object) -> None:
        full = f"{self.namespace}_{name}"
        if full not in self._metrics:
            self._metrics[full] = (kind, help_, [])
        self._metrics[full][2].append((labels, value))

    # ---- construction from summaries ----
    @classmethod
    def from_summary(cls, summary: Dict,
                     namespace: str = "repro_serving") -> "MetricsRegistry":
        reg = cls(namespace)
        if "cells" in summary:
            reg._add_scope(summary, scope="fleet")
            for name, cell in summary["cells"].items():
                reg._add_scope(cell, scope="cell", cell=name)
        else:
            reg._add_scope(summary, scope="system")
        return reg

    def _add_scope(self, s: Dict, **labels: object) -> None:
        for key, help_ in SCOPE_CONSERVED_KEYS:
            if key in s:
                self.add(f"{key}_total", "counter", help_, s[key], **labels)
        for kind, n in (s.get("dropped_kinds") or {}).items():
            self.add("dropped_events_by_kind_total", "counter",
                     "unhandled loop events by event kind", n,
                     kind=kind, **labels)
        spill = s.get("spill") or {}
        for key in SPILL_KEYS:
            if key in spill:
                self.add(f"spill_{key}_total", "counter",
                         "per-cell spill attribution", spill[key], **labels)
        for key, help_ in SCOPE_GAUGE_KEYS:
            if key in s:
                self.add(key, "gauge", help_, s[key], **labels)
        cache = s.get("cache") or {}
        for key in CACHE_COUNTER_KEYS:
            if key in cache:
                self.add(f"cache_{key}_total", "counter",
                         "embedding cache / shard tier tallies",
                         cache[key], **labels)
        if "transit_s" in cache:
            self.add("shard_transit_seconds_total", "counter",
                     "inter-cell RTT paid by remote shard fetches",
                     cache["transit_s"], **labels)
        control = s.get("control") or {}
        if "samples" in control:
            self.add("control_samples_total", "counter",
                     "online latency-model observations", control["samples"],
                     **labels)
        for plat, d in (control.get("by_platform") or {}).items():
            self.add("control_latency_correction", "gauge",
                     "learned dense-latency correction (1.0 = calibrated)",
                     d.get("mean_latency_correction", 1.0),
                     platform=plat, **labels)
            self.add("control_fetch_correction", "gauge",
                     "learned embed-fetch correction (1.0 = calibrated)",
                     d.get("mean_fetch_correction", 1.0),
                     platform=plat, **labels)
        self._add_breakdown(s.get("latency_breakdown") or {}, **labels)

    def _add_breakdown(self, block: Dict, **labels: object) -> None:
        if not block:
            return
        self.add("latency_breakdown_requests_total", "counter",
                 "requests decomposed into latency components",
                 block.get("count", 0), **labels)
        self.add("latency_end_to_end_seconds_total", "counter",
                 "summed end-to-end latency of decomposed requests",
                 block.get("end_to_end_s", 0.0), **labels)
        for name, v in (block.get("components") or {}).items():
            self.add("latency_component_seconds_total", "counter",
                     "summed per-component latency attribution",
                     v, component=name, **labels)
        buckets = block.get("histogram_buckets_s")
        for name, cum in (block.get("histograms") or {}).items():
            if buckets is None:
                break
            for edge, c in zip(list(buckets) + ["+Inf"], cum):
                le = edge if isinstance(edge, str) else repr(float(edge))
                self.add("latency_component_seconds_bucket", "histogram",
                         "per-component latency distribution (cumulative)",
                         c, component=name, le=le, **labels)

    # ---- rendering ----
    @staticmethod
    def _fmt_value(v: float) -> str:
        if isinstance(v, bool):
            return str(int(v))
        if isinstance(v, int):
            return str(v)
        f = float(v)
        return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)

    @staticmethod
    def _fmt_label(v: object) -> str:
        s = str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
        return f'"{s}"'

    def to_prometheus_text(self) -> str:
        """The text exposition format scrapers ingest: `# HELP`/`# TYPE`
        headers once per metric, then one labeled sample per line."""
        lines: List[str] = []
        for name, (kind, help_, samples) in self._metrics.items():
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in samples:
                if labels:
                    inner = ",".join(
                        f"{k}={self._fmt_label(v)}" for k, v in labels.items()
                    )
                    lines.append(f"{name}{{{inner}}} {self._fmt_value(value)}")
                else:
                    lines.append(f"{name} {self._fmt_value(value)}")
        return "\n".join(lines) + "\n"
