"""Elastic inference engine: discrete-event loop tying together the
batcher, least-loaded router, autoscaler, warm pool, tiered rate limiter
and SLO monitor (paper §IV.B). Service times come from LatencyModels
calibrated on real jitted executables (replica.py), so "Distilled+int8 vs
Baseline under a traffic spike" is an experiment, not an assertion.

Events: ARRIVAL -> admit (rate limit) -> enqueue (priority bypass skips
batching) -> router picks least-loaded replica when a batch closes
(max_batch or max_wait) -> SERVICE_DONE records latency -> SCALE_TICK
drives the autoscaler from sliding-window utilisation.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.serving.autoscaler import AutoScaler, ScalerConfig
from repro.core.serving.metrics import SLOMonitor
from repro.core.serving.rate_limiter import HybridRateLimiter, TierPolicy
from repro.core.serving.replica import Replica, ReplicaSpec


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 64
    max_wait_s: float = 0.005
    slo_p99_s: float = 0.100
    scale_tick_s: float = 1.0
    n_replicas: int = 2
    autoscale: bool = True
    priority_bypass: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    t_arrive: float
    tier: str
    priority: bool = False


class ElasticEngine:
    def __init__(
        self,
        spec: ReplicaSpec,
        cfg: EngineConfig,
        tiers: Optional[Dict[str, TierPolicy]] = None,
        scaler_cfg: Optional[ScalerConfig] = None,
    ):
        self.spec = spec
        self.cfg = cfg
        self.limiter = HybridRateLimiter(
            tiers or {"tier0": TierPolicy(1e9, 1e9), "tier1": TierPolicy(1e9, 1e9)}
        )
        self.scaler = AutoScaler(scaler_cfg or ScalerConfig(min_replicas=cfg.n_replicas))
        self.monitor = SLOMonitor()
        self.replicas: List[Replica] = [
            Replica(i, spec, ready_at=0.0) for i in range(cfg.n_replicas)
        ]
        self._registry: Dict[int, Replica] = {r.rid: r for r in self.replicas}
        self._rid = itertools.count(len(self.replicas))

    # ---- router ----
    def _pick_replica(self, now: float) -> Replica:
        return min(self.replicas, key=lambda r: r.load(now))

    def _utilisation(self, now: float, horizon: float) -> float:
        # booting replicas are excluded — counting them as busy makes the
        # scaler chase its own pending capacity (observed 25-replica
        # overshoot under cold starts)
        ready = [r for r in self.replicas if r.ready_at <= now]
        if not ready:
            return 1.0
        busy = sum(min(max(r.busy_until - now, 0.0), horizon) for r in ready)
        return busy / (horizon * len(ready))

    # ---- simulation ----
    def run(
        self,
        arrivals: List[Request],
        until: Optional[float] = None,
    ) -> Dict:
        cfg = self.cfg
        events: List[Tuple[float, int, str, object]] = []
        seq = itertools.count()
        for r in arrivals:
            heapq.heappush(events, (r.t_arrive, next(seq), "arrive", r))
        if cfg.autoscale:
            heapq.heappush(events, (cfg.scale_tick_s, next(seq), "scale", None))

        queue: List[Request] = []
        batch_deadline: Optional[float] = None
        trace = {"t": [], "p99": [], "qps": [], "replicas": [], "queue": []}
        horizon = until or (arrivals[-1].t_arrive + 5.0 if arrivals else 5.0)

        def flush(now: float):
            nonlocal batch_deadline
            while queue:
                take = queue[: cfg.max_batch]
                del queue[: cfg.max_batch]
                rep = self._pick_replica(now)
                done = rep.start_batch(now, len(take))
                heapq.heappush(events, (done, next(seq), "done", (rep.rid, take, now)))
                if len(queue) < cfg.max_batch:
                    break
            batch_deadline = None

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if now > horizon and kind in ("scale",):
                continue
            if kind == "arrive":
                r: Request = payload  # type: ignore
                self.monitor.admitted += 1
                if not self.limiter.admit(now, r.tier):
                    self.monitor.rejected += 1
                    continue
                if cfg.priority_bypass and r.priority:
                    rep = self._pick_replica(now)
                    done = rep.start_batch(now, 1)
                    heapq.heappush(events, (done, next(seq), "done", (rep.rid, [r], now)))
                    continue
                queue.append(r)
                if len(queue) >= cfg.max_batch:
                    flush(now)
                elif batch_deadline is None:
                    batch_deadline = now + cfg.max_wait_s
                    heapq.heappush(events, (batch_deadline, next(seq), "timeout", None))
            elif kind == "timeout":
                if batch_deadline is not None and now >= batch_deadline and queue:
                    flush(now)
            elif kind == "done":
                rep_id, batch, started = payload  # type: ignore
                rep = self._registry[rep_id]
                rep.in_flight -= 1
                for r in batch:
                    self.monitor.record(now, now - r.t_arrive)
            elif kind == "scale":
                stats = self.monitor.percentiles(now)
                util = self._utilisation(now, cfg.scale_tick_s)
                self.limiter.adapt(stats["p99"], cfg.slo_p99_s)
                want = self.scaler.desired(now, len(self.replicas), util)
                while want > len(self.replicas):
                    delay = self.scaler.take_start_delay(
                        self.spec.warm_start_s, self.spec.cold_start_s
                    )
                    rep = Replica(next(self._rid), self.spec, ready_at=now + delay)
                    self.replicas.append(rep)
                    self._registry[rep.rid] = rep
                # graceful scale-down: retire only drained replicas
                idle = [r for r in self.replicas if r.in_flight == 0 and r.busy_until <= now]
                while want < len(self.replicas) and len(self.replicas) > 1 and idle:
                    victim = idle.pop()
                    self.replicas.remove(victim)
                    self.scaler.replenish()
                trace["t"].append(now)
                trace["p99"].append(stats["p99"])
                trace["qps"].append(stats["qps"])
                trace["replicas"].append(len(self.replicas))
                trace["queue"].append(len(queue))
                if now + cfg.scale_tick_s <= horizon:
                    heapq.heappush(
                        events, (now + cfg.scale_tick_s, next(seq), "scale", None)
                    )

        final = self.monitor.percentiles(horizon)
        all_lat = np.array([l for _, l in self.monitor.lat]) if self.monitor.lat else np.zeros(1)
        return {
            "p50": final["p50"],
            "p99": final["p99"],
            "mean_latency": float(all_lat.mean()),
            "completed": self.monitor.completed,
            "rejected": self.monitor.rejected,
            "throughput": self.monitor.completed / horizon,
            "final_replicas": len(self.replicas),
            "trace": trace,
        }


def poisson_arrivals(
    rate_fn: Callable[[float], float],
    horizon: float,
    *,
    seed: int = 0,
    tiers: Tuple[str, ...] = ("tier0", "tier1"),
    priority_frac: float = 0.02,
) -> List[Request]:
    """Inhomogeneous Poisson traffic via thinning; rate_fn(t) in QPS."""
    rng = np.random.default_rng(seed)
    peak = max(rate_fn(t) for t in np.linspace(0, horizon, 200)) + 1e-9
    out, t, rid = [], 0.0, 0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= horizon:
            break
        if rng.random() < rate_fn(t) / peak:
            out.append(
                Request(
                    rid, t,
                    tier=str(rng.choice(tiers)),
                    priority=bool(rng.random() < priority_frac),
                )
            )
            rid += 1
    return out
