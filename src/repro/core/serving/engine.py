"""Heterogeneous multi-pool serving engine (paper §IV.B).

Post-refactor layering — the engine is an orchestrator, not a monolith:

    events.py    EventLoop        the discrete-event kernel
    replica.py   Replica/Spec     calibrated service times, start costs
    pool.py      ReplicaPool      per-variant batcher + AutoScaler + SLOMonitor
    router.py    Router policies  least-loaded / power-of-two / SLO-aware /
                                  cost-model (recommended) / size-aware
                                  (recommended on fleets mixing platform
                                  classes: pointwise -> CPU-class pools,
                                  ranking -> accelerator-class pools)
    cascade.py   CascadeDispatcher  light-filter -> heavy-rerank chaining
    cache.py     EmbeddingCache/ResultCache  per-pool hot-ID caching:
                                  misses pay embed_fetch_s, repeats can
                                  complete straight from the result cache
    shard.py     EmbeddingShardService  the sharded table under the
                                  caches: pool L1 misses probe a cell-
                                  shared L2 (CacheConfig.l2, built here),
                                  the rest fetch from home/remote shards;
                                  versioned updates invalidate downward
    control.py   OnlineLatencyModel/BatchSizeController  adaptive control
                                  plane: EWMA-corrected latency curve +
                                  SLO-aware per-pool batch sizing
    autoscaler.py CapacityBudget  fleet-wide replica cap shared by pools
    this file    ServingSystem    admission (rate limit) -> route -> pools
    federation.py Cell/FederatedSystem  cells (one system each) on one
                                  shared loop, cross-cell spillover

A ServingSystem normally owns its EventLoop and handles "arrive"/"scale"
events; pass `loop`/`event_ns` to embed it as one cell of a federation
instead — events are namespaced ("arrive:<cell>") and the federation
drives admission through try_submit()/inject() and start().

ServingSystem runs any number of Table-I variant pools on one event loop:
ARRIVAL -> admit (fleet-global tiered rate limit, then the target pool's
own cost-weighted limiter if configured) -> router (or cascade) picks the
pool -> pool batches by request count AND work items, picks the replica ->
BATCH_DONE records per-pool stage latency and, for cascades, chains the
next stage -> SCALE_TICK drives every pool's autoscaler against the shared
capacity budget and every pool-local limiter against its own SLO signal.

ElasticEngine remains as the single-pool convenience wrapper: the
constructor/run surface is unchanged for existing callers (launchers,
end-to-end examples), but the summary metrics were deliberately
redefined — p50/p99 are now full-run percentiles (previously the last
10s sliding window) and "throughput" counts only completions inside the
horizon (previously all completions, including post-horizon backlog
drain). Numbers are not comparable with pre-refactor runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.serving.autoscaler import CapacityBudget, ScalerConfig
from repro.core.serving.cache import CacheConfig, EmbeddingCache
from repro.core.serving.cascade import CascadeConfig, CascadeDispatcher
from repro.core.serving.shard import EmbeddingShardService
from repro.core.serving.control import ControlConfig
from repro.core.serving.events import EventLoop
from repro.core.serving.metrics import (
    SLOMonitor, TraceBuffer, fleet_cache_rollup, fleet_control_rollup,
)
from repro.core.serving.tracing import BreakdownAccumulator, Tracer
from repro.core.serving.pool import PoolConfig, ReplicaPool, Request
from repro.core.serving.rate_limiter import HybridRateLimiter, TierPolicy
from repro.core.serving.replica import ReplicaSpec
from repro.core.serving.router import LeastLoadedRouter, Router


@dataclasses.dataclass
class PoolSpec:
    """Everything needed to bring up one variant pool. `tiers` gives the
    pool its own cost-weighted rate limiter (sheds from the pool's own SLO
    signal); None leaves admission to the fleet-global limiter alone.
    `cache` gives the pool its own hot-ID embedding cache (and optionally
    a result cache) — see serving/cache.py; None means every embedding
    row the pool's traffic carries pays `ReplicaSpec.embed_fetch_s`.
    `control` opts the pool into the adaptive control plane — an online-
    corrected latency curve and/or SLO-aware batch sizing (see
    serving/control.py); None keeps the static pre-control behaviour."""

    spec: ReplicaSpec
    cfg: PoolConfig = dataclasses.field(default_factory=PoolConfig)
    scaler: Optional[ScalerConfig] = None
    tiers: Optional[Dict[str, TierPolicy]] = None
    cache: Optional[CacheConfig] = None
    control: Optional[ControlConfig] = None


@dataclasses.dataclass
class EngineConfig:
    """Single-pool knobs (pre-refactor API, used by ElasticEngine)."""

    max_batch: int = 64
    max_wait_s: float = 0.005
    max_batch_items: Optional[int] = None  # close batches by work items too
    slo_p99_s: float = 0.100
    scale_tick_s: float = 1.0
    n_replicas: int = 2
    autoscale: bool = True
    priority_bypass: bool = True


class ServingSystem:
    def __init__(
        self,
        pools: Dict[str, Union[PoolSpec, ReplicaSpec]],
        router: Optional[Router] = None,
        *,
        tiers: Optional[Dict[str, TierPolicy]] = None,
        slo_p99_s: float = 0.100,
        scale_tick_s: float = 1.0,
        capacity: Optional[Union[int, CapacityBudget]] = None,
        cascade: Optional[CascadeConfig] = None,
        adaptive_shedding: bool = True,
        loop: Optional[EventLoop] = None,
        event_ns: str = "",
        scheduler: str = "calendar",
        strict_events: bool = False,
        shard: Optional[EmbeddingShardService] = None,
        tracer: Optional[Tracer] = None,
    ):
        # `loop`/`event_ns` let a federation embed several systems (cells)
        # on ONE shared clock: each system's events — and its pools' — are
        # suffixed with the namespace so same-named pools never collide.
        # `scheduler` picks the pending-event store ("calendar" fast path
        # or the seed "heap"); `strict_events` makes unhandled event kinds
        # raise instead of being counted (both forwarded to EventLoop and
        # ignored when an existing `loop` is passed in).
        self.loop = (
            loop if loop is not None
            else EventLoop(scheduler=scheduler, strict=strict_events)
        )
        self.event_ns = event_ns
        self.router = router or LeastLoadedRouter()
        self.slo_p99_s = slo_p99_s
        self.scale_tick_s = scale_tick_s
        self.adaptive_shedding = adaptive_shedding
        self.limiter = HybridRateLimiter(
            tiers or {"tier0": TierPolicy(1e9, 1e9), "tier1": TierPolicy(1e9, 1e9)}
        )
        if isinstance(capacity, CapacityBudget):
            self.budget: Optional[CapacityBudget] = capacity
        else:
            self.budget = CapacityBudget(capacity) if capacity is not None else None
        self.monitor = SLOMonitor(slo_s=slo_p99_s)  # end-to-end latencies
        self.shard = shard
        # latency attribution (serving/tracing.py): always-on end-to-end
        # breakdown; the tracer is optional, shared with every pool, and
        # observes only — no simulation decision or summary reads it
        self.breakdown = BreakdownAccumulator()
        self.tracer = tracer
        specs = {
            name: ps if isinstance(ps, PoolSpec) else PoolSpec(ps)
            for name, ps in pools.items()
        }
        # cell-shared L2: ONE EmbeddingCache for the whole system (cell),
        # described by the pools' CacheConfig.l2 — every pool that sets it
        # must agree, because they are describing the same shared cache.
        # Registered with the shard service BEFORE any pool L1 so
        # invalidations propagate shard -> L2 -> L1.
        l2_cfgs = {
            (ps.cache.l2.capacity_rows, ps.cache.l2.policy)
            for ps in specs.values()
            if ps.cache is not None and ps.cache.l2 is not None
        }
        if len(l2_cfgs) > 1:
            raise ValueError(
                f"pools disagree on the shared L2 cache config: {sorted(l2_cfgs)}"
            )
        self.l2_cache: Optional[EmbeddingCache] = None
        if l2_cfgs:
            cap, policy = min(l2_cfgs)  # singleton; min() is order-free
            self.l2_cache = EmbeddingCache(cap, policy)
            if shard is not None:
                shard.register_cache(self.l2_cache)
        self.pools: Dict[str, ReplicaPool] = {}
        for name, ps in specs.items():
            has_l2 = ps.cache is not None and ps.cache.l2 is not None
            self.pools[name] = ReplicaPool(
                name, ps.spec, ps.cfg, self.loop,
                scaler_cfg=ps.scaler, budget=self.budget,
                on_complete=self._stage_complete, slo_s=slo_p99_s,
                picker=self.router.select_replica, tiers=ps.tiers,
                event_key=f"{event_ns}/{name}" if event_ns else name,
                cache_cfg=ps.cache, control_cfg=ps.control,
                l2_cache=self.l2_cache if has_l2 else None,
                shard=shard, cell=event_ns, tracer=tracer,
            )
        self.cascade = CascadeDispatcher(cascade) if cascade is not None else None
        if self.cascade is not None:
            for stage in (cascade.stage1, cascade.stage2):
                if stage not in self.pools:
                    raise KeyError(f"cascade stage pool {stage!r} not configured")
        # federation hooks: on_complete fires after a request fully finishes
        # here; spill_stage may claim a cascade's next stage for a remote
        # cell (returns True when it took the request)
        self.on_complete: Optional[Callable[[float, Request], None]] = None
        self.spill_stage: Optional[Callable[[float, Request, str], bool]] = None
        self._horizon = float("inf")
        self._completed_in_horizon = 0
        self._ran = False
        self.trace = TraceBuffer([
            "t", "p99", "qps", ("replicas", np.int64), ("queue", np.int64)
        ])
        self.loop.on(self._event("arrive"), self._handle_arrive)
        self.loop.on(self._event("scale"), self._handle_scale)
        if shard is not None:
            # online table updates for standalone systems: push/stream
            # ("shard_update", ids) events (namespaced when embedded; a
            # federation additionally handles the global "shard_update")
            self.loop.on(self._event("shard_update"), self._handle_shard_update)

    def _event(self, kind: str) -> str:
        return f"{kind}:{self.event_ns}" if self.event_ns else kind

    def _handle_shard_update(self, now: float, ids) -> None:
        self.shard.publish(ids)

    # ---- admission path (reusable: the arrive handler and federation
    # cells both go through it) ----
    def try_submit(self, now: float, req: Request) -> bool:
        """Admission WITHOUT arrival/rejection accounting: fleet limiter ->
        cascade redirect or router -> pool-local (cost-weighted) admission.
        Returns False when any admission layer sheds the request — the
        caller decides whether that is a rejection or a cross-cell spill."""
        if not self.limiter.admit(now, req.tier):
            return False
        if self.cascade is not None:
            req, pool = self.cascade.admit(req, self.pools)
        else:
            pool = self.router.select_pool(req, list(self.pools.values()), now)
        return pool.submit(now, req)

    def inject(self, now: float, req: Request) -> bool:
        """Full admission path with accounting: one arrival, admitted or
        rejected. Standalone systems run every request through this."""
        self.monitor.arrived += 1
        if self.try_submit(now, req):
            return True
        self.monitor.rejected += 1
        return False

    # ---- event handlers ----
    def _handle_arrive(self, now: float, req: Request) -> None:
        self.inject(now, req)

    def _stage_complete(self, now: float, req: Request, pool: ReplicaPool) -> None:
        if self.cascade is not None:
            nxt = self.cascade.advance(req, self.pools)
            if nxt is not None:
                # a cascade stays within its home cell unless the federation
                # claims the next stage for a remote cell (rerank spillover)
                if self.spill_stage is not None and self.spill_stage(now, req, nxt.name):
                    return
                # stage advancement bypasses pool admission: the cascade has
                # already spent stage-1 work on this request
                nxt.submit(now, req, force=True)
                return
        self.monitor.record(now, now - req.t_arrive)
        # end-to-end attribution at the same instant and from the same
        # floats the monitor records — the decomposition's total IS the
        # recorded latency, bit for bit
        self.breakdown.observe(req, now)
        if self.tracer is not None and self.tracer.sampled(req.rid):
            self.tracer.record_request(req, now,
                                       track=self.event_ns or "system")
        if now <= self._horizon:
            self._completed_in_horizon += 1
        if self.on_complete is not None:
            self.on_complete(now, req)

    def _handle_scale(self, now: float, _payload) -> None:
        if now > self._horizon:
            return
        stats = self.monitor.percentiles(now)
        if self.adaptive_shedding:
            self.limiter.adapt(stats["p99"], self.slo_p99_s)
        for pool in self.pools.values():
            pool.scale_tick(now, self.scale_tick_s)
        self.trace.append(
            now, stats["p99"], stats["qps"],
            sum(len(p.replicas) for p in self.pools.values()),
            sum(len(p.queue) for p in self.pools.values()),
        )
        if now + self.scale_tick_s <= self._horizon:
            self.loop.push(now + self.scale_tick_s, self._event("scale"))

    # ---- simulation ----
    def start(self, horizon: float) -> None:
        """Set the reporting horizon and arm the scale tick — marking the
        system as started, so a later run() raises. run() calls this; a
        federation embedding this system on a shared loop calls it
        directly (and later drains the loop itself)."""
        self._ran = True
        self._horizon = horizon
        # clamp the FIRST tick into the horizon: with horizon <
        # scale_tick_s the old `push(scale_tick_s)` fired past it, so
        # short runs got empty traces and the limiter/scaler/controller
        # loops never ran at all
        self.loop.push(min(self.scale_tick_s, horizon), self._event("scale"))

    def run(self, arrivals: List[Request], until: Optional[float] = None) -> Dict:
        if self._ran:
            raise RuntimeError(
                "this ServingSystem has already run once; monitors, queues and "
                "replica state accumulate across runs — build a fresh system"
            )
        if arrivals:
            # lazily merged stream instead of one heap tuple per arrival:
            # pending memory is O(1) per stream. The stable sort by
            # t_arrive reproduces the seed's (t, push-order) fire order
            # exactly, even for unsorted arrival lists, and stream events
            # beat queued events at equal timestamps just as the
            # arrival pushes (lowest sequence numbers) used to.
            ordered = sorted(arrivals, key=lambda r: r.t_arrive)
            self.loop.add_stream(
                self._event("arrive"), ((r.t_arrive, r) for r in ordered)
            )
        # `until is not None` (not truthiness): until=0.0 is a valid horizon
        self.start(until if until is not None else default_horizon(arrivals))
        self.loop.run()
        return self.summary()

    def summary(self) -> Dict:
        totals = self.monitor.totals()
        in_queue = sum(len(p.queue) for p in self.pools.values())
        cache = fleet_cache_rollup(p.cache_summary() for p in self.pools.values())
        if self.l2_cache is not None:
            # the shared L2 is cell-level state, not any one pool's: fold
            # its counters into the cell cache block under their own keys
            # (fleet_cache_rollup sums them upward through federated_rollup)
            s = self.l2_cache.stats()
            cache["l2_hits"] = s["hits"]
            cache["l2_misses"] = s["misses"]
            cache["l2_hit_rate"] = s["hit_rate"]
            cache["staleness"] += s["staleness"]
            cache["invalidated"] += s["invalidated"]
        if self.shard is not None:
            cache.update(self.shard.cell_stats(self.event_ns))
        return {
            "p50": totals["p50"],
            "p99": totals["p99"],
            "mean_latency": totals["mean"],
            "slo_attainment": totals["attainment"],
            "arrived": self.monitor.arrived,
            "completed": self.monitor.completed,
            "rejected": self.monitor.rejected,
            "in_queue": in_queue,
            # sustained rate: completions INSIDE the horizon — backlog that
            # only drains after traffic stops is not throughput the system
            # sustained (total completions stay in "completed")
            "completed_in_horizon": self._completed_in_horizon,
            "throughput": (
                self._completed_in_horizon / self._horizon if self._horizon > 0 else 0.0
            ),
            "final_replicas": sum(len(p.replicas) for p in self.pools.values()),
            "cache": cache,
            "control": fleet_control_rollup(
                p.control_summary() for p in self.pools.values()
            ),
            # this cell's OWN shard traffic (fleet-global shard state lives
            # in FederatedSystem.summary()["shard"])
            "shard": (
                self.shard.cell_stats(self.event_ns)
                if self.shard is not None else None
            ),
            # events that fired with no registered handler on this system's
            # loop (shared with every cell when federated); the seed kernel
            # dropped these silently
            "dropped_events": self.loop.dropped_events,
            "dropped_kinds": dict(self.loop.dropped_kinds),
            # end-to-end latency attribution (serving/tracing.py): per-
            # component seconds whose per-request sums equal the recorded
            # latencies exactly
            "latency_breakdown": self.breakdown.summary(),
            "trace": self.trace.as_dict(),
            "pools": {name: p.summary() for name, p in self.pools.items()},
        }


class ElasticEngine(ServingSystem):
    """Single-pool convenience wrapper: one variant, least-loaded routing —
    the pre-refactor surface, now a 10-line shim over ServingSystem.
    Simulation behavior matches the old engine; reported metrics follow
    the new full-run/in-horizon definitions (see module docstring)."""

    def __init__(
        self,
        spec: ReplicaSpec,
        cfg: Optional[EngineConfig] = None,
        tiers: Optional[Dict[str, TierPolicy]] = None,
        scaler_cfg: Optional[ScalerConfig] = None,
    ):
        cfg = cfg or EngineConfig()
        self.spec = spec
        self.cfg = cfg
        pool_cfg = PoolConfig(
            max_batch=cfg.max_batch, max_wait_s=cfg.max_wait_s,
            max_batch_items=cfg.max_batch_items,
            n_replicas=cfg.n_replicas, autoscale=cfg.autoscale,
            priority_bypass=cfg.priority_bypass,
        )
        super().__init__(
            {spec.variant: PoolSpec(spec, pool_cfg, scaler_cfg)},
            LeastLoadedRouter(),
            tiers=tiers, slo_p99_s=cfg.slo_p99_s, scale_tick_s=cfg.scale_tick_s,
            # the pre-refactor engine only ran limiter adaptation from the
            # scale tick, which existed only when autoscaling — mirror that
            adaptive_shedding=cfg.autoscale,
        )

    @property
    def replicas(self):
        (pool,) = self.pools.values()
        return pool.replicas


def default_horizon(arrivals: List[Request]) -> float:
    """Reporting horizon when the caller gives none: LATEST arrival plus
    a drain margin. Shared by ServingSystem.run and FederatedSystem.run
    so standalone and federated runs stay comparable. (This used to read
    `arrivals[-1]`, which silently under-reported the horizon for
    unsorted arrival lists.)"""
    if not arrivals:
        return 5.0
    return max(r.t_arrive for r in arrivals) + 5.0


def attach_zipf_ids(
    arrivals: List[Request],
    vocab: int,
    ids_per_request: int,
    *,
    alpha: float = 1.1,
    seed: int = 0,
    offset: int = 0,
    n_distinct: Optional[int] = None,
) -> List[Request]:
    """Give each arrival the embedding ids its lookups touch, drawn from
    `zipf_id_stream` (data/synthetic.py) — the workload the caching layer
    (serving/cache.py) exists for.

    Default: one long stream chopped into per-request tuples (every
    query distinct — exercises the EmbeddingCache alone). With
    `n_distinct`, arrivals instead draw (Zipf again, hot queries repeat
    often) from a pool of that many distinct query signatures, which is
    what makes the ResultCache earn its keep. `offset` shifts the id
    range so different cells can model DISJOINT hot sets (cell-resident
    users): a request spilled to a remote cell then misses that cell's
    cache cold. Idempotent on replay (same args reassign the same ids);
    mutates and returns `arrivals`."""
    from repro.data.synthetic import zipf_id_stream

    n = ids_per_request * (n_distinct if n_distinct is not None else len(arrivals))
    stream = zipf_id_stream(n, vocab, alpha, seed=seed) + offset
    sigs = [
        tuple(stream[i * ids_per_request:(i + 1) * ids_per_request])
        for i in range(n // ids_per_request)
    ]
    if n_distinct is None:
        for req, sig in zip(arrivals, sigs):
            req.ids = sig
    else:
        pick = zipf_id_stream(len(arrivals), n_distinct, alpha, seed=seed + 1)
        for req, k in zip(arrivals, pick):
            req.ids = sigs[int(k)]
    return arrivals


def poisson_arrivals(
    rate_fn: Callable[[float], float],
    horizon: float,
    *,
    seed: int = 0,
    tiers: Tuple[str, ...] = ("tier0", "tier1"),
    priority_frac: float = 0.02,
    cost: int = 1,
    cost_mix: Optional[Sequence[Tuple[int, float]]] = None,
) -> List[Request]:
    """Inhomogeneous Poisson traffic via thinning; rate_fn(t) in QPS.
    `cost` is the per-request work size (candidates to score) — 1 for
    pointwise traffic, the candidate-set size for ranking traffic.
    `cost_mix` overrides `cost` with a weighted distribution of sizes,
    e.g. ((1, 0.9), (512, 0.1)) for 90% pointwise / 10% ranking traffic —
    deterministic under the same seed."""
    rng = np.random.default_rng(seed)
    if cost_mix is not None:
        mix_costs = np.asarray([c for c, _ in cost_mix], dtype=np.int64)
        mix_w = np.asarray([w for _, w in cost_mix], dtype=np.float64)
        mix_w = mix_w / mix_w.sum()
    peak = max(rate_fn(t) for t in np.linspace(0, horizon, 200)) + 1e-9
    out, t, rid = [], 0.0, 0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= horizon:
            break
        if rng.random() < rate_fn(t) / peak:
            out.append(
                Request(
                    rid, t,
                    tier=str(rng.choice(tiers)),
                    priority=bool(rng.random() < priority_frac),
                    cost=int(rng.choice(mix_costs, p=mix_w)) if cost_mix is not None else cost,
                )
            )
            rid += 1
    return out
