"""RecPipe-style cascade inference (arXiv 2105.08820) as chained service
events: stage 1 scores the FULL candidate set on a light pool (distilled /
int8), stage 2 reranks only the top-k survivors on the heavy pool. The
heavy model therefore sees k items per query instead of the full set —
latency and throughput scale with k while ranking quality is anchored by
the strong reranker.

Public API
    CascadeConfig       stage pool names + candidates / rerank_k (ITEMS)
    CascadeDispatcher.admit    redirect a fresh arrival into stage 1
                               (clones the request; timeline dict shared)
    CascadeDispatcher.advance  on stage completion, mutate the request
                               into its next stage and return the next
                               pool (None = cascade finished)

The dispatcher owns no clock and no queue: it redirects a request's entry
pool at admission and, when a stage's batch completes, mutates the request
into its next stage and resubmits it to the next pool on the same event
loop. End-to-end latency is then exactly stage-1 (queue + service) plus
stage-2 (queue + service), which the tests assert from the per-stage
timeline stamps (`s1_*`, `s2_*` — stage 0 stamps under `s0_*`, so one
arrival list replays cleanly through baseline AND cascade runs).

Invariants: stage advancement uses `submit(force=True)` — work already
paid for upstream is never shed mid-chain; each stage stamps enqueue <=
start <= done in order. In a multi-cell federation a cascade stays within
its home cell, with one exception: the engine's `spill_stage` hook may
hand the rerank stage to a remote cell's same-named pool when the home
rerank pool is past its SLO headroom (the request then pays the
inter-cell RTT between `s1_done` and `s2_enqueue` — stamps survive the
hop because the stage prefix, not the cell, keys them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.serving.pool import ReplicaPool, Request


@dataclasses.dataclass
class CascadeConfig:
    stage1: str  # light filter pool name (e.g. "distilled")
    stage2: str  # heavy rerank pool name (e.g. "baseline")
    candidates: int = 512  # stage-1 scores the full candidate set
    rerank_k: int = 32  # stage-2 reranks the top-k survivors


class CascadeDispatcher:
    def __init__(self, cfg: CascadeConfig):
        self.cfg = cfg

    def admit(self, req: Request, pools: Dict[str, ReplicaPool]) -> Tuple[Request, ReplicaPool]:
        """Route a fresh arrival into stage 1 with the full candidate load.
        The arrival is cloned (sharing its timeline dict, so the caller can
        still read per-stage stamps) — arrival lists are commonly reused
        across A/B runs and must never come back with mutated cost/stage.
        Sharing is safe because stage prefixes never collide: a baseline
        run stamps s0_* (Request.stamp keys by the request's own stage)
        while cascade stages stamp s1_*/s2_*."""
        staged = dataclasses.replace(req, stage=1, cost=self.cfg.candidates)
        return staged, pools[self.cfg.stage1]

    def advance(self, req: Request, pools: Dict[str, ReplicaPool]) -> Optional[ReplicaPool]:
        """Called when a stage completes. Returns the next pool to submit
        the request to, or None when the cascade is finished."""
        if req.stage == 1:
            req.stage = 2
            req.cost = self.cfg.rerank_k
            return pools[self.cfg.stage2]
        return None
