"""Request-level tracing + latency attribution for the serving stack.

Two independent mechanisms live here, deliberately decoupled:

1.  **Attribution (always on, O(1) memory).** Every completed request
    decomposes into named latency components whose left-to-right float
    sum equals its end-to-end latency BIT-EXACTLY — an invariant, not an
    estimate (`decompose`, property-tested in
    tests/test_serving_properties.py). `BreakdownAccumulator` aggregates
    the components into the `latency_breakdown` blocks that pool, cell
    and fleet summaries expose (fleet level via
    `metrics.fleet_breakdown_rollup`).

2.  **Tracing (opt-in, sampled, bounded).** A `Tracer` records a span
    tree for a deterministic 1-in-N sample of requests — queue wait,
    replica wait, service sub-phases, inter-cell transit, per-batch
    replica occupancy — in columnar `TraceBuffer` storage, exported as
    Chrome trace-event JSON (`Tracer.to_chrome_trace()`, loadable in
    Perfetto / chrome://tracing). The tracer only ever OBSERVES: it owns
    no RNG, mutates no request, and feeds no summary, so enabling it
    leaves every summary bit-identical to an untraced run (also
    property-tested).

Component taxonomy (docs/observability.md; summed across cascade stages):

    queue_wait        enqueue -> batch close (waiting for the batch to fill
                      or its deadline to fire)
    replica_wait      batch close -> service start (target replica busy /
                      still booting)
    dense_compute     the batch's dense forward pass (calibrated curve at
                      the batch's work items; the drifted curve when the
                      control plane models drift)
    embed_fetch_local rows fetched from shards homed in the serving cell
                      (and, pre-shard, every modelled row fetch)
    embed_fetch_remote rows fetched from remote-cell shards
    shard_transit     the batched inter-cell RTT those remote fetches paid
    transit           everything between stages: front-door routing hops,
                      cross-cell spill RTT, cascade hand-offs — computed
                      as the residual `total - sum(above)`, which is what
                      those gaps are mathematically
    closure           sub-ULP rounding closure (see below), ~1e-16 of the
                      total; kept separate so `transit` stays physically
                      meaningful

Exactness: stamp differences do not telescope bit-exactly in IEEE-754,
and a single residual term provably cannot always close the sum (with
round-ties-to-even an odd-mantissa total can be unreachable from
`fl(acc + r)` for EVERY float r). The two-term closure always can:
`transit = fl(total - acc)` leaves `acc2 = fl(acc + transit)` within a
few ULPs of `total`, so Sterbenz's lemma makes `closure = total - acc2`
EXACT and `fl(acc2 + closure) == total` unconditionally.

Span model (Chrome trace-event JSON): one *process* per cell (pid), one
*thread* per pool plus one per replica (tid). Per-batch replica
occupancy is emitted as synchronous B/E pairs on the replica's thread
(replicas serialize batches, so the pairs nest trivially); per-request
spans — root, per-stage wait/service phases, inter-stage transit — are
async "b"/"e" pairs keyed by the request id, which Perfetto renders as
a per-request waterfall without requiring non-overlapping tracks.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.serving.metrics import TraceBuffer

if TYPE_CHECKING:  # pool imports tracing at runtime; type-only here
    from repro.core.serving.pool import Request
    from repro.core.serving.replica import MissRows, ReplicaSpec

# The ordered taxonomy. `transit` and `closure` MUST stay last (in this
# order): they are the residual and the sub-ULP closure term that make
# the left-to-right sum land exactly on the end-to-end latency.
COMPONENTS: Tuple[str, ...] = (
    "queue_wait",
    "replica_wait",
    "dense_compute",
    "embed_fetch_local",
    "embed_fetch_remote",
    "shard_transit",
    "transit",
    "closure",
)

# log-spaced histogram edges (seconds) shared by every breakdown
# histogram — fixed so Prometheus series from different runs line up
HISTOGRAM_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


def service_phases(spec: "ReplicaSpec", items: int,
                   miss_rows: "MissRows") -> Tuple[float, float, float, float]:
    """Decompose one batch's service duration into its modelled phases
    (dense_s, fetch_local_s, fetch_remote_s, transit_s) using the same
    curves `ReplicaSpec.service_time` charges the clock with — the TRUE
    (drifted) curve when one is set, so attribution explains the latency
    that actually happened, not the calibration's opinion of it. Pure:
    reads the spec, touches nothing. The phase sum may differ from
    `service_time` by float dust; `decompose`'s residual absorbs it."""
    dense = spec.true_latency if spec.true_latency is not None else spec.latency
    fetch = (
        spec.true_embed_fetch_s
        if spec.true_embed_fetch_s is not None
        else spec.embed_fetch_s
    )
    from repro.core.serving.replica import MissProfile  # local: avoid cycle

    if isinstance(miss_rows, MissProfile):
        return (
            dense(items),
            miss_rows.local_rows * fetch,
            miss_rows.remote_rows * fetch,
            miss_rows.transit_s,
        )
    return dense(items), miss_rows * fetch, 0.0, 0.0


def _stage_path(req: "Request") -> List[int]:
    """The cascade stages THIS run's request actually traversed. Timeline
    dicts are shared across replayed runs (cascade.admit clones but keeps
    the dict), so stale stamps from a previous baseline run may coexist —
    the request's final stage, not the union of keys, names the path."""
    if req.stage <= 0:
        return [0]
    return [k for k in range(1, req.stage + 1)
            if f"s{k}_enqueue" in req.timeline]


def stage_components(timeline: Dict[str, float], stage: int,
                     done: float) -> Dict[str, float]:
    """The in-pool components of ONE stage, each a difference of
    consecutive timeline stamps (pool.py writes them in `_dispatch`).
    Missing boundary stamps fall back to the previous boundary — a
    result-cache fast path stamps only enqueue/start/done and correctly
    contributes zeros everywhere."""
    enq = timeline[f"s{stage}_enqueue"]
    start = timeline.get(f"s{stage}_start", enq)
    dispatch = timeline.get(f"s{stage}_dispatch", start)
    b_dense = timeline.get(f"s{stage}_compute_done", start)
    b_local = timeline.get(f"s{stage}_fetch_local_done", b_dense)
    b_remote = timeline.get(f"s{stage}_fetch_remote_done", b_local)
    b_service = timeline.get(f"s{stage}_service_done", b_remote)
    del done  # the stage's own `done` stamp is absorbed by the residual
    return {
        "queue_wait": dispatch - enq,
        "replica_wait": start - dispatch,
        "dense_compute": b_dense - start,
        "embed_fetch_local": b_local - b_dense,
        "embed_fetch_remote": b_remote - b_local,
        "shard_transit": b_service - b_remote,
    }


def decompose(req: "Request", done: float, *,
              t_origin: Optional[float] = None,
              stages: Optional[Sequence[int]] = None) -> Dict[str, float]:
    """Attribute one completed request's latency to the component
    taxonomy. `done` is the completion time (the event-loop `now` the
    final `done` stamp carries); `t_origin` overrides the latency origin
    (default `req.t_arrive` for end-to-end; a pool passes the stage's
    `t_enqueue` for its stage-local view) and `stages` restricts which
    cascade stages contribute (default: the full path this run took —
    a pool passes `[req.stage]` so its stage view never double-counts an
    upstream stage against a stage-local total).

    INVARIANT (property-tested): summing the returned values in
    `COMPONENTS` order with plain float additions reproduces
    `done - t_origin` — the exact float the SLO monitors recorded —
    bit-exactly. All components are non-negative except `transit`
    (>= 0 up to float dust) and `closure` (always within a few ULPs of
    the total)."""
    origin = req.t_arrive if t_origin is None else t_origin
    total = done - origin
    comps = {name: 0.0 for name in COMPONENTS}
    for stage in (_stage_path(req) if stages is None else stages):
        if f"s{stage}_enqueue" not in req.timeline:
            continue
        for name, val in stage_components(req.timeline, stage, done).items():
            # max(): a stamp fallback chain can produce a -0.0-style
            # artifact but never a real negative (stamps are monotone)
            comps[name] += max(val, 0.0)
    acc = 0.0
    for name in COMPONENTS[:-2]:
        acc += comps[name]
    # two-term closure (module docstring): residual transit, then the
    # Sterbenz-exact sub-ULP term
    comps["transit"] = total - acc
    acc2 = acc + comps["transit"]
    comps["closure"] = total - acc2
    return comps


class BreakdownAccumulator:
    """O(1)-memory aggregate of per-request decompositions: per-component
    sums, per-component log-bucket histograms (Prometheus-ready), request
    count and the summed end-to-end latency. Deterministic: state is a
    pure fold over completion order, so replays produce bit-identical
    blocks whether or not a Tracer is attached."""

    __slots__ = ("count", "end_to_end_s", "sums", "_hist")

    def __init__(self) -> None:
        self.count = 0
        self.end_to_end_s = 0.0
        self.sums = {name: 0.0 for name in COMPONENTS}
        # one (len(buckets)+1)-cell counter row per component; the last
        # cell is the +Inf overflow bucket
        self._hist = {
            name: [0] * (len(HISTOGRAM_BUCKETS_S) + 1) for name in COMPONENTS
        }

    def add(self, comps: Dict[str, float], total: float) -> None:
        self.count += 1
        self.end_to_end_s += total
        for name in COMPONENTS:
            v = comps[name]
            self.sums[name] += v
            self._hist[name][bisect.bisect_left(HISTOGRAM_BUCKETS_S, v)] += 1

    def observe(self, req: "Request", done: float, *,
                t_origin: Optional[float] = None,
                stages: Optional[Sequence[int]] = None) -> None:
        """Decompose + add in one call (the pool/engine completion hook)."""
        origin = req.t_arrive if t_origin is None else t_origin
        self.add(decompose(req, done, t_origin=origin, stages=stages),
                 done - origin)

    def summary(self) -> Dict:
        """The `latency_breakdown` block summaries embed: per-component
        seconds + share of the summed end-to-end latency, cumulative
        histogram counts (le-style, Prometheus semantics), and the
        invariant's aggregate echo (`component_sum_s` tracks
        `end_to_end_s` up to float-reassociation dust — the bit-exact
        claim is per-request, which the property suite asserts)."""
        comp_sum = sum(self.sums.values())
        denom = self.end_to_end_s if self.end_to_end_s > 0 else 1.0
        cumulative = {}
        for name in COMPONENTS:
            counts = self._hist[name]
            cum, out = 0, []
            for c in counts:
                cum += c
                out.append(cum)
            cumulative[name] = out
        return {
            "count": self.count,
            "end_to_end_s": self.end_to_end_s,
            "component_sum_s": comp_sum,
            "components": dict(self.sums),
            "shares": {n: self.sums[n] / denom for n in COMPONENTS},
            "histogram_buckets_s": list(HISTOGRAM_BUCKETS_S),
            "histograms": cumulative,
        }


# ---------------------------------------------------------------------------
# the sampling tracer
# ---------------------------------------------------------------------------

# span kinds (interned as ints in the columnar store)
_SPAN_KINDS: Tuple[str, ...] = (
    "request", "queue_wait", "replica_wait", "service", "dense_compute",
    "embed_fetch_local", "embed_fetch_remote", "shard_transit", "transit",
    "batch",
)
_KIND_ID = {name: i for i, name in enumerate(_SPAN_KINDS)}
# which kinds export as synchronous B/E pairs on their own thread track
# (everything else is an async per-request "b"/"e" pair keyed by rid)
_SYNC_KINDS = frozenset({"batch"})


class Tracer:
    """Deterministic sampling span recorder.

    Sampling is a pure hash of the request id (`sample_every=1` keeps
    every request): no RNG is consumed, no request is mutated, and no
    simulation decision ever consults the tracer — the property suite
    asserts summaries are bit-identical with the tracer on or off.
    Storage is bounded: past `max_spans` recorded spans, new spans are
    counted in `dropped_spans` and discarded (the trace stays loadable,
    the accounting stays honest)."""

    def __init__(self, *, sample_every: int = 16, seed: int = 0,
                 max_spans: int = 200_000) -> None:
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self.sample_every = sample_every
        self.seed = seed
        self.max_spans = max_spans
        self.dropped_spans = 0
        self._tracks: Dict[str, int] = {}
        self._spans = TraceBuffer([
            ("kind", np.int64), ("track", np.int64), ("rid", np.int64),
            ("stage", np.int64), "t0", "t1", ("items", np.int64),
        ])

    # ---- sampling ----
    def sampled(self, rid: int) -> bool:
        """Pure decision: Fibonacci-style integer hash of (rid, seed).
        The same rid samples identically in every run with the same
        tracer config — sampled replays are themselves replayable."""
        if self.sample_every == 1:
            return True
        h = (rid * 0x9E3779B1 + self.seed * 0x85EBCA6B) & 0xFFFFFFFF
        return h % self.sample_every == 0

    # ---- recording ----
    def _track_id(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = len(self._tracks)
            self._tracks[track] = tid
        return tid

    def _push(self, kind: str, track: str, rid: int, stage: int,
              t0: float, t1: float, items: int = 0) -> None:
        if len(self._spans) >= self.max_spans:
            self.dropped_spans += 1
            return
        self._spans.append(_KIND_ID[kind], self._track_id(track),
                           rid, stage, t0, t1, items)

    def record_batch(self, cell: str, pool: str, replica: int,
                     t0: float, t1: float, items: int, n_requests: int) -> None:
        """One batch's replica occupancy [service start, done) — the
        pool calls this from `_dispatch` when the batch carries at least
        one sampled request. Exported as a B/E pair on the replica's own
        thread (replicas serialize batches, so pairs nest trivially)."""
        track = f"{cell or 'system'}/{pool}/replica{replica}"
        self._push("batch", track, n_requests, 0, t0, t1, items)

    def record_stage(self, req: "Request", cell: str, pool: str,
                     done: float) -> None:
        """One sampled request's in-pool stage spans: queue wait, replica
        wait, and the service sub-phases, read off the timeline stamps
        `ReplicaPool._dispatch` wrote. Called from the pool's batch-done
        handler (the fast-path result-cache completion records nothing:
        its stage is a point, not a span)."""
        track = f"{cell or 'system'}/{pool}"
        stage = req.stage
        tl = req.timeline
        enq = tl.get(f"s{stage}_enqueue")
        if enq is None:
            return
        start = tl.get(f"s{stage}_start", enq)
        dispatch = tl.get(f"s{stage}_dispatch", start)
        self._push("queue_wait", track, req.rid, stage, enq, dispatch)
        self._push("replica_wait", track, req.rid, stage, dispatch, start)
        self._push("service", track, req.rid, stage, start, done)
        prev = start
        for kind, key in (("dense_compute", "compute_done"),
                          ("embed_fetch_local", "fetch_local_done"),
                          ("embed_fetch_remote", "fetch_remote_done"),
                          ("shard_transit", "service_done")):
            nxt = tl.get(f"s{stage}_{key}", prev)
            if nxt > prev:
                self._push(kind, track, req.rid, stage, prev, nxt)
            prev = nxt

    def record_request(self, req: "Request", done: float,
                       track: str = "fleet") -> None:
        """A sampled request's root span [t_arrive, done) plus the
        inter-stage transit gaps (front-door routing hop, cross-cell
        spill RTT, cascade hand-offs) — called once, at final
        completion, by the engine/federation completion path."""
        self._push("request", track, req.rid, req.stage, req.t_arrive, done)
        prev_done = req.t_arrive
        for stage in _stage_path(req):
            enq = req.timeline.get(f"s{stage}_enqueue")
            if enq is None:
                continue
            if enq > prev_done:
                self._push("transit", track, req.rid, stage, prev_done, enq)
            prev_done = req.timeline.get(f"s{stage}_done", enq)

    # ---- export ----
    def __len__(self) -> int:
        return len(self._spans)

    def summary(self) -> Dict:
        """Tracer-side stats (NOT embedded in any system summary — the
        tracer must never change what an untraced run reports)."""
        return {
            "spans": len(self._spans),
            "dropped_spans": self.dropped_spans,
            "sample_every": self.sample_every,
            "tracks": len(self._tracks),
        }

    def to_chrome_trace(self) -> Dict:
        """Chrome trace-event JSON (Perfetto / chrome://tracing / the
        `traceEvents` array format). One process per cell, one thread
        per pool / replica track; timestamps in microseconds. Sync
        B/E pairs carry replica batch occupancy; everything per-request
        is an async "b"/"e" pair keyed by the request id so overlapping
        requests on one pool render as a waterfall, not a mangled
        stack. Events are emitted in non-decreasing `ts` order
        (tools/check_trace.py validates this plus B/E pairing and
        pid/tid naming)."""
        # track name "cell/pool[/replicaN]" -> (pid, tid): processes are
        # cells in first-seen order, threads number within their process
        pids: Dict[str, int] = {}
        tids: Dict[int, Tuple[int, int]] = {}
        per_proc_threads: Dict[int, int] = {}
        meta: List[Dict] = []
        for track, track_id in self._tracks.items():
            proc = track.split("/", 1)[0]
            if proc not in pids:
                pids[proc] = len(pids) + 1
                meta.append({
                    "ph": "M", "name": "process_name", "pid": pids[proc],
                    "tid": 0, "ts": 0,
                    "args": {"name": proc},
                })
            pid = pids[proc]
            tid = per_proc_threads.get(pid, 0) + 1
            per_proc_threads[pid] = tid
            tids[track_id] = (pid, tid)
            meta.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "ts": 0, "args": {"name": track},
            })
        cols = self._spans.as_dict()
        opened: List[Tuple[float, int, Dict, Dict]] = []  # (ts, seq, b, e)
        for i in range(len(self._spans)):
            kind = _SPAN_KINDS[cols["kind"][i]]
            pid, tid = tids[cols["track"][i]]
            t0_us = cols["t0"][i] * 1e6
            t1_us = cols["t1"][i] * 1e6
            if kind in _SYNC_KINDS:
                begin = {
                    "ph": "B", "name": kind, "cat": "serving",
                    "pid": pid, "tid": tid, "ts": t0_us,
                    "args": {"items": cols["items"][i],
                             "requests": cols["rid"][i]},
                }
                end = {"ph": "E", "name": kind, "cat": "serving",
                       "pid": pid, "tid": tid, "ts": t1_us}
            else:
                rid = cols["rid"][i]
                begin = {
                    "ph": "b", "name": kind, "cat": "request",
                    "id": rid, "pid": pid, "tid": tid, "ts": t0_us,
                    "args": {"stage": cols["stage"][i]},
                }
                end = {"ph": "e", "name": kind, "cat": "request",
                       "id": rid, "pid": pid, "tid": tid, "ts": t1_us}
            opened.append((t0_us, i, begin, end))
        # interleave begins and ends into one globally ts-sorted list; at
        # equal ts, earlier-opened spans order first and a begin precedes
        # its own end — so a replica's E(batch k) lands before B(batch
        # k+1) when the next batch starts the instant the previous ends,
        # and zero-width spans stay B-then-E
        events: List[Tuple[float, int, int, Dict]] = []
        for ts, seq, b, e in opened:
            events.append((ts, seq, 0, b))
            events.append((e["ts"], seq, 1, e))
        events.sort(key=lambda t: (t[0], t[1], t[2]))
        return {
            "traceEvents": meta + [ev for _, _, _, ev in events],
            "displayTimeUnit": "ms",
            "metadata": {
                "sample_every": self.sample_every,
                "dropped_spans": self.dropped_spans,
            },
        }
