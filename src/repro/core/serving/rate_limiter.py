"""Hybrid token-bucket rate limiter (paper §IV.B): per-tier buckets plus a
load-adaptive shed of the lowest tiers when the SLO is threatened.

Public API
    TierPolicy(rate, burst)      tokens/s refill and bucket cap, in WORK
                                 items (or requests when cost stays 1)
    HybridRateLimiter.admit(now, tier, cost=1)   draw `cost` tokens;
                                 False = shed (tier shed or bucket empty)
    HybridRateLimiter.adapt(p99, slo)   load feedback: shed one more tier
                                 on breach, recover when p99 < 0.6*slo
    shed_order                   explicit shed sequence (first shed
                                 first); default sheds by NUMERIC tier
                                 suffix descending, not lexically

Token draws are cost-weighted: a 512-candidate ranking query drains 512
tokens where a pointwise query drains 1, so a tier's budget bounds admitted
WORK items, not request counts (DeepRecSys-style admission). Callers doing
plain request-count limiting leave cost at its default of 1; callers
admitting ranking traffic by work must size `burst` at least as large as
the biggest single-request cost they want to ever admit.

Invariants: admit() is deterministic given the call sequence (refill is
computed from timestamps, never wall clock); the highest-priority tier is
never shed (shed_level tops out at n_tiers - 1); unknown tier names are
rejected rather than admitted free. Times in seconds, rates per second.

The fleet keeps one limiter at the front door (request-count draws) and
each ReplicaPool may own another (cost-weighted draws, adapted from that
pool's own SLOMonitor) — see pool.py. In a federation, a cell shedding
at either level is what triggers reactive cross-cell spillover — the
request is offered to a remote cell instead of being dropped
(federation.py counts it spilled, not rejected).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Sequence, Tuple, Union


@dataclasses.dataclass
class TierPolicy:
    rate: float  # tokens/s
    burst: float


def _tier_sort_key(name: str) -> Tuple[str, Union[int, float]]:
    """Priority key: alpha prefix, then NUMERIC suffix — so "tier10" sorts
    after "tier9" (lower priority), not between "tier1" and "tier2" as a
    plain lexical sort would. Names without a numeric suffix keep lexical
    order among themselves and rank above suffixed ones with equal prefix."""
    m = re.match(r"(.*?)(\d+)$", name)
    if m:
        return (m.group(1), int(m.group(2)))
    return (name, -1)


class HybridRateLimiter:
    """`shed_order`, when given, lists tiers in the order they are shed
    (first element shed first); it must name every tier exactly once.
    Otherwise tiers shed from the highest numeric suffix down ("tier11"
    before "tier10" before ... "tier2" — not lexically)."""

    def __init__(
        self,
        tiers: Dict[str, TierPolicy],
        shed_order: Optional[Sequence[str]] = None,
    ):
        self.tiers = tiers
        self.tokens = {t: p.burst for t, p in tiers.items()}
        self.last = 0.0
        self.shed_level = 0  # 0 = admit all; k = shed k lowest tiers
        if shed_order is not None:
            if sorted(shed_order) != sorted(tiers):
                raise ValueError(
                    f"shed_order must name every tier exactly once; "
                    f"got {list(shed_order)!r} for tiers {sorted(tiers)!r}"
                )
            # _order stores best-first; shedding consumes from the end
            self._order = list(reversed(list(shed_order)))
        else:
            self._order = sorted(tiers, key=_tier_sort_key)

    def _refill(self, now: float):
        dt = max(now - self.last, 0.0)
        self.last = now
        for t, p in self.tiers.items():
            self.tokens[t] = min(p.burst, self.tokens[t] + dt * p.rate)

    def admit(self, now: float, tier: str, cost: float = 1.0) -> bool:
        self._refill(now)
        if self.shed_level and tier in self._order[-self.shed_level:]:
            return False
        if self.tokens.get(tier, 0.0) >= cost:
            self.tokens[tier] -= cost
            return True
        return False

    def adapt(self, p99: float, slo: float):
        """Load feedback: shed lowest tier when p99 breaches the SLO,
        recover when comfortably below."""
        if p99 > slo and self.shed_level < len(self._order) - 1:
            self.shed_level += 1
        elif p99 < 0.6 * slo and self.shed_level > 0:
            self.shed_level -= 1
