"""Hybrid token-bucket rate limiter (paper §IV.B): per-tier buckets plus a
load-adaptive shed of the lowest tiers when the SLO is threatened."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class TierPolicy:
    rate: float  # tokens/s
    burst: float


class HybridRateLimiter:
    def __init__(self, tiers: Dict[str, TierPolicy]):
        self.tiers = tiers
        self.tokens = {t: p.burst for t, p in tiers.items()}
        self.last = 0.0
        self.shed_level = 0  # 0 = admit all; k = shed k lowest tiers
        self._order = sorted(tiers)  # lexical: tier0 < tier1 < ...

    def _refill(self, now: float):
        dt = max(now - self.last, 0.0)
        self.last = now
        for t, p in self.tiers.items():
            self.tokens[t] = min(p.burst, self.tokens[t] + dt * p.rate)

    def admit(self, now: float, tier: str) -> bool:
        self._refill(now)
        if self.shed_level and tier in self._order[-self.shed_level:]:
            return False
        if self.tokens.get(tier, 0.0) >= 1.0:
            self.tokens[tier] -= 1.0
            return True
        return False

    def adapt(self, p99: float, slo: float):
        """Load feedback: shed lowest tier when p99 breaches the SLO,
        recover when comfortably below."""
        if p99 > slo and self.shed_level < len(self._order) - 1:
            self.shed_level += 1
        elif p99 < 0.6 * slo and self.shed_level > 0:
            self.shed_level -= 1
