"""Sharded embedding tier: parameter-server shards under the pools.

At production scale the embedding table dwarfs any single host —
HugeCTR/Merlin's answer is model-parallel tables hashed across devices,
and the cross-stack recsys characterizations show sparse-lookup
locality across exactly this memory hierarchy is the dominant serving
bottleneck. This module is the simulator's version of that hierarchy's
bottom layer; together with cache.py the full miss path is

    request ids -> pool L1 (EmbeddingCache, per pool)
                -> cell L2 (one shared EmbeddingCache per cell,
                            CacheConfig.l2, built by ServingSystem)
                -> EmbeddingShardService.fetch (this module)

Sharding model: ids hash DETERMINISTICALLY to `n_shards` shards
(`shard_of`, a Fibonacci-multiplier hash so the hot low ids of a Zipf
stream spread across shards instead of clustering), and a placement
map assigns each shard a HOME CELL round-robin over the placement
tuple. A fetch from a shard homed in the serving cell (or from any
shard when the placement is empty — the single-host table) is local:
it pays only the replica's per-row `embed_fetch_s`. A fetch from a
remote-cell shard additionally pays inter-cell transit: fetches are
batched per shard, so one dispatched batch pays ONE rtt(serving cell,
home cell) per distinct remote shard it touches, not one per row.
`fetch` returns that decomposition as a `replica.MissProfile`, which
`ReplicaSpec.service_time` prices and `ReplicaPool.predicted_miss_cost`
/ `CostModelRouter.estimate` predict — so routing prefers cells whose
L2 and local shards are warm.

Online table updates: `publish(ids)` bumps each row's version (the
"live model update without service interruption"). With
`invalidation=True` the new versions propagate down the hierarchy
immediately — every registered cache (the cell L2s first, then the
pool L1s, in registration order) marks its resident copies dirty, and
the next access refetches them in place. With invalidation off the
caches keep serving superseded rows and their `staleness` counters
record every such serve; `version_of` is what lets them notice.

Determinism: hashing, placement and versions are pure functions of the
push/fetch sequence — no wall clock, no randomness — so sharded runs
replay bit-identically (`summary()["version_sum"]` is the fingerprint
the replay tests compare). Per-cell fetch counters are kept separately
(`cell_stats`) so per-cell summaries attribute their own traffic and
fleet rollups never double count.

`RttMatrix` lives here (moved from federation.py, which re-exports it):
the shard tier sits BELOW the federation and both charge hops from the
same per-cell-pair matrix — `FederatedSystem` binds its matrix onto a
shard service constructed without one.
"""
from __future__ import annotations

from typing import Dict, Hashable, Iterable, List, Optional, Tuple

from repro.core.serving.cache import EmbeddingCache
from repro.core.serving.replica import MissProfile


class RttMatrix:
    """Per-cell-pair one-way transfer times. Looks up (src, dst), then the
    symmetric (dst, src), then falls back to the scalar default — so a
    federation built with only `rtt_s` behaves exactly as before, and a
    partial matrix only needs the asymmetric / non-default pairs. Same-cell
    and front-door (src == "") hops are free."""

    def __init__(self, default_s: float,
                 pairs: Optional[Dict[Tuple[str, str], float]] = None):
        self.default_s = default_s
        self.pairs = dict(pairs or {})

    def __call__(self, src: str, dst: str) -> float:
        if not src or src == dst:
            return 0.0
        hit = self.pairs.get((src, dst))
        if hit is None:
            hit = self.pairs.get((dst, src))
        return self.default_s if hit is None else hit


# Fibonacci (golden-ratio) multiplicative hash: consecutive ids — the
# HOT ids of a rank-ordered Zipf stream — land on different shards
# instead of clustering, while staying a pure deterministic function
_HASH_MULT = 0x9E3779B1
_HASH_MASK = 0xFFFFFFFF


class EmbeddingShardService:
    """N embedding-table shards with home cells, batched fetch costing,
    versioned rows and hierarchy-wide invalidation. One instance serves
    a whole fleet: pass it to `ServingSystem(shard=...)` (standalone)
    or `FederatedSystem(shard=...)` (which hands it to every cell and
    binds its RTT matrix if none was given)."""

    def __init__(
        self,
        n_shards: int,
        placement: Tuple[str, ...] = (),
        *,
        rtt: Optional[RttMatrix] = None,
        invalidation: bool = True,
    ):
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        self.n_shards = n_shards
        self.placement = tuple(placement)
        self.rtt = rtt
        self.invalidation = invalidation
        self._versions: Dict[Hashable, int] = {}  # row -> published version
        self._caches: List[EmbeddingCache] = []  # invalidation fan-out order
        self.publishes = 0  # publish() calls (update events)
        self.updated_rows = 0  # rows whose version was bumped, cumulative
        self.invalidated_rows = 0  # resident rows dirtied across all caches
        # per serving cell: [local rows, remote rows, transit seconds]
        self._by_cell: Dict[str, List[float]] = {}

    # -- placement ---------------------------------------------------------

    def shard_of(self, key: Hashable) -> int:
        return (int(key) * _HASH_MULT & _HASH_MASK) % self.n_shards

    def home(self, shard: int) -> str:
        """The shard's home cell; "" (local everywhere) when no placement."""
        if not self.placement:
            return ""
        return self.placement[shard % len(self.placement)]

    # -- versions + invalidation ------------------------------------------

    def version_of(self, key: Hashable) -> int:
        """Published version of a row; 0 until first published."""
        return self._versions.get(key, 0)

    def register_cache(self, cache: EmbeddingCache) -> None:
        """Join a cache to the hierarchy: it starts versioning rows
        against this table and receives invalidations on publish.
        Registration order IS propagation order — the engine registers
        the cell L2 before the pool L1s, so updates walk shard -> L2 ->
        L1."""
        if cache.version_of is None:
            cache.version_of = self.version_of
        self._caches.append(cache)

    def publish(self, ids: Iterable[Hashable]) -> None:
        """One online table update: bump the published version of every
        row in `ids`. With invalidation on, registered caches mark
        resident copies dirty (next access refetches); with it off they
        keep serving superseded rows — counted in their `staleness`."""
        ids = tuple(ids)
        for i in ids:
            self._versions[i] = self._versions.get(i, 0) + 1
        self.publishes += 1
        self.updated_rows += len(ids)
        if self.invalidation:
            for cache in self._caches:
                self.invalidated_rows += cache.invalidate(ids)

    # -- fetch -------------------------------------------------------------

    def fetch(self, cell: str, ids: Iterable[Hashable]) -> MissProfile:
        """Serve one batch's post-L2 miss rows for a batch dispatched in
        `cell`. Rows from shards homed in `cell` (or unhomed) are local;
        the rest pay one rtt(cell, home) per distinct remote shard
        touched (per-shard fetch batching) in `transit_s`, on top of
        the per-row `embed_fetch_s` the replica charges for every
        fetched row. Returns the decomposition with `l2_hits=0` — the
        pool fills that in from its own L2 probe."""
        local = remote = 0
        remote_rtts: Dict[int, float] = {}
        for i in ids:
            s = self.shard_of(i)
            home = self.home(s)
            if not home or not cell or home == cell:
                local += 1
            else:
                remote += 1
                if s not in remote_rtts:
                    remote_rtts[s] = self.rtt(cell, home) if self.rtt is not None else 0.0
        transit = sum(remote_rtts.values())
        if local or remote:
            tally = self._by_cell.setdefault(cell, [0, 0, 0.0])
            tally[0] += local
            tally[1] += remote
            tally[2] += transit
        return MissProfile(l2_hits=0, local_rows=local, remote_rows=remote,
                           transit_s=transit)

    # -- signals + summaries ----------------------------------------------

    def predicted_transit_per_row(self, cell: str) -> float:
        """Expected inter-cell transit seconds per shard-fetched row for
        batches served in `cell`, learned from that cell's own fetch
        history — the remote leg of the routers' three-way predicted
        miss cost. 0 until the cell has fetched (a cold cell competes
        on dense cost alone, like the rows-per-item EWMA)."""
        local, remote, transit = self._by_cell.get(cell, (0, 0, 0.0))
        rows = local + remote
        return transit / rows if rows else 0.0

    def cell_stats(self, cell: str) -> Dict:
        """This cell's own fetch traffic (fleet rollups sum these
        without double counting)."""
        local, remote, transit = self._by_cell.get(cell, (0, 0, 0.0))
        return {
            "local_fetches": int(local),
            "remote_fetches": int(remote),
            "transit_s": float(transit),
        }

    def summary(self) -> Dict:
        local = sum(int(v[0]) for v in self._by_cell.values())
        remote = sum(int(v[1]) for v in self._by_cell.values())
        return {
            "n_shards": self.n_shards,
            "placement": self.placement,
            "invalidation": self.invalidation,
            "local_fetches": local,
            "remote_fetches": remote,
            "transit_s": float(sum(v[2] for v in self._by_cell.values())),
            "publishes": self.publishes,
            "updated_rows": self.updated_rows,
            "invalidated_rows": self.invalidated_rows,
            "versioned_rows": len(self._versions),
            # replay fingerprint: bit-identical runs publish bit-identical
            # version tables
            "version_sum": sum(self._versions.values()),
            "cells": {c: self.cell_stats(c) for c in sorted(self._by_cell)},
        }
