"""Multi-tier hot-ID caching for the serving simulator.

Recommendation inference is dominated by sparse embedding lookups over
heavily Zipf-skewed ID popularity (DeepRecSys arXiv 2001.02772; the
cross-stack characterization in arXiv 2001.02772's companion studies):
a small resident cache of hot rows converts most memory-bound fetches
into near-free hits. This module is the simulator half of that memory
model — deterministic, pure-Python caches the serving stack wires
through replica -> pool -> cell:

    EmbeddingCache   capacity in ROWS; a pluggable eviction policy from
                     CACHE_POLICIES (lru / lfu / s3fifo) decides which
                     hot IDs stay resident. ReplicaPool owns one per
                     pool; each dispatched batch runs its requests' ids
                     through it and pays `ReplicaSpec.embed_fetch_s`
                     seconds per MISSED row on top of the dense service
                     time (replica.py) — so batch latency depends on the
                     live hit-rate, not just batch size.
    ResultCache      request-signature -> score TTL cache: a repeat
                     query whose ids signature is still fresh completes
                     immediately, bypassing batching and service.
    CacheConfig      everything a pool needs to bring both up
                     (PoolSpec.cache in engine.py).

The real-array counterpart (resident-table `embedding_bag` gather,
validated against kernels/embedding_bag/ref.py) lives in
repro/core/caching.py.

The shard tier (serving/shard.py) adds a second cache level and row
VERSIONS on top of the same policies: `CacheConfig.l2` describes one
shared per-cell L2 EmbeddingCache probed between a pool's L1 miss and
the shard fetch, and a cache constructed with (or later given) a
`version_of` callable tracks which published row version each resident
key was fetched at. `EmbeddingShardService.publish` bumps versions and
— with invalidation on — calls `invalidate(ids)` down the hierarchy
(shard -> L2 -> L1): an invalidated resident row is served as a MISS on
its next access (refetched in place, version refreshed). With
invalidation off the caches keep serving superseded rows; every such
serve increments the `staleness` counter, the number the staleness-vs-
hit-rate bench experiment sweeps.

Invariants: every policy is deterministic — same access stream, same
capacity => bit-identical hit/miss sequence, eviction order and final
resident set (the tests replay streams and compare `resident_keys()`).
No policy ever holds more than `capacity` keys. Stats counters
(hits/misses/evictions/staleness) are cumulative over the run;
`warm()` touches keys without counting, so a pre-warmed cache starts
at hit_rate 0/0. Invalidation never changes eviction order: the dirty
mark lives beside the policy, not inside it, so the policy sees the
exact same access stream either way. Times are seconds on the
event-loop clock; capacities are rows (ids).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import OrderedDict, deque
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Set, Tuple


class CachePolicyBase:
    """One eviction policy over a fixed-capacity key set. Subclasses
    implement `access(key) -> bool` (True = hit; a miss ADMITS the key,
    evicting deterministically when full) and `resident_keys()`."""

    name = "base"

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1 row, got {capacity}")
        self.capacity = capacity
        self.evictions = 0

    def access(self, key: Hashable) -> bool:
        raise NotImplementedError

    def resident_keys(self) -> Tuple:
        """Resident set in a policy-defined deterministic order."""
        raise NotImplementedError

    def __contains__(self, key: Hashable) -> bool:
        """Residency check with NO side effects (no recency/frequency
        touch, no admission) — what invalidation probes."""
        raise NotImplementedError

    def __len__(self) -> int:
        return len(self.resident_keys())


class LRUCache(CachePolicyBase):
    """Least-recently-used: evict the key untouched for longest."""

    name = "lru"

    def __init__(self, capacity: int) -> None:
        super().__init__(capacity)
        self._od: "OrderedDict[Hashable, None]" = OrderedDict()

    def access(self, key: Hashable) -> bool:
        if key in self._od:
            self._od.move_to_end(key)
            return True
        if len(self._od) >= self.capacity:
            self._od.popitem(last=False)
            self.evictions += 1
        self._od[key] = None
        return False

    def resident_keys(self) -> Tuple:
        return tuple(self._od)  # LRU -> MRU order

    def __contains__(self, key: Hashable) -> bool:
        return key in self._od


class LFUCache(CachePolicyBase):
    """Least-frequently-used with FIFO tie-break (older entry evicted
    first at equal frequency). Lazy heap: stale entries are skipped at
    eviction time, so access stays O(log n)."""

    name = "lfu"

    def __init__(self, capacity: int) -> None:
        super().__init__(capacity)
        self._freq: Dict[Hashable, Tuple[int, int]] = {}  # key -> (freq, seq)
        self._heap: list = []  # (freq, seq, key), lazily invalidated
        self._seq = itertools.count()

    def _compact(self) -> None:
        # hot-heavy streams push a heap entry per HIT and stale ones only
        # leave at eviction time — rebuild before the heap outgrows a few
        # multiples of capacity so memory tracks capacity, not stream length
        if len(self._heap) > 8 * self.capacity:
            self._heap = [(f, s, k) for k, (f, s) in self._freq.items()]
            heapq.heapify(self._heap)

    def access(self, key: Hashable) -> bool:
        if key in self._freq:
            freq, seq = self._freq[key]
            self._freq[key] = (freq + 1, seq)
            heapq.heappush(self._heap, (freq + 1, seq, key))
            self._compact()
            return True
        if len(self._freq) >= self.capacity:
            while True:  # pop until a live (freq, seq) entry surfaces
                freq, seq, victim = heapq.heappop(self._heap)
                if self._freq.get(victim) == (freq, seq):
                    del self._freq[victim]
                    self.evictions += 1
                    break
        entry = (1, next(self._seq))
        self._freq[key] = entry
        heapq.heappush(self._heap, (*entry, key))
        self._compact()
        return False

    def resident_keys(self) -> Tuple:
        # (freq asc, insertion seq asc): eviction order, coldest first
        return tuple(sorted(self._freq, key=self._freq.__getitem__))

    def __contains__(self, key: Hashable) -> bool:
        return key in self._freq


class S3FifoCache(CachePolicyBase):
    """S3-FIFO-style: a small probationary FIFO (~10% of capacity)
    absorbs one-hit wonders, keys re-referenced there graduate to the
    main FIFO, and a ghost FIFO of recently evicted keys fast-tracks
    comebacks straight into main. Main eviction gives one second chance
    to keys touched since insertion (capped frequency counter)."""

    name = "s3fifo"

    def __init__(self, capacity: int) -> None:
        super().__init__(capacity)
        if capacity < 2:
            # one row can't split into probationary + main tiers; letting
            # both tiers default to 1 would hold 2 keys and break the
            # "never more than capacity" invariant
            raise ValueError("s3fifo needs capacity >= 2 rows (small + main tier)")
        self._small_cap = max(1, capacity // 10)
        self._main_cap = capacity - self._small_cap
        self._small: "deque[Hashable]" = deque()
        self._main: "deque[Hashable]" = deque()
        self._where: Dict[Hashable, str] = {}  # key -> "small" | "main"
        self._freq: Dict[Hashable, int] = {}
        # ghost records carry a stamp so a key re-ghosted after a comeback
        # is tracked by its NEWEST record: popping a stale older record
        # must not cancel the live one's comeback eligibility
        self._ghost: "deque[Tuple[Hashable, int]]" = deque()
        self._ghost_live: Dict[Hashable, int] = {}  # key -> live stamp
        self._stamp = itertools.count()

    def _remember_ghost(self, key: Hashable) -> None:
        while len(self._ghost) >= self.capacity:
            gone, stamp = self._ghost.popleft()
            if self._ghost_live.get(gone) == stamp:
                del self._ghost_live[gone]
        stamp = next(self._stamp)
        self._ghost.append((key, stamp))
        self._ghost_live[key] = stamp

    def _evict_main(self) -> None:
        while True:
            victim = self._main.popleft()
            if self._freq.get(victim, 0) > 0:  # second chance
                self._freq[victim] -= 1
                self._main.append(victim)
                continue
            del self._where[victim]
            self._freq.pop(victim, None)
            self.evictions += 1
            return

    def _insert_main(self, key: Hashable) -> None:
        if len(self._main) >= self._main_cap:
            self._evict_main()
        self._main.append(key)
        self._where[key] = "main"
        self._freq[key] = 0

    def _evict_small(self) -> None:
        victim = self._small.popleft()
        del self._where[victim]
        if self._freq.pop(victim, 0) > 0:
            self._insert_main(victim)  # re-referenced: graduate
        else:
            self._remember_ghost(victim)
            self.evictions += 1

    def access(self, key: Hashable) -> bool:
        if key in self._where:
            self._freq[key] = min(self._freq.get(key, 0) + 1, 3)
            return True
        if key in self._ghost_live:  # comeback: straight into main
            del self._ghost_live[key]
            self._insert_main(key)
            return False
        if len(self._small) >= self._small_cap:
            self._evict_small()
        self._small.append(key)
        self._where[key] = "small"
        self._freq[key] = 0
        return False

    def resident_keys(self) -> Tuple:
        return tuple(self._small) + tuple(self._main)  # FIFO order per tier

    def __contains__(self, key: Hashable) -> bool:
        return key in self._where


CACHE_POLICIES: Dict[str, type] = {
    LRUCache.name: LRUCache,
    LFUCache.name: LFUCache,
    S3FifoCache.name: S3FifoCache,
}


def make_cache_policy(name: str, capacity: int) -> CachePolicyBase:
    try:
        return CACHE_POLICIES[name](capacity)
    except KeyError:
        raise KeyError(
            f"unknown cache policy {name!r}; have {sorted(CACHE_POLICIES)}"
        ) from None


@dataclasses.dataclass
class CacheConfig:
    """Per-pool cache knobs (PoolSpec.cache). `capacity_rows` bounds the
    embedding cache in resident ID rows; `result_capacity`/`result_ttl_s`
    bring up the request-signature ResultCache (0 disables it). `l2`
    describes the CELL-level cache: one shared EmbeddingCache built by
    the ServingSystem from this nested config and probed by every pool
    in the cell between its own L1 miss and the shard fetch. All pools
    that set `l2` within one cell must agree on (capacity_rows, policy)
    — there is exactly one L2 per cell (engine.py enforces this)."""

    capacity_rows: int
    policy: str = "lru"
    result_capacity: int = 0
    result_ttl_s: float = 1.0
    l2: Optional["CacheConfig"] = None


class EmbeddingCache:
    """Hot-ID row cache: `lookup(ids)` runs one request's embedding ids
    through the policy and returns (hits, misses); missed rows are
    admitted (fetch-on-miss). Cumulative hit/miss counters feed the
    pool's metrics and the routers' predicted miss cost.

    Versioning (shard tier): with a `version_of` callable bound — the
    shard service binds its own via `register_cache` — each fetch
    (miss) records the row's published version. A later `invalidate`
    marks resident copies dirty: the next access serves them as a MISS
    (refetch in place; the policy still sees a hit, so eviction order
    is untouched and bit-identical with invalidation on or off). A
    clean hit whose recorded version is superseded bumps `staleness` —
    the count of stale serves, i.e. what users see when invalidation
    is off or hasn't reached this tier."""

    def __init__(
        self,
        capacity_rows: int,
        policy: str = "lru",
        *,
        version_of: Optional[Callable[[Hashable], int]] = None,
    ) -> None:
        self.impl = make_cache_policy(policy, capacity_rows)
        self.policy = policy
        self.capacity_rows = capacity_rows
        self.version_of = version_of
        self.hits = 0
        self.misses = 0
        self.staleness = 0  # serves of a superseded row version
        self.invalidated = 0  # resident rows marked dirty, cumulative
        self._ver: Dict[Hashable, int] = {}  # key -> version at fetch
        self._dirty: Set[Hashable] = set()  # resident but superseded

    def access(self, key: Hashable) -> bool:
        """One id through the policy + version layer; True = hit. An
        invalidated resident row reports a miss (the refetch) even
        though the policy keeps it resident; a clean hit on a
        superseded version counts one stale serve."""
        hit = self.impl.access(key)
        if hit and key in self._dirty:
            hit = False  # invalidated: refetch the row in place
        if hit:
            if self.version_of is not None and self._ver.get(key, 0) != self.version_of(key):
                self.staleness += 1
            self.hits += 1
        else:
            self._dirty.discard(key)
            if self.version_of is not None:
                self._ver[key] = self.version_of(key)
                # _ver tracks fetch versions for resident keys only; prune
                # it (deterministically, against the policy's resident set)
                # before it outgrows a few multiples of capacity
                if len(self._ver) > 8 * self.capacity_rows:
                    resident = set(self.impl.resident_keys())
                    self._ver = {k: v for k, v in self._ver.items() if k in resident}
            self.misses += 1
        return hit

    def lookup(self, ids: Iterable[Hashable]) -> Tuple[int, int]:
        hits = misses = 0
        for i in ids:
            if self.access(i):
                hits += 1
            else:
                misses += 1
        return hits, misses

    def lookup_misses(self, ids: Iterable[Hashable]) -> Tuple[int, List[Hashable]]:
        """Like `lookup` but returns the missed ids themselves, in
        access order — the rows the next tier down (cell L2, then the
        shard service) must serve."""
        hits = 0
        missed: List[Hashable] = []
        for i in ids:
            if self.access(i):
                hits += 1
            else:
                missed.append(i)
        return hits, missed

    def invalidate(self, ids: Iterable[Hashable]) -> int:
        """Mark resident copies of `ids` superseded (shard publish with
        invalidation on): their next access refetches in place. Only
        resident rows are marked — non-resident ids would miss anyway —
        so the dirty set stays bounded by capacity. Idempotent; returns
        the rows newly marked."""
        marked = 0
        for i in ids:
            if i in self.impl and i not in self._dirty:
                self._dirty.add(i)
                marked += 1
        self.invalidated += marked
        return marked

    def warm(self, ids: Iterable[Hashable]) -> None:
        """Pre-load ids without touching the hit/miss counters — a warmed
        cache starts the run resident but statistically clean. Warmed
        rows record the current published version."""
        for i in ids:
            if not self.impl.access(i) and self.version_of is not None:
                self._ver[i] = self.version_of(i)

    @property
    def hit_rate(self) -> float:
        seen = self.hits + self.misses
        return self.hits / seen if seen else 0.0

    @property
    def evictions(self) -> int:
        return self.impl.evictions

    def resident_keys(self) -> Tuple:
        return self.impl.resident_keys()

    def stats(self) -> Dict:
        return {
            "policy": self.policy,
            "capacity_rows": self.capacity_rows,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "evictions": self.evictions,
            "resident_rows": len(self.impl),
            "staleness": self.staleness,
            "invalidated": self.invalidated,
        }


class ResultCache:
    """Request-signature -> result TTL cache. A repeat query whose
    signature (its ids tuple) was completed within `ttl_s` is served
    from cache — the pool completes it immediately, no batching, no
    service time. LRU over `capacity` signatures; expired entries are
    dropped on get. Deterministic: eviction and expiry depend only on
    the (now, key) call sequence."""

    def __init__(self, capacity: int, ttl_s: float) -> None:
        if capacity < 1:
            raise ValueError(f"result cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        self._od: "OrderedDict[Hashable, Tuple[float, object]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, now: float, key: Hashable) -> Optional[object]:
        """The cached value, or None on miss/expiry."""
        entry = self._od.get(key)
        if entry is not None and now - entry[0] <= self.ttl_s:
            self._od.move_to_end(key)
            self.hits += 1
            return entry[1]
        if entry is not None:  # expired: drop so capacity isn't held hostage
            del self._od[key]
        self.misses += 1
        return None

    def put(self, now: float, key: Hashable, value: object = True) -> None:
        if key in self._od:
            self._od.move_to_end(key)
        elif len(self._od) >= self.capacity:
            self._od.popitem(last=False)
        self._od[key] = (now, value)

    def __len__(self) -> int:
        return len(self._od)
