"""Discrete-event kernel for the serving simulator.

The kernel is deliberately tiny: a time-ordered heap of (t, seq, kind,
payload) events and a registry of handlers keyed by event kind. Pools,
routers, the cascade dispatcher and the engine all plug into the same loop
by registering handlers and pushing events — none of them own the clock.
Event kinds are plain strings; components namespace theirs
("batch_done:<pool>") so several pools can share one loop.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._handlers: Dict[str, Callable[[float, object], None]] = {}
        self.now = 0.0

    def on(self, kind: str, handler: Callable[[float, object], None]) -> None:
        """Register the handler for an event kind (one handler per kind)."""
        if kind in self._handlers:
            raise ValueError(f"handler already registered for event kind {kind!r}")
        self._handlers[kind] = handler

    def push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def run(self) -> float:
        """Drain the heap in time order; returns the time of the last event
        processed. The loop itself has no horizon — periodic handlers (scale
        ticks) stop rescheduling themselves past theirs, while in-flight
        service completions always run so no work is lost."""
        last = self.now
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = last = t
            handler = self._handlers.get(kind)
            if handler is not None:
                handler(t, payload)
        return last
