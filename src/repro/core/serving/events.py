"""Discrete-event kernel for the serving simulator.

Public API
    EventLoop.on(kind, handler)   register ONE handler per event kind
                                  (a second registration raises)
    EventLoop.push(t, kind, payload=None)   schedule an event
    EventLoop.run()               drain the heap in time order
    EventLoop.now                 the clock, in seconds

The kernel is deliberately tiny: a time-ordered heap of (t, seq, kind,
payload) events and a registry of handlers keyed by event kind. Pools,
routers, the cascade dispatcher, the engine and the multi-cell federation
all plug into the same loop by registering handlers and pushing events —
none of them own the clock. Event kinds are plain strings; components
namespace theirs ("batch_done:<pool>", "arrive:<cell>") so several pools
— and several cells' same-named pools — can share one loop.

Invariants: events fire in (time, push-order) — FIFO within equal
timestamps, so replaying the same pushes yields a bit-identical run
(payloads are never compared; the monotone sequence number breaks ties).
The loop has no horizon of its own: periodic handlers stop rescheduling
themselves past theirs, while in-flight completions always run, so no
admitted work is ever lost at the end of a simulation. All times are in
seconds.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._handlers: Dict[str, Callable[[float, object], None]] = {}
        self.now = 0.0

    def on(self, kind: str, handler: Callable[[float, object], None]) -> None:
        """Register the handler for an event kind (one handler per kind)."""
        if kind in self._handlers:
            raise ValueError(f"handler already registered for event kind {kind!r}")
        self._handlers[kind] = handler

    def push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def run(self) -> float:
        """Drain the heap in time order; returns the time of the last event
        processed. The loop itself has no horizon — periodic handlers (scale
        ticks) stop rescheduling themselves past theirs, while in-flight
        service completions always run so no work is lost."""
        last = self.now
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = last = t
            handler = self._handlers.get(kind)
            if handler is not None:
                handler(t, payload)
        return last
