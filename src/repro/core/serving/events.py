"""Discrete-event kernel for the serving simulator — the fast path.

Public API
    EventLoop.on(kind, handler)   register ONE handler per event kind
                                  (a second registration raises)
    EventLoop.push(t, kind, payload=None)   schedule an event
    EventLoop.add_stream(kind, events)      lazily merge a PRE-SORTED
                                  (t, payload) stream into the loop —
                                  O(1) pending memory per stream instead
                                  of one heap entry per arrival
    EventLoop.run()               drain queue + streams in time order
    EventLoop.now                 the clock, in seconds
    EventLoop.processed           events dispatched so far
    EventLoop.dropped_events / dropped_kinds
                                  events whose kind had no handler (the
                                  seed kernel skipped these SILENTLY);
                                  with strict=True the loop raises instead

Two pending-event stores implement one ordering contract:

    HeapScheduler      the seed kernel's single binary heap of
                       (t, seq, kind, payload) — O(log n) in ALL pending
                       events. Kept as the reference implementation for
                       the determinism tests and the bench_engine
                       baseline ("the pre-PR kernel").
    CalendarScheduler  calendar-queue / bucketed scheduler (the default):
                       events inside the CURRENT time window live in a
                       columnar numpy argmin store (_ArgminWindow);
                       later events append O(1) into per-window buckets
                       keyed by integer window index (a lazy min-heap
                       over occupied indices finds the next window).
                       Near-O(1) push/pop for the mostly monotone
                       streams pools generate, because the window holds
                       only the events of one bucket width — not the
                       whole simulation's backlog — and its minimum is
                       one vectorized scan, not per-event tuple sifting.

Ordering invariant (both schedulers, bit-exact): events fire in
(time, push-order) — FIFO within equal timestamps, so replaying the same
pushes yields a bit-identical run (payloads are never compared; the
monotone sequence number breaks ties). Out-of-band pushes — a handler
scheduling work at or before times already buffered — land in the
current window heap and keep exact heap semantics.

Arrival streams: `add_stream` registers a time-sorted iterator that the
run loop merges lazily — only each stream's HEAD event exists in memory,
so a million-arrival trace costs O(1) pending state instead of a
million heap tuples. At equal timestamps a stream event fires before any
queued event, which reproduces the seed semantics of pushing the whole
arrival list before arming periodic events (arrivals held the lowest
sequence numbers). Streams must be non-decreasing in time; a backwards
step raises.

The loop has no horizon of its own: periodic handlers stop rescheduling
themselves past theirs, while in-flight completions always run, so no
admitted work is ever lost at the end of a simulation. All times are in
seconds.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

# one pending event: (time, push-order, kind, payload)
Entry = Tuple[float, int, str, object]


class HeapScheduler:
    """Single binary heap of (t, seq, kind, payload) — the seed kernel's
    store. O(log n) push/pop with n = all pending events; the calendar
    queue replaces it on the hot path, but it stays as the reference
    ordering (determinism tests replay against it) and the bench_engine
    baseline."""

    __slots__ = ("_heap",)

    def __init__(self) -> None:
        self._heap: List[Entry] = []

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, entry: Entry) -> None:
        heapq.heappush(self._heap, entry)

    def peek(self) -> Optional[Entry]:
        return self._heap[0] if self._heap else None

    def pop(self) -> Entry:
        return heapq.heappop(self._heap)


class _ArgminWindow:
    """CalendarScheduler's current-window store: columnar (t, seq) numpy
    arrays aligned with an entry list, served by a vectorized argmin
    instead of heap sifting. A window holds one bucket's worth of events
    (~MAX_BUCKET/4 after a shrink), so the O(n) scan is one contiguous
    float compare over a small array — cheaper in practice than the
    pointer-chasing tuple comparisons heappush/heappop do per event.

    Order contract (bit-exact vs the binary heap): the minimum is the
    entry with the least (t, seq) — np.argmin finds the earliest time,
    and ties on t resolve by the least sequence number (seq is unique,
    so the pair is a total order; kind/payload never participate, same
    as the heap where seq always breaks the tie first).

    The argmin slot is cached: a push keeps the cache valid by comparing
    the new entry against the cached minimum; a pop swap-deletes the
    minimum with the last slot (clearing the popped reference) and
    invalidates the cache, so a peek/pop pair costs one scan."""

    __slots__ = ("_t", "_seq", "_entries", "_n", "_min")

    def __init__(self, entries: Optional[List[Entry]] = None) -> None:
        n = len(entries) if entries else 0
        cap = max(16, n)
        self._t = np.empty(cap, dtype=np.float64)
        self._seq = np.empty(cap, dtype=np.int64)
        self._entries: List[Optional[Entry]] = [None] * cap
        if entries:
            for i, e in enumerate(entries):
                self._t[i] = e[0]
                self._seq[i] = e[1]
                self._entries[i] = e
        self._n = n
        self._min = -1  # cached argmin slot; -1 = unknown

    def __len__(self) -> int:
        return self._n

    def __iter__(self) -> Iterator[Entry]:
        return iter(self._entries[: self._n])

    def push(self, entry: Entry) -> None:
        n = self._n
        if n == len(self._entries):
            grown_t = np.empty(2 * n, dtype=np.float64)
            grown_t[:n] = self._t
            self._t = grown_t
            grown_seq = np.empty(2 * n, dtype=np.int64)
            grown_seq[:n] = self._seq
            self._seq = grown_seq
            self._entries.extend([None] * n)
        self._t[n] = entry[0]
        self._seq[n] = entry[1]
        self._entries[n] = entry
        self._n = n + 1
        m = self._min
        if m >= 0:
            tm = self._t[m]
            if entry[0] < tm or (entry[0] == tm and entry[1] < self._seq[m]):
                self._min = n
        elif n == 0:
            self._min = 0

    def _argmin(self) -> int:
        t = self._t[: self._n]
        i = int(np.argmin(t))
        ties = np.flatnonzero(t == t[i])
        if len(ties) > 1:
            i = int(ties[np.argmin(self._seq[ties])])
        self._min = i
        return i

    def peek(self) -> Entry:
        m = self._min
        if m < 0:
            m = self._argmin()
        return self._entries[m]

    def pop(self) -> Entry:
        m = self._min
        if m < 0:
            m = self._argmin()
        entry = self._entries[m]
        last = self._n - 1
        if m != last:
            self._t[m] = self._t[last]
            self._seq[m] = self._seq[last]
            self._entries[m] = self._entries[last]
        self._entries[last] = None  # drop the popped reference
        self._n = last
        self._min = -1
        return entry


class CalendarScheduler:
    """Calendar-queue scheduler: a small current-window argmin store +
    unsorted future buckets.

    Routing happens entirely in integer bucket-index space: an event's
    index is int(t / width), and the window covers every index up to
    `_win_idx` inclusive. An event at or before the window index joins
    the window's argmin store (exact order kept, including out-of-band
    pushes at or before `now`); a later event APPENDS to its index's
    bucket — O(1) — creating the bucket (and registering its index in a
    min-heap) on first use. Comparing indices, not float boundary times,
    matters: fp division can round t/width UP across a bucket boundary,
    and an equal-time pair split across the boundary by a float
    `t < win_end` test would fire out of push order. int(t/width) is
    monotone in t, so index order is time order and equal times always
    share one container.

    Pop/peek: serve the window's (t, seq) minimum via _ArgminWindow's
    vectorized scan; when the window drains, promote the earliest
    occupied bucket — pop its index, wrap its entries as the new window
    (O(bucket)), and advance `_win_idx` to it.

    Total order is EXACTLY the binary heap's (time, push-order): every
    bucketed event's index exceeds `_win_idx` (so its time is >= every
    window event's), buckets promote in index order, and the window
    serves by least (t, seq).

    Width adapts downward only, deterministically: when a promoted bucket
    exceeds MAX_BUCKET entries the width shrinks (targeting ~MAX_BUCKET/4
    per window) and the remaining buckets are rebuilt under the new width
    — O(pending), amortised by the pops that filled the bucket. Sparse
    streams degrade gracefully without growing the width: singleton
    buckets make the index heap behave like the plain binary heap."""

    __slots__ = ("_width", "_win", "_win_idx", "_buckets", "_indices", "_len")

    MAX_BUCKET = 4096
    MIN_WIDTH = 1e-9

    def __init__(self, width: float = 0.05) -> None:
        self._width = width
        self._win = _ArgminWindow()  # current window (exact order)
        self._win_idx = 0  # window covers every index <= this (past stays exact)
        self._buckets: Dict[int, List[Entry]] = {}
        self._indices: List[int] = []  # min-heap of occupied bucket indices
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def push(self, entry: Entry) -> None:
        self._len += 1
        idx = int(entry[0] / self._width)
        if idx <= self._win_idx:
            self._win.push(entry)
            return
        bucket = self._buckets.get(idx)
        if bucket is None:
            self._buckets[idx] = [entry]
            heapq.heappush(self._indices, idx)
        else:
            bucket.append(entry)

    def _promote(self) -> None:
        """Move the earliest occupied bucket into the (empty) window."""
        idx = heapq.heappop(self._indices)
        bucket = self._buckets.pop(idx)
        self._win = _ArgminWindow(bucket)
        self._win_idx = idx
        if len(bucket) > self.MAX_BUCKET and self._width > self.MIN_WIDTH:
            self._shrink(len(bucket))

    def _shrink(self, occupancy: int) -> None:
        """Events cluster denser than the bucket width: narrow it for the
        still-bucketed future and rebucket. Deterministic — a pure
        function of the push/pop history."""
        self._width = max(
            self._width * (self.MAX_BUCKET / (4.0 * occupancy)), self.MIN_WIDTH
        )
        # the just-promoted window spans several new-width indices; the
        # window threshold becomes the LAST of them, and pending events
        # at or before it must JOIN the window heap — left in a bucket,
        # they (and later equal-time pushes routed by the new width)
        # would fire after window events with greater times
        self._win_idx = max(int(e[0] / self._width) for e in self._win)
        pending = [e for b in self._buckets.values() for e in b]
        self._buckets.clear()
        self._indices.clear()
        for entry in pending:
            idx = int(entry[0] / self._width)
            if idx <= self._win_idx:
                self._win.push(entry)
            elif (bucket := self._buckets.get(idx)) is None:
                self._buckets[idx] = [entry]
                heapq.heappush(self._indices, idx)
            else:
                bucket.append(entry)

    def peek(self) -> Optional[Entry]:
        if not self._win:
            if not self._indices:
                return None
            self._promote()
        return self._win.peek()

    def pop(self) -> Entry:
        if not self._win:
            self._promote()
        self._len -= 1
        return self._win.pop()


SCHEDULERS = {"heap": HeapScheduler, "calendar": CalendarScheduler}


class _Stream:
    """A lazily-consumed, time-sorted (t, payload) event source: only the
    head event is materialised. `t` is +inf once exhausted."""

    __slots__ = ("kind", "t", "payload", "_it")

    def __init__(self, kind: str,
                 events: Iterable[Tuple[float, object]]) -> None:
        self.kind = kind
        self._it: Iterator[Tuple[float, object]] = iter(events)
        self.t = float("-inf")
        self.payload: object = None
        self.advance()

    def advance(self) -> None:
        prev = self.t
        try:
            self.t, self.payload = next(self._it)
        except StopIteration:
            self.t = float("inf")
            self.payload = None
            return
        if self.t < prev:
            raise ValueError(
                f"arrival stream {self.kind!r} is not time-sorted: "
                f"{self.t} after {prev}"
            )


class EventLoop:
    def __init__(self, scheduler: str = "calendar",
                 strict: bool = False) -> None:
        try:
            self._sched = SCHEDULERS[scheduler]()
        except KeyError:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; one of {sorted(SCHEDULERS)}"
            ) from None
        self.scheduler = scheduler
        self._seq = itertools.count()
        self._handlers: Dict[str, Callable[[float, object], None]] = {}
        self._streams: List[_Stream] = []
        self.strict = strict
        self.now = 0.0
        self.processed = 0  # events dispatched (handled or dropped)
        self.dropped_events = 0  # events whose kind had no handler
        self.dropped_kinds: Dict[str, int] = {}
        self._queue_dirty = False  # a push may outrun run()'s cached head

    def on(self, kind: str, handler: Callable[[float, object], None]) -> None:
        """Register the handler for an event kind (one handler per kind)."""
        if kind in self._handlers:
            raise ValueError(f"handler already registered for event kind {kind!r}")
        self._handlers[kind] = handler

    def push(self, t: float, kind: str, payload: object = None) -> None:
        self._sched.push((t, next(self._seq), kind, payload))
        # run() caches the queue head while draining a stream; any push may
        # schedule ahead of the cached head, so flag it for a re-peek
        self._queue_dirty = True

    def add_stream(self, kind: str, events: Iterable[Tuple[float, object]]) -> None:
        """Merge a pre-sorted (t, payload) stream into the loop lazily.
        Only the stream's head event is held in memory; at equal
        timestamps stream events fire before queued events (matching the
        seed semantics of pushing every arrival before any periodic
        event), and earlier-added streams win ties between streams."""
        stream = _Stream(kind, events)
        if stream.t != float("inf"):
            self._streams.append(stream)

    def _drop(self, t: float, kind: str) -> None:
        """An event fired with no registered handler. The seed kernel
        skipped these SILENTLY; now they are counted (dropped_events /
        dropped_kinds feed ServingSystem.summary()) and a strict loop —
        what the tests run — raises instead."""
        if self.strict:
            raise KeyError(
                f"no handler registered for event kind {kind!r} at "
                f"t={t:.6f} (strict event loop)"
            )
        self.dropped_events += 1
        self.dropped_kinds[kind] = self.dropped_kinds.get(kind, 0) + 1

    def run(self) -> float:
        """Drain queued events and arrival streams in (time, push-order);
        returns the time of the last event processed. The loop itself has
        no horizon — periodic handlers (scale ticks) stop rescheduling
        themselves past theirs, while in-flight service completions
        always run so no work is lost."""
        sched = self._sched
        handlers = self._handlers
        streams = self._streams
        inf = float("inf")
        processed = 0
        while streams:  # merge arrival streams with the queue
            s = streams[0]
            if len(streams) > 1:
                for cand in streams:
                    if cand.t < s.t:
                        s = cand
            if s.t == inf:
                # every stream is exhausted: fall to the stream-free loop
                streams.clear()
                break
            # other streams' heads are static while this one drains; the
            # queue head is cached and re-peeked only when a push lands
            # (the _queue_dirty flag) or a queue event is consumed — so
            # the common case costs no peek at all. Ties BETWEEN streams
            # go to the earliest-added one (the seed pushed stream 0's
            # events first, so they hold the lower sequence numbers):
            # s may drain through a tie with t_other only when it was
            # added before the first other stream holding that head time.
            t_other = inf
            stop_at_tie = False
            seen_s = False
            for c in streams:
                if c is s:
                    seen_s = True
                elif c.t < t_other:
                    t_other = c.t
                    stop_at_tie = not seen_s
            kind_s, it = s.kind, s._it
            handler_s = handlers.get(kind_s)  # constant per stream: hoisted
            t_s, payload_s = s.t, s.payload
            head = sched.peek()
            t_q = head[0] if head is not None else inf
            self._queue_dirty = False
            while True:
                if t_s <= t_q:
                    if t_s > t_other or (stop_at_tie and t_s == t_other):
                        break  # another stream's head is due: switch
                    self.now = t_s
                    processed += 1
                    if handler_s is not None:
                        handler_s(t_s, payload_s)
                    else:
                        self._drop(t_s, kind_s)
                    nxt = next(it, None)
                    if nxt is None:
                        t_s, payload_s = inf, None
                        break
                    t_prev = t_s
                    t_s, payload_s = nxt
                    if t_s < t_prev:
                        s.t, s.payload = t_s, payload_s
                        raise ValueError(
                            f"arrival stream {kind_s!r} is not time-sorted: "
                            f"{t_s} after {t_prev}"
                        )
                else:
                    if t_q >= t_other:
                        break  # another stream's head is due first (ties
                        # between a stream and the queue go to the stream)
                    t, _, kind, payload = sched.pop()
                    self.now = t
                    processed += 1
                    handler = handlers.get(kind)
                    if handler is not None:
                        handler(t, payload)
                    else:
                        self._drop(t, kind)
                    self._queue_dirty = True  # pop moved the head: re-peek
                if self._queue_dirty:
                    head = sched.peek()
                    t_q = head[0] if head is not None else inf
                    self._queue_dirty = False
            s.t, s.payload = t_s, payload_s  # sync the head back
        while len(sched):  # stream-free fast path
            t, _, kind, payload = sched.pop()
            self.now = t
            processed += 1
            handler = handlers.get(kind)
            if handler is not None:
                handler(t, payload)
            else:
                self._drop(t, kind)
        self.processed += processed
        return self.now
