"""Multi-cell serving federation: cross-cell routing + spillover.

One level above engine.py's ServingSystem (paper §IV.B taken to
datacenter scale, DeepRecSys arXiv 2001.02772): a `Cell` wraps ONE
ServingSystem — its own pools, router, cell-local CapacityBudget and
cell-local SLOMonitor — and a `FederatedSystem` routes arrivals across
cells over one shared EventLoop. The layering mirrors the pool layer
exactly one level up:

    Router picks the pool a request enters      (router.py,   intra-cell)
    CellPolicy picks the CELL a request enters  (this module, inter-cell)

Public API
    CellSpec          everything needed to bring up one cell
    Cell              the running cell: embedded ServingSystem + spill
                      accounting + read-only load signals for policies
    CellPolicy        base class; shipped policies in CELL_POLICIES:
                      sticky (home-cell), least_loaded, cost_model
    FederatedSystem   arrivals -> cell policy -> cell admission, with
                      cross-cell spillover; run() returns fleet metrics
    assign_homes      deterministic weighted home-cell assignment for an
                      arrival list (skewed per-cell traffic)

Spillover semantics: a request is offered to the cell its policy picked
(a policy that routes a homed request OFF its home cell pays the same
`rtt_s` hop in transit that its decision rule charged — homeless
requests originate at a global front door and hop free). When the entry
cell is past its SLO headroom (predicted latency above `spill_headroom *
slo`) or its admission sheds the request, the federation spills it to
the best remote cell with headroom — ONE hop, paying `rtt_s` seconds of
inter-cell transit before remote admission. A
cascade stays within its home cell, except the rerank stage: when the
home rerank pool is past headroom and a remote cell runs a same-named
pool that is predicted cheaper even after the RTT, the stage spills
(`submit(force=True)` — stage-1 work is never dropped) and the request's
stage timeline stamps survive the hop (`s1_*` from home, `s2_*` remote).

Accounting invariants (tests/test_serving.py pins these down):
  - fleet-wide conservation: injected == completed + rejected +
    in_flight, where in_flight counts cell queues AND inter-cell transit;
    after the loop drains, in_flight == 0;
  - spill attribution is separate from rejection: per cell, arrived ==
    completed + rejected + spilled_out once drained, and the fleet's
    spilled_out total equals its spilled_in total;
  - per-cell budgets are independent: one cell scaling up never spends
    another cell's CapacityBudget — unless an optional GLOBAL cap is set,
    which bounds the sum (autoscaler.py hierarchical budgets);
  - determinism: given one arrival list (homes assigned by seed), any
    cell topology replays bit-identically.

Units: all times in seconds on the shared loop clock; `rtt_s` is the
one-way inter-cell transfer penalty per hop — or pass `rtt`, a dict
keyed by (src, dst) cell-name pairs (RttMatrix: symmetric fallback, then
the scalar), and every hop — policy charge, spill transit, cascade-stage
spill — consults the pair's own value.

Cells may own DIFFERENT platform-class mixes (replica.py family
constructors): a CPU-only edge cell next to an accelerator-heavy core
cell is a normal topology, and the cell policies see that heterogeneous
capacity without any special casing — `Cell.predicted_latency` is the
cost-model estimate AT THE REQUEST'S COST, so an accelerator-only cell
quotes its high fixed cost to a pointwise probe and its flat curve to a
512-candidate ranking query, and spillover targets rank the same way.
A ranking query homed on a CPU-only cell therefore spills to
accelerator capacity as soon as its home quote exceeds the remote
quote plus the RTT. `Cell.platforms` (and the "platforms" summary key)
reports each cell's mix; per-class control corrections roll up
un-blended through `metrics.fleet_control_rollup`.

Control is cell-local too (serving/control.py via each pool's
PoolSpec.control): every cell's pools learn their own latency
corrections and adapt their own batch caps from their own SLO signals —
there is no fleet-wide controller to fight cell-local drift — and the
per-cell control summaries roll up through `federated_rollup` next to
the cache tallies.

Caches are cell-local (serving/cache.py via each pool's PoolSpec.cache):
a request spilled to a remote cell runs its ids through THAT cell's
caches, so with per-cell hot sets a spill pays cold misses remotely —
spillover trades queueing delay against cache locality, and the summary
shows both sides (per-cell hit rates + fleet cache rollup).

The embedding TABLE, by contrast, is fleet-global: pass
`shard=EmbeddingShardService(...)` (serving/shard.py) and every cell's
pools fetch their cache misses from the same sharded table — shards
homed in the serving cell are local, remote shards pay this
federation's per-pair RTT matrix (bound onto the shard service when it
was built without one). Online table updates arrive as
("shard_update", ids) events on the shared loop and propagate
invalidations shard -> cell L2 -> pool L1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.serving.autoscaler import CapacityBudget
from repro.core.serving.cascade import CascadeConfig
from repro.core.serving.engine import PoolSpec, ServingSystem, default_horizon
from repro.core.serving.events import EventLoop
from repro.core.serving.metrics import (
    SLOMonitor, SpillStats, TraceBuffer, federated_rollup,
)
from repro.core.serving.pool import Request
from repro.core.serving.rate_limiter import TierPolicy
from repro.core.serving.replica import ReplicaSpec
from repro.core.serving.router import CostModelRouter, Router, make_router
# RttMatrix moved down to shard.py (the shard tier charges hops from the
# same matrix and sits below the federation); re-exported here so existing
# `from ...federation import RttMatrix` imports keep working
from repro.core.serving.shard import EmbeddingShardService, RttMatrix


@dataclasses.dataclass
class CellSpec:
    """Everything needed to bring up one cell: its pools (same shapes
    engine.py takes), an intra-cell router, optional fleet tiers, an
    optional cell-local replica budget (`capacity`; parented to the
    federation's global cap when one is set) and an optional cascade."""

    pools: Dict[str, Union[PoolSpec, ReplicaSpec]]
    router: Optional[Router] = None
    tiers: Optional[Dict[str, TierPolicy]] = None
    capacity: Optional[int] = None
    cascade: Optional[CascadeConfig] = None
    slo_p99_s: float = 0.100
    adaptive_shedding: bool = True


class Cell:
    """One cell of the federation: a ServingSystem embedded on the shared
    loop (event namespace = cell name), plus spill accounting and the
    read-only load signals cell policies and the spillover logic use."""

    def __init__(self, name: str, spec: CellSpec, loop: EventLoop,
                 budget: Optional[CapacityBudget], scale_tick_s: float,
                 rtt: Optional[RttMatrix] = None,
                 shard: Optional[EmbeddingShardService] = None,
                 tracer=None):
        self.name = name
        # per-pair transfer time INTO this cell; policies charge it for
        # off-home candidates so the decision rule and the physical hop
        # (FederatedSystem._transit) always agree
        self._rtt = rtt if rtt is not None else RttMatrix(0.0)
        self.system = ServingSystem(
            spec.pools, spec.router, tiers=spec.tiers,
            slo_p99_s=spec.slo_p99_s, scale_tick_s=scale_tick_s,
            capacity=budget, cascade=spec.cascade,
            adaptive_shedding=spec.adaptive_shedding,
            loop=loop, event_ns=name, shard=shard, tracer=tracer,
        )
        self.spill = SpillStats()

    def rtt_from(self, src: str) -> float:
        """One-way transfer seconds from cell `src` into this cell (0 for
        itself and for homeless front-door arrivals)."""
        return self._rtt(src, self.name)

    @property
    def platforms(self) -> Tuple[str, ...]:
        """The platform classes this cell's pools draw from, sorted —
        cells may own different mixes (a CPU-only edge cell, an
        accelerator core cell), and policies price that heterogeneity
        through `predicted_latency` at the request's cost."""
        return tuple(sorted({p.spec.platform for p in self.system.pools.values()}))

    # ---- read-only signals for cell policies / spillover ----
    def predicted_latency(self, now: float, cost: int = 1) -> float:
        """Completion-time estimate for an arrival entering this cell
        (calibrated LatencyModel + live queue residuals — the same
        cost-model estimate the intra-cell router uses). For a plain cell
        the estimate is minimised over pools, because the router will pick
        the best one on admission; for a cascade cell the entry pool is
        FIXED (stage 1, at the full candidate cost), so an idle rerank
        pool must not make a saturated filter pool look like headroom."""
        if self.system.cascade is not None:
            cfg = self.system.cascade.cfg
            entry = self.system.pools[cfg.stage1]
            return CostModelRouter.estimate(entry, cfg.candidates, now)
        return min(
            CostModelRouter.estimate(p, cost, now)
            for p in self.system.pools.values()
        )

    def has_headroom(self, now: float, cost: int, headroom_s: float) -> bool:
        return self.predicted_latency(now, cost) <= headroom_s

    def summary(self) -> Dict:
        return {**self.system.summary(), "spill": self.spill.as_dict(),
                "platforms": list(self.platforms)}


# ---------------------------------------------------------------------------
# cell-level policies (the Router registry pattern, one level up)
# ---------------------------------------------------------------------------


class CellPolicy:
    name = "base"

    def select_cell(self, req: Request, cells: Sequence[Cell], now: float) -> Cell:
        raise NotImplementedError

    @staticmethod
    def _home_or_first(req: Request, cells: Sequence[Cell]) -> Cell:
        for c in cells:
            if c.name == req.home:
                return c
        # no affinity: deterministic round-robin by request id
        return cells[req.rid % len(cells)]


class StickyCellPolicy(CellPolicy):
    """Home-cell affinity: every request enters its home cell (user state,
    embedding caches live there); requests without a home round-robin by
    id. Load balance across cells comes only from spillover."""

    name = "sticky"

    def select_cell(self, req, cells, now):
        return self._home_or_first(req, cells)


class LeastLoadedCellPolicy(CellPolicy):
    """Global shortest-expected-delay across cells: the home cell competes
    at par, remote cells are charged the inter-cell RTT from the request's
    home (per-pair when the federation has an RTT matrix) — so traffic
    stays home until a remote cell is genuinely cheaper despite the hop."""

    name = "least_loaded"

    def select_cell(self, req, cells, now):
        home = req.home
        return min(
            cells,
            key=lambda c: c.predicted_latency(now, req.cost) + c.rtt_from(home),
        )


class CostModelCellPolicy(LeastLoadedCellPolicy):
    """Cost-model routing at cell level: per-cell calibrated latency +
    queue residuals (Cell.predicted_latency) + RTT for non-home cells.
    Inherits LeastLoadedCellPolicy's decision rule verbatim — registered
    under its own name so the estimate can grow cell-specific terms
    (egress bandwidth, per-cell power caps) without renaming policies."""

    name = "cost_model"


CELL_POLICIES: Dict[str, type] = {
    StickyCellPolicy.name: StickyCellPolicy,
    LeastLoadedCellPolicy.name: LeastLoadedCellPolicy,
    CostModelCellPolicy.name: CostModelCellPolicy,
}


def make_cell_policy(name: str, **kwargs) -> CellPolicy:
    return make_router(name, registry=CELL_POLICIES, **kwargs)


# ---------------------------------------------------------------------------
# the federation
# ---------------------------------------------------------------------------


class FederatedSystem:
    """Routes arrivals across cells on one shared EventLoop.

    `policy` picks the entry cell (name from CELL_POLICIES or a CellPolicy
    instance). With `spillover=True`, a request whose entry cell is past
    its SLO headroom — or whose admission sheds it — takes ONE hop to the
    best remote cell with headroom, paying `rtt_s` in transit. `capacity`
    is an optional GLOBAL replica cap: each cell's own budget becomes a
    child of it, so cells stay independent until the global cap binds."""

    def __init__(
        self,
        cells: Dict[str, CellSpec],
        policy: Union[str, CellPolicy] = "sticky",
        *,
        rtt_s: float = 0.005,
        rtt: Optional[Dict[Tuple[str, str], float]] = None,
        spillover: bool = True,
        spill_headroom: float = 0.8,
        capacity: Optional[int] = None,
        slo_p99_s: float = 0.100,
        scale_tick_s: float = 1.0,
        scheduler: str = "calendar",
        strict_events: bool = False,
        shard: Optional[EmbeddingShardService] = None,
        tracer=None,
    ):
        if not cells:
            raise ValueError("a federation needs at least one cell")
        # every cell shares this one loop, so the scheduler choice and
        # strict-mode policy are fleet-wide
        self.loop = EventLoop(scheduler=scheduler, strict=strict_events)
        self.policy = make_cell_policy(policy) if isinstance(policy, str) else policy
        self.tracer = tracer
        self.rtt_s = rtt_s
        self.rtt = RttMatrix(rtt_s, rtt)  # per-(src, dst) with scalar fallback
        self.shard = shard
        if shard is not None and shard.rtt is None:
            # shard fetches and spill hops charge the SAME per-pair matrix
            shard.rtt = self.rtt
        self.spillover = spillover
        self.spill_headroom = spill_headroom
        self.slo_p99_s = slo_p99_s
        self.scale_tick_s = scale_tick_s
        self.global_budget = CapacityBudget(capacity) if capacity is not None else None
        self.cells: Dict[str, Cell] = {}
        for name, spec in cells.items():
            if spec.capacity is not None:
                budget = CapacityBudget(spec.capacity, parent=self.global_budget)
            else:
                budget = self.global_budget  # share the global cap directly
            cell = Cell(name, spec, self.loop, budget, scale_tick_s,
                        rtt=self.rtt, shard=shard, tracer=tracer)
            cell.system.on_complete = self._request_done
            cell.system.spill_stage = (
                lambda now, req, pool_name, _cell=cell:
                self._maybe_spill_stage(now, req, _cell, pool_name)
            )
            self.cells[name] = cell
        self.monitor = SLOMonitor(slo_s=slo_p99_s)  # fleet end-to-end
        self.in_transit = 0
        self._horizon = float("inf")
        self._completed_in_horizon = 0
        self._ran = False
        self.trace = TraceBuffer([
            "t", "p99", "qps", ("spilled", np.int64), ("in_transit", np.int64)
        ])
        self.loop.on("arrive", self._handle_arrive)
        self.loop.on("route", self._handle_route)
        self.loop.on("spill", self._handle_spill)
        self.loop.on("spill_stage", self._handle_spill_stage)
        self.loop.on("scale", self._handle_scale)
        if shard is not None:
            # online table updates: push/stream ("shard_update", ids) onto
            # the shared loop; the publish propagates shard -> L2 -> L1
            self.loop.on("shard_update", self._handle_shard_update)

    def _handle_shard_update(self, now: float, ids) -> None:
        self.shard.publish(ids)

    # ---- spill decisions ----
    def _headroom_s(self, cell: Cell) -> float:
        return self.spill_headroom * cell.system.slo_p99_s

    def _transit(self, now: float, kind: str, payload, delay_s: float) -> None:
        """One inter-cell hop: the request is in flight for the pair's RTT
        before the delivery handler (which decrements in_transit) runs."""
        self.in_transit += 1
        self.loop.push(now + delay_s, kind, payload)

    def _spill_target(self, now: float, req: Request, from_cell: Cell) -> Optional[Cell]:
        """Best remote cell with SLO headroom, ranked by predicted latency
        plus the (src, dst) transit it would pay — with a per-pair RTT
        matrix a nearby cell beats an equally loaded far one. None keeps
        the request (and its fate) at `from_cell`. Deterministic: min over
        insertion order; the headroom filter looks at the cell's own
        predicted latency (the hop happens regardless of who pays it)."""
        scored = [
            (c, c.predicted_latency(now, req.cost))
            for c in self.cells.values() if c is not from_cell
        ]
        cands = [(c, pred + self.rtt(from_cell.name, c.name))
                 for c, pred in scored if pred <= self._headroom_s(c)]
        if not cands:
            return None
        return min(cands, key=lambda cp: cp[1])[0]

    def _spill(self, now: float, req: Request, from_cell: Cell, to_cell: Cell) -> None:
        from_cell.spill.spilled_out += 1
        self._transit(now, "spill", (req, to_cell.name),
                      self.rtt(from_cell.name, to_cell.name))

    def _offer(self, now: float, req: Request, cell: Cell, *, can_spill: bool) -> None:
        """One cell's shot at a request: proactive spill when the cell is
        past its headroom, else admission, else reactive spill, else a
        rejection (counted at the cell AND fleet-wide). Spilled requests
        arrive with can_spill=False — one hop, no ping-pong."""
        cell.system.monitor.arrived += 1
        if can_spill and self.spillover and not cell.has_headroom(
                now, req.cost, self._headroom_s(cell)):
            target = self._spill_target(now, req, cell)
            if target is not None:
                self._spill(now, req, cell, target)
                return
        if cell.system.try_submit(now, req):
            return
        if can_spill and self.spillover:
            target = self._spill_target(now, req, cell)
            if target is not None:
                self._spill(now, req, cell, target)
                return
        cell.system.monitor.rejected += 1
        self.monitor.rejected += 1

    def _maybe_spill_stage(self, now: float, req: Request, home: Cell,
                           pool_name: str) -> bool:
        """Cascade rerank spillover: claim the next stage for a remote cell
        when the home pool is past headroom and a remote same-named pool is
        predicted cheaper even after the RTT. Called by the home cell's
        engine; returning False keeps the stage home."""
        if not self.spillover:
            return False
        home_pool = home.system.pools[pool_name]
        home_pred = home_pool.predicted_latency(now, req.cost)
        if home_pred <= self._headroom_s(home):
            return False
        best, best_pred = None, home_pred
        for cell in self.cells.values():
            if cell is home or pool_name not in cell.system.pools:
                continue
            hop = self.rtt(home.name, cell.name)
            pred = cell.system.pools[pool_name].predicted_latency(now, req.cost)
            if pred + hop < best_pred:
                best, best_pred = cell, pred + hop
        if best is None:
            return False
        home.spill.spilled_out += 1
        home.spill.cascade_out += 1
        self._transit(now, "spill_stage", (req, best.name, pool_name),
                      self.rtt(home.name, best.name))
        return True

    # ---- event handlers ----
    def _handle_arrive(self, now: float, req: Request) -> None:
        self.monitor.arrived += 1
        cell = self.policy.select_cell(req, list(self.cells.values()), now)
        if req.home and cell.name != req.home:
            # the policy routed this arrival off its home cell: the hop is
            # physical, so it pays the same (home, dst) RTT the decision
            # rule charged (requests without a home originate at a global
            # front door — no hop to pay, matching the zero charge)
            self._transit(now, "route", (req, cell.name),
                          self.rtt(req.home, cell.name))
            return
        self._offer(now, req, cell, can_spill=True)

    def _handle_route(self, now: float, payload) -> None:
        req, target_name = payload
        self.in_transit -= 1
        self._offer(now, req, self.cells[target_name], can_spill=True)

    def _handle_spill(self, now: float, payload) -> None:
        req, target_name = payload
        self.in_transit -= 1
        cell = self.cells[target_name]
        cell.spill.spilled_in += 1
        self._offer(now, req, cell, can_spill=False)

    def _handle_spill_stage(self, now: float, payload) -> None:
        req, target_name, pool_name = payload
        self.in_transit -= 1
        cell = self.cells[target_name]
        cell.system.monitor.arrived += 1
        cell.spill.spilled_in += 1
        cell.spill.cascade_in += 1
        # force: stage-1 work is already spent; remote admission never
        # sheds a mid-cascade request
        cell.system.pools[pool_name].submit(now, req, force=True)

    def _request_done(self, now: float, req: Request) -> None:
        """Cell on_complete hook: fleet-wide end-to-end latency (includes
        any inter-cell RTT the request paid — latency is done - t_arrive)."""
        self.monitor.record(now, now - req.t_arrive)
        if now <= self._horizon:
            self._completed_in_horizon += 1

    def _handle_scale(self, now: float, _payload) -> None:
        if now > self._horizon:
            return
        stats = self.monitor.percentiles(now)
        self.trace.append(
            now, stats["p99"], stats["qps"],
            sum(c.spill.spilled_out for c in self.cells.values()),
            self.in_transit,
        )
        if now + self.scale_tick_s <= self._horizon:
            self.loop.push(now + self.scale_tick_s, "scale")

    # ---- simulation ----
    def run(self, arrivals: List[Request], until: Optional[float] = None) -> Dict:
        if self._ran:
            raise RuntimeError(
                "this FederatedSystem has already run once; cell monitors, "
                "queues and replica state accumulate — build a fresh one"
            )
        self._ran = True
        if arrivals:
            # lazy stream instead of one heap tuple per arrival (see
            # ServingSystem.run): the stable sort keeps the seed's
            # (t, push-order) fire order bit-exact
            ordered = sorted(arrivals, key=lambda r: r.t_arrive)
            self.loop.add_stream("arrive", ((r.t_arrive, r) for r in ordered))
        self._horizon = until if until is not None else default_horizon(arrivals)
        for cell in self.cells.values():
            # start() marks each embedded system as started, so calling
            # run() directly on a federation cell raises
            cell.system.start(self._horizon)
        # first fleet tick clamped into the horizon (engine.start does the
        # same for each cell): short runs still trace and adapt
        self.loop.push(min(self.scale_tick_s, self._horizon), "scale")
        self.loop.run()
        return self.summary()

    def summary(self) -> Dict:
        totals = self.monitor.totals()
        cells = {name: cell.summary() for name, cell in self.cells.items()}
        rollup = federated_rollup(cells)
        in_flight = rollup["in_queue"] + self.in_transit
        return {
            "p50": totals["p50"],
            "p99": totals["p99"],
            "mean_latency": totals["mean"],
            "slo_attainment": totals["attainment"],
            # conservation: injected == completed + rejected + in_flight,
            # fleet-wide, with spill transit counted as in-flight
            "injected": self.monitor.arrived,
            "completed": self.monitor.completed,
            "rejected": self.monitor.rejected,
            "in_flight": in_flight,
            "in_transit": self.in_transit,
            "spilled": rollup["spilled_out"],
            "spilled_in": rollup["spilled_in"],
            "cascade_spilled": rollup["cascade_out"],
            "completed_in_horizon": self._completed_in_horizon,
            "throughput": (
                self._completed_in_horizon / self._horizon
                if self._horizon > 0 else 0.0
            ),
            "final_replicas": rollup["final_replicas"],
            "dropped_events": self.loop.dropped_events,
            "dropped_kinds": dict(self.loop.dropped_kinds),
            # fleet-wide cache/shard tallies (summed across cells) so the
            # fleet scope exposes staleness the same way each cell does
            "cache": rollup["cache"],
            # fleet latency attribution: the cells' always-on breakdown
            # blocks rolled up (metrics.fleet_breakdown_rollup) — transit
            # here includes every inter-cell RTT spill hops paid
            "latency_breakdown": rollup["latency_breakdown"],
            "trace": self.trace.as_dict(),
            # fleet-global shard view (per-cell fetch splits live in each
            # cell's own summary["shard"] and in summary["cache"] rollups)
            "shard": self.shard.summary() if self.shard is not None else None,
            "cells": cells,
        }


def assign_homes(arrivals: Sequence[Request], weights: Dict[str, float],
                 *, seed: int = 0) -> List[Request]:
    """Assign each arrival a home cell by weighted draw — deterministic
    under the seed, and idempotent on replay (re-running over the same
    list reassigns the same homes). Skew the weights to model a hot cell:
    assign_homes(arr, {"us": 0.7, "eu": 0.2, "ap": 0.1})."""
    names = list(weights)
    w = np.asarray([weights[n] for n in names], dtype=np.float64)
    w = w / w.sum()
    rng = np.random.default_rng(seed)
    draws = rng.choice(len(names), size=len(arrivals), p=w)
    for req, idx in zip(arrivals, draws):
        req.home = names[int(idx)]
    return list(arrivals)
