"""Replica pools: each pool owns the replicas of ONE Table-I variant,
with its own batcher (max_batch / max_batch_items / max_wait), its own
AutoScaler, its own SLOMonitor and (optionally) its own tiered rate
limiter. Pools plug into a shared EventLoop; the router decides which
pool a request enters, the pool decides whether it is admitted, how it
is batched and which replica serves it (via a pluggable replica picker).

Public API
    Request                the unit of traffic; `cost` is work items
                           carried (1 = pointwise, candidate-set size =
                           ranking), `stage` the cascade stage, `home`
                           the request's home cell (federation)
    PoolConfig             batching + scaling knobs
    ReplicaPool.submit     admission (pool-local limiter) + enqueue;
                           `force=True` bypasses admission (cascade
                           advancement, cross-cell spill arrivals)
    ReplicaPool.predicted_latency / predicted_miss_cost / hit_rate /
    recent_p99 / queued_cost
                           read-only router signals
    ReplicaPool.scale_tick autoscaler + limiter adaptation, driven by
                           the engine's per-tick `scale` event
    ReplicaPool.summary    end-of-run per-pool stats

Units: all times are SECONDS on the shared event-loop clock; `cost`,
`max_batch_items` and `queued_cost` are work ITEMS; rates are per-second.

Invariants the tests pin down:
  - conservation: every submitted request is eventually dispatched and
    completed exactly once (sheds happen only in submit, before enqueue);
  - batching: a closed batch holds <= max_batch requests and (when item
    batching is on) <= max_batch_items work items — except a single
    oversized request, which dispatches alone;
  - no request waits more than max_wait_s for a batch to close (a partial
    remainder re-arms its deadline from the OLDEST queued enqueue time);
  - determinism: given the same arrival list and picker, two runs produce
    bit-identical timelines.

Batching is cost-aware (DeepRecSys-style): a batch closes when it holds
`max_batch` requests OR carries `max_batch_items` work items, whichever
first — so one 512-candidate ranking query does not share a count budget
with 64 pointwise queries. Admission is cost-aware too: a pool-local
HybridRateLimiter draws `Request.cost` tokens per admit and sheds tiers
from the pool's OWN SLO signal, so an overloaded heavy pool protects
itself while cheap pools keep absorbing tail traffic (the fleet-global
limiter in engine.py stays as the outer guard).

Caching is per-pool (serving/cache.py): with a CacheConfig the pool owns
a hot-ID EmbeddingCache — each dispatched batch runs its requests' ids
through it in queue order and pays `ReplicaSpec.embed_fetch_s` per missed
row on top of the dense service time — and optionally a request-signature
ResultCache whose fresh repeats complete instantly (no tokens, no batch).
A pool with NO cache fetches every id row its traffic carries: the
memory-bound baseline the cache exists to beat. Hit-rate feeds the trace,
the summary and the routers' predicted miss cost.

With the shard tier (serving/shard.py) the pool cache becomes the L1 of
a real hierarchy: L1 misses probe the cell-shared L2 cache
(`l2_cache`, built by the engine from CacheConfig.l2), and what BOTH
miss is fetched from the sharded table in one batched `shard.fetch`
call — local-shard rows pay `embed_fetch_s`, remote-shard rows
additionally pay one inter-cell RTT per (batch, remote shard). The
decomposition travels as a `replica.MissProfile` through service time,
the batch-done observation and `predicted_miss_cost`, so the
cost-model router sees the same three-way split the clock charges.

The control plane is per-pool too (serving/control.py, opt-in via a
ControlConfig): an OnlineLatencyModel EWMA-corrects the offline-
calibrated curve from each completed batch's (items, miss rows,
measured seconds) — `dense_latency`, `predicted_latency` and the cost-
model router then consult the corrected curve instead of trusting a
possibly drifted calibration — and a BatchSizeController retunes the
pool's EFFECTIVE `max_batch_items` each scale tick from SLO headroom
(breach narrows for latency, headroom widens for throughput), traced
per tick next to replicas/p99. The id-rows-per-item average feeding
`predicted_miss_cost` is a windowed EWMA of per-batch ratios (it was a
never-decaying lifetime counter), so a traffic-mix shift stops
haunting the miss-cost prediction forever.

Scaling is per-pool but capacity is fleet-wide: every grow request goes
through the shared CapacityBudget, so heterogeneous pools compete for
the same accelerators instead of each assuming it owns the cluster. In a
multi-cell federation the budget may itself be a cell-local slice of a
global cap (see autoscaler.py).

Several pools share one EventLoop by namespacing their events with
`event_key` — cells pass "<cell>/<pool>" so two cells can each run a
"baseline" pool on the federation's shared loop without colliding.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.serving.autoscaler import AutoScaler, CapacityBudget, ScalerConfig
from repro.core.serving.cache import CacheConfig, EmbeddingCache, ResultCache
from repro.core.serving.control import (
    BatchSizeController, ControlConfig, Ewma, OnlineLatencyModel,
)
from repro.core.serving.events import EventLoop
from repro.core.serving.metrics import SLOMonitor, TraceBuffer
from repro.core.serving.rate_limiter import HybridRateLimiter, TierPolicy
from repro.core.serving.replica import MissProfile, Replica, ReplicaSpec
from repro.core.serving.shard import EmbeddingShardService
from repro.core.serving.tracing import (
    BreakdownAccumulator, Tracer, service_phases,
)


@dataclasses.dataclass
class Request:
    rid: int
    t_arrive: float
    tier: str
    priority: bool = False
    cost: int = 1  # work items carried (e.g. candidates to score)
    stage: int = 0  # 0 = single-stage; 1, 2, ... = cascade stages
    home: str = ""  # home cell in a multi-cell federation ("" = no affinity)
    ids: Optional[Tuple[int, ...]] = None  # embedding ids touched (cache layer);
    # a tuple so the same value doubles as the ResultCache signature
    t_enqueue: float = 0.0  # when it entered the current pool
    timeline: Dict[str, float] = dataclasses.field(default_factory=dict)

    def stamp(self, event: str, t: float) -> None:
        # stage 0 stamps under its own "s0_" prefix so replaying one
        # arrival list through a baseline run and then a cascade run
        # (which shares the timeline dict, see cascade.admit) keeps both
        # runs' stamps instead of the cascade overwriting stage-1 keys
        self.timeline[f"s{self.stage}_{event}"] = t


@dataclasses.dataclass
class PoolConfig:
    max_batch: int = 64  # batch closes at this many requests...
    max_wait_s: float = 0.005  # ...or when the oldest has waited this long...
    max_batch_items: Optional[int] = None  # ...or at this many work items
    n_replicas: int = 2
    autoscale: bool = True
    priority_bypass: bool = True

    @classmethod
    def for_platform(cls, platform: str, **overrides) -> "PoolConfig":
        """Batching defaults matched to a platform class's curve shape
        (replica.py family constructors), so a heterogeneous fleet gets
        per-class batching out of the box: CPU-class pools close small
        batches fast (a steep per-item slope means wide batches only
        add latency — and a 512-item ranking batch routed there by a
        size-blind policy dispatches ALONE rather than holding
        pointwise traffic hostage); accelerator-class pools batch wide
        and wait longer to amortise their fixed cost. Unknown platforms
        get the generic defaults. Any field can be overridden."""
        defaults = {
            "cpu": dict(max_batch=16, max_batch_items=64, max_wait_s=0.002),
            "accelerator": dict(max_batch=64, max_batch_items=2048,
                                max_wait_s=0.010),
        }.get(platform, {})
        defaults.update(overrides)
        return cls(**defaults)


class ReplicaPool:
    def __init__(
        self,
        name: str,
        spec: ReplicaSpec,
        cfg: PoolConfig,
        loop: EventLoop,
        *,
        scaler_cfg: Optional[ScalerConfig] = None,
        budget: Optional[CapacityBudget] = None,
        on_complete: Optional[Callable[[float, Request, "ReplicaPool"], None]] = None,
        slo_s: Optional[float] = None,
        picker: Optional[Callable[["ReplicaPool", float], Replica]] = None,
        tiers: Optional[Dict[str, TierPolicy]] = None,
        event_key: Optional[str] = None,
        cache_cfg: Optional[CacheConfig] = None,
        control_cfg: Optional[ControlConfig] = None,
        l2_cache: Optional[EmbeddingCache] = None,
        shard: Optional[EmbeddingShardService] = None,
        cell: str = "",
        tracer: Optional[Tracer] = None,
    ):
        self.name = name
        # events are keyed by event_key, not name: a federation runs several
        # cells' same-named pools on one loop ("cell0/baseline" vs name
        # "baseline", which routers and reports keep seeing)
        self.event_key = event_key or name
        self.spec = spec
        self.cfg = cfg
        self.loop = loop
        self.scaler = AutoScaler(scaler_cfg or ScalerConfig(min_replicas=cfg.n_replicas))
        self.budget = budget
        self.on_complete = on_complete or (lambda now, req, pool: None)
        self.monitor = SLOMonitor(slo_s=slo_s)
        self.picker = picker or (lambda pool, now: min(pool.replicas, key=lambda r: r.load(now)))
        # pool-local admission: cost-weighted token draws, shed level driven
        # by THIS pool's SLO signal (scale_tick) — None admits everything
        self.limiter = HybridRateLimiter(tiers) if tiers is not None else None
        self.shed = 0
        # caching layer: a per-pool hot-ID embedding cache (misses pay
        # spec.embed_fetch_s each on top of the dense service time) and an
        # optional request-signature result cache for repeat queries
        self.embed_cache: Optional[EmbeddingCache] = None
        self.result_cache: Optional[ResultCache] = None
        if cache_cfg is not None:
            self.embed_cache = EmbeddingCache(cache_cfg.capacity_rows, cache_cfg.policy)
            if cache_cfg.result_capacity > 0:
                self.result_cache = ResultCache(
                    cache_cfg.result_capacity, cache_cfg.result_ttl_s
                )
        # shard tier: the cell-shared L2 (one EmbeddingCache for all pools
        # in this cell, engine-built) and the fleet's shard service; the
        # pool's own cache becomes the hierarchy's L1 and joins the shard's
        # invalidation fan-out (AFTER the L2 — the engine registers that
        # first, so updates propagate shard -> L2 -> L1)
        self.l2_cache = l2_cache
        self.shard = shard
        self.cell = cell
        if shard is not None and self.embed_cache is not None:
            shard.register_cache(self.embed_cache)
        # control plane (serving/control.py): online-corrected latency
        # curve + SLO-aware effective item cap, both opt-in
        self.control_cfg = control_cfg
        ewma_alpha = control_cfg.ewma_alpha if control_cfg is not None else 0.25
        self.model: Optional[OnlineLatencyModel] = None
        if control_cfg is not None and control_cfg.online_latency:
            self.model = OnlineLatencyModel(
                spec.latency, spec.embed_fetch_s, alpha=ewma_alpha)
        self.controller: Optional[BatchSizeController] = None
        if control_cfg is not None and control_cfg.adapt_batch:
            self.controller = BatchSizeController(
                control_cfg, initial=cfg.max_batch_items)
        # windowed id-rows-per-item average (per-batch ratios, EWMA): the
        # routers' predicted miss cost for a prospective batch, learned
        # from dispatched traffic and able to FORGET an old traffic mix
        self._rows_per_item = Ewma(ewma_alpha)
        # latency attribution (serving/tracing.py): the always-on stage
        # breakdown — every completion decomposes against its enqueue time,
        # so breakdown.count tracks monitor.completed — and the OPTIONAL
        # sampling tracer, which only observes (no summary reads it)
        self.breakdown = BreakdownAccumulator()
        self.tracer = tracer

        if budget is not None and budget.acquire(cfg.n_replicas) < cfg.n_replicas:
            raise ValueError(
                f"capacity budget exhausted bringing up pool {name!r} "
                f"({cfg.n_replicas} initial replicas, {budget.available} left)"
            )
        self.replicas: List[Replica] = [
            Replica(i, spec, ready_at=0.0) for i in range(cfg.n_replicas)
        ]
        self._registry: Dict[int, Replica] = {r.rid: r for r in self.replicas}
        self._rid = itertools.count(len(self.replicas))

        self.queue: List[Request] = []
        self.queued_cost = 0  # running sum of queue costs (O(1) router signal)
        self._batch_deadline: Optional[float] = None
        self.trace = TraceBuffer([
            "t", ("replicas", np.int64), ("queue", np.int64), "p99",
            "hit_rate", "max_batch_items", "latency_corr"
        ])

        loop.on(f"batch_timeout:{self.event_key}", self._handle_timeout)
        loop.on(f"batch_done:{self.event_key}", self._handle_done)

    # ---- routing signals ----
    def dense_latency(self, items: int) -> float:
        """Predicted dense service time at `items` work items: the
        online-corrected curve when the control plane is learning one,
        else the offline calibration — the ONE dense-latency lens every
        predictor (predicted_latency, CostModelRouter.estimate) looks
        through, so a drifted calibration stops misrouting as soon as
        observations arrive."""
        if self.model is not None:
            return self.model.dense(items)
        return self.spec.latency(items)

    def predicted_latency(self, now: float, cost: int = 1) -> float:
        """Router signal: wait for the freest replica + service time of the
        backlog this request would join (dense + predicted miss cost)."""
        ready = [r for r in self.replicas if r.ready_at <= now] or self.replicas
        wait = min(r.load(now) for r in ready)
        items = self.queued_cost + cost
        return wait + self.dense_latency(items) + self.predicted_miss_cost(items)

    def predicted_miss_cost(self, items: int) -> float:
        """Expected embedding-fetch seconds for a batch of `items` work
        items, decomposed the same three ways the service clock charges
        (L1 miss -> L2 hit -> shard local/remote): the pool's learned
        id-rows-per-item average (windowed EWMA of per-batch ratios)
        discounted by the live L1 hit-rate gives the rows reaching the
        L2; discounting by the live L2 hit-rate gives the rows reaching
        the shard tier, each paying the per-row fetch PLUS this cell's
        learned per-row inter-cell transit — so the cost-model router
        prefers cells whose L2 and local shards are warm. No cache =
        every row fetches; no shard = no transit leg. Zero until the
        pool has dispatched id-carrying traffic — cold pools compete on
        dense cost alone. The per-row fetch consults the online-
        corrected model when one is learning."""
        fetch = self.model.fetch_s if self.model is not None else self.spec.embed_fetch_s
        if self._rows_per_item.value is None:
            return 0.0
        rows = self._rows_per_item.value * items
        if self.embed_cache is not None:
            rows *= 1.0 - self.embed_cache.hit_rate
        if self.l2_cache is not None:
            rows *= 1.0 - self.l2_cache.hit_rate
        per_row = max(fetch, 0.0)
        if self.shard is not None:
            per_row += self.shard.predicted_transit_per_row(self.cell)
        return rows * per_row

    def hit_rate(self) -> float:
        return self.embed_cache.hit_rate if self.embed_cache is not None else 0.0

    def recent_p99(self, now: float) -> float:
        return self.monitor.percentiles(now)["p99"]

    # ---- admission / batching ----
    def submit(self, now: float, req: Request, *, force: bool = False) -> bool:
        """Admit (pool-local limiter, cost-weighted) and enqueue. Returns
        False when this pool's limiter sheds the request. `force=True`
        bypasses pool admission — cascade stage advancement uses it so work
        already paid for upstream is never dropped mid-chain."""
        # result-cache fast path: a repeat query whose signature is still
        # fresh completes immediately — no pool-local admission tokens, no
        # batching, no service (the fleet-global front-door limiter has
        # already been paid by this point). Mid-cascade (force) submissions
        # never shortcut: their upstream stage produced fresh scores to
        # rerank.
        if (
            self.result_cache is not None
            and not force
            and req.ids is not None
            # signature = (ids, cost): a pointwise probe and a 512-candidate
            # ranking request over the SAME ids are different computations
            # and must never share a cached result
            and self.result_cache.get(now, (req.ids, req.cost)) is not None
        ):
            req.t_enqueue = now
            req.stamp("enqueue", now)
            req.stamp("start", now)
            req.stamp("done", now)
            self.monitor.record(now, 0.0)
            # a cached repeat is a completion too: all-zero components,
            # so breakdown.count keeps tracking monitor.completed
            self.breakdown.observe(req, now, t_origin=req.t_enqueue,
                                   stages=[req.stage])
            self.on_complete(now, req, self)
            return True
        if (
            self.limiter is not None
            and not force
            and not self.limiter.admit(now, req.tier, cost=req.cost)
        ):
            self.shed += 1
            return False
        req.t_enqueue = now
        req.stamp("enqueue", now)
        if self.cfg.priority_bypass and req.priority:
            self._dispatch(now, [req])
            return True
        self.queue.append(req)
        self.queued_cost += req.cost
        if self._batch_full():
            self._flush(now)
        elif self._batch_deadline is None:
            self._arm(now + self.cfg.max_wait_s)
        return True

    def item_cap(self) -> Optional[int]:
        """The pool's EFFECTIVE max_batch_items: the BatchSizeController's
        live cap when SLO-aware batch sizing is on, else the static
        configured value (None = no item budget)."""
        if self.controller is not None:
            return self.controller.cap
        return self.cfg.max_batch_items

    def _batch_full(self) -> bool:
        cap = self.item_cap()
        return len(self.queue) >= self.cfg.max_batch or (
            cap is not None and self.queued_cost >= cap
        )

    def _arm(self, deadline: float) -> None:
        self._batch_deadline = deadline
        self.loop.push(deadline, f"batch_timeout:{self.event_key}")

    def _next_batch(self) -> List[Request]:
        """Pop the next batch off the queue head: up to max_batch requests
        AND (when item batching is on) max_batch_items work items. A single
        request larger than the item budget still dispatches — alone."""
        cap = self.item_cap()
        k = 0  # split index, then one slice-delete: O(queue) per batch
        items = 0
        while k < len(self.queue) and k < self.cfg.max_batch:
            nxt = self.queue[k]
            if k and cap is not None and items + nxt.cost > cap:
                break
            items += nxt.cost
            k += 1
        take = self.queue[:k]
        del self.queue[:k]
        self.queued_cost -= items
        return take

    def _dispatch(self, now: float, take: List[Request]) -> None:
        rep = self.picker(self, now)
        items = sum(r.cost for r in take)
        # miss hierarchy: each request's embedding ids run through the
        # pool's L1 in queue order (deterministic); L1 misses probe the
        # cell-shared L2; what both miss is fetched from the shard tier in
        # ONE batched call (one RTT per remote shard touched). A pool with
        # no cache sends every row down — the memory-bound baseline. With
        # no L2 and no shard, miss_rows stays the plain int of the
        # single-tier model, bit-identical to pre-shard behaviour.
        id_rows = 0
        below_l1: List = []  # rows the L1 missed, in access order
        for r in take:
            if r.ids:
                id_rows += len(r.ids)
                if self.embed_cache is not None:
                    below_l1.extend(self.embed_cache.lookup_misses(r.ids)[1])
                else:
                    below_l1.extend(r.ids)
        l2_hits = 0
        if self.l2_cache is not None and below_l1:
            l2_hits, below_l1 = self.l2_cache.lookup_misses(below_l1)
        if self.shard is not None:
            prof = self.shard.fetch(self.cell, below_l1)
            miss_rows: "int | MissProfile" = dataclasses.replace(
                prof, l2_hits=l2_hits)
        elif self.l2_cache is not None:
            miss_rows = MissProfile(l2_hits=l2_hits, local_rows=len(below_l1))
        else:
            miss_rows = len(below_l1)
        if items > 0:
            self._rows_per_item.update(id_rows / items)
        start, done = rep.start_batch(now, items, miss_rows)
        # service-phase boundaries for attribution/tracing: cumulative
        # stamps in the order service_time charges the clock (dense ->
        # local fetch -> remote fetch -> shard transit), clamped at the
        # batch's done so float dust from re-deriving the phases never
        # pushes a boundary past the completion stamp. Zero phases stamp
        # nothing (decompose falls back to the previous boundary).
        bounds = []
        t = start
        for key, dur in zip(
            ("compute_done", "fetch_local_done",
             "fetch_remote_done", "service_done"),
            service_phases(self.spec, items, miss_rows),
        ):
            if dur > 0.0:
                t = min(t + dur, done)
                bounds.append((key, t))
        for r in take:
            r.stamp("dispatch", now)
            r.stamp("start", start)
            for key, bt in bounds:
                r.stamp(key, bt)
        if self.tracer is not None and any(
                self.tracer.sampled(r.rid) for r in take):
            self.tracer.record_batch(self.cell, self.name, rep.rid,
                                     start, done, items, len(take))
        # the payload carries the batch observation (items, miss rows,
        # service start) so batch_done can feed the online latency model
        # the MEASURED service time without re-deriving the batch shape
        self.loop.push(done, f"batch_done:{self.event_key}",
                       (rep.rid, take, items, miss_rows, start))

    def _flush(self, now: float) -> None:
        while self.queue:
            self._dispatch(now, self._next_batch())
            if not self._batch_full():
                break
        if self.queue:
            # partial remainder waits for more arrivals, but only until the
            # OLDEST queued request has been waiting max_wait — re-arming
            # from `now` would let it wait up to 2x max_wait across closes
            self._arm(max(now, self.queue[0].t_enqueue + self.cfg.max_wait_s))
        else:
            self._batch_deadline = None

    def _handle_timeout(self, now: float, _payload) -> None:
        if self._batch_deadline is not None and now >= self._batch_deadline and self.queue:
            self._flush(now)

    def _handle_done(self, now: float, payload) -> None:
        rep_id, take, items, miss_rows, started = payload
        self._registry[rep_id].in_flight -= 1
        if self.model is not None:
            # one observation per completed batch: the measured service
            # seconds against the offline prediction for this batch shape
            self.model.observe(items, miss_rows, now - started)
        for r in take:
            r.stamp("done", now)
            self.monitor.record(now, now - r.t_enqueue)
            # stage-local attribution: the same decomposition the engine
            # applies end-to-end, with this stage's enqueue as origin —
            # the component sum reproduces the monitor's latency bit-exactly
            self.breakdown.observe(r, now, t_origin=r.t_enqueue,
                                   stages=[r.stage])
            if self.tracer is not None and self.tracer.sampled(r.rid):
                self.tracer.record_stage(r, self.cell, self.name, now)
            if self.result_cache is not None and r.stage == 0 and r.ids is not None:
                # freshly computed scores become servable repeats
                self.result_cache.put(now, (r.ids, r.cost))
            self.on_complete(now, r, self)

    # ---- scaling ----
    def utilisation(self, now: float, horizon: float) -> float:
        # booting replicas are excluded — counting them as busy makes the
        # scaler chase its own pending capacity (observed 25-replica
        # overshoot under cold starts)
        ready = [r for r in self.replicas if r.ready_at <= now]
        if not ready:
            return 1.0
        busy = sum(min(max(r.busy_until - now, 0.0), horizon) for r in ready)
        return busy / (horizon * len(ready))

    def scale_tick(self, now: float, tick_s: float) -> None:
        stats = self.monitor.percentiles(now)
        if self.limiter is not None and self.monitor.slo_s is not None:
            # pool-local shedding reacts to the pool's OWN stage latency,
            # not the fleet-wide end-to-end signal
            self.limiter.adapt(stats["p99"], self.monitor.slo_s)
        if self.controller is not None and self.monitor.slo_s is not None:
            # SLO-aware batch sizing: same per-pool windowed p99 signal
            # the limiter adapts from — breach narrows the effective item
            # cap (latency), headroom widens it (throughput)
            self.controller.tick(stats["p99"], self.monitor.slo_s)
        if self.cfg.autoscale:
            util = self.utilisation(now, tick_s)
            want = self.scaler.desired(now, len(self.replicas), util)
            grow = want - len(self.replicas)
            if grow > 0:
                if self.budget is not None:
                    grow = self.budget.acquire(grow)
                for _ in range(grow):
                    delay = self.scaler.take_start_delay(
                        self.spec.warm_start_s, self.spec.cold_start_s
                    )
                    rep = Replica(next(self._rid), self.spec, ready_at=now + delay)
                    self.replicas.append(rep)
                    self._registry[rep.rid] = rep
            elif grow < 0:
                # graceful scale-down: retire only drained replicas
                idle = [r for r in self.replicas if r.in_flight == 0 and r.busy_until <= now]
                while want < len(self.replicas) and len(self.replicas) > 1 and idle:
                    victim = idle.pop()
                    self.replicas.remove(victim)
                    self.scaler.replenish()
                    if self.budget is not None:
                        self.budget.release(1)
        self.trace.append(
            now, len(self.replicas), len(self.queue), stats["p99"],
            self.hit_rate(),
            # control-plane visibility: 0.0 = no item cap in force
            float(self.item_cap() or 0),
            self.model.correction if self.model is not None else 1.0,
        )

    # ---- reporting ----
    def cache_summary(self) -> Dict:
        """Cache counters in one flat dict (zeros when no cache is
        configured, so fleet rollups can sum unconditionally)."""
        out = {"policy": None, "hits": 0, "misses": 0, "hit_rate": 0.0,
               "evictions": 0, "result_hits": 0, "staleness": 0,
               "invalidated": 0}
        if self.embed_cache is not None:
            s = self.embed_cache.stats()
            out.update({k: s[k] for k in ("policy", "hits", "misses",
                                          "hit_rate", "evictions",
                                          "staleness", "invalidated")})
        if self.result_cache is not None:
            out["result_hits"] = self.result_cache.hits
        return out

    def control_summary(self) -> Dict:
        """Control-plane counters in one flat dict (identity values when
        no control is configured, so fleet rollups work unconditionally):
        the learned latency correction + sample count and the effective
        item cap (0 = uncapped). Tagged with the pool's platform class:
        corrections are learned PER POOL and a pool serves one platform,
        so the fleet rollup (metrics.fleet_control_rollup) can keep
        per-class means instead of blending a CPU fleet's drift into an
        accelerator fleet's."""
        return {
            "platform": self.spec.platform,
            "online_latency": self.model is not None,
            "latency_correction": (
                self.model.correction if self.model is not None else 1.0),
            "fetch_correction": (
                self.model.fetch_correction if self.model is not None else 1.0),
            "samples": self.model.samples if self.model is not None else 0,
            "adaptive_batch": self.controller is not None,
            "max_batch_items": int(self.item_cap() or 0),
        }

    def summary(self) -> Dict:
        tot = self.monitor.totals()
        return {
            "variant": self.spec.variant,
            "platform": self.spec.platform,
            "completed": self.monitor.completed,
            "shed": self.shed,
            "p50": tot["p50"],
            "p99": tot["p99"],
            "mean": tot["mean"],
            "slo_attainment": tot["attainment"],
            "final_replicas": len(self.replicas),
            "max_replicas": (
                int(self.trace.column("replicas").max())
                if len(self.trace) else len(self.replicas)
            ),
            "served_items": sum(r.served for r in self._registry.values()),
            "cache": self.cache_summary(),
            "control": self.control_summary(),
            "latency_breakdown": self.breakdown.summary(),
            "trace": self.trace.as_dict(),
        }
