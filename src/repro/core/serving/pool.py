"""Replica pools: each pool owns the replicas of ONE Table-I variant,
with its own batcher (max_batch / max_wait), its own AutoScaler and its
own SLOMonitor. Pools plug into a shared EventLoop; the router decides
which pool a request enters, the pool decides how it is batched and
which replica serves it (via a pluggable replica picker).

Scaling is per-pool but capacity is fleet-wide: every grow request goes
through the shared CapacityBudget, so heterogeneous pools compete for
the same accelerators instead of each assuming it owns the cluster.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional

from repro.core.serving.autoscaler import AutoScaler, CapacityBudget, ScalerConfig
from repro.core.serving.events import EventLoop
from repro.core.serving.metrics import SLOMonitor
from repro.core.serving.replica import Replica, ReplicaSpec


@dataclasses.dataclass
class Request:
    rid: int
    t_arrive: float
    tier: str
    priority: bool = False
    cost: int = 1  # work items carried (e.g. candidates to score)
    stage: int = 0  # 0 = single-stage; 1, 2, ... = cascade stages
    t_enqueue: float = 0.0  # when it entered the current pool
    timeline: Dict[str, float] = dataclasses.field(default_factory=dict)

    def stamp(self, event: str, t: float) -> None:
        self.timeline[f"s{max(self.stage, 1)}_{event}"] = t


@dataclasses.dataclass
class PoolConfig:
    max_batch: int = 64  # batch closes at this many requests...
    max_wait_s: float = 0.005  # ...or when the oldest has waited this long
    n_replicas: int = 2
    autoscale: bool = True
    priority_bypass: bool = True


class ReplicaPool:
    def __init__(
        self,
        name: str,
        spec: ReplicaSpec,
        cfg: PoolConfig,
        loop: EventLoop,
        *,
        scaler_cfg: Optional[ScalerConfig] = None,
        budget: Optional[CapacityBudget] = None,
        on_complete: Optional[Callable[[float, Request, "ReplicaPool"], None]] = None,
        slo_s: Optional[float] = None,
        picker: Optional[Callable[["ReplicaPool", float], Replica]] = None,
    ):
        self.name = name
        self.spec = spec
        self.cfg = cfg
        self.loop = loop
        self.scaler = AutoScaler(scaler_cfg or ScalerConfig(min_replicas=cfg.n_replicas))
        self.budget = budget
        self.on_complete = on_complete or (lambda now, req, pool: None)
        self.monitor = SLOMonitor(slo_s=slo_s)
        self.picker = picker or (lambda pool, now: min(pool.replicas, key=lambda r: r.load(now)))

        if budget is not None and budget.acquire(cfg.n_replicas) < cfg.n_replicas:
            raise ValueError(
                f"capacity budget exhausted bringing up pool {name!r} "
                f"({cfg.n_replicas} initial replicas, {budget.available} left)"
            )
        self.replicas: List[Replica] = [
            Replica(i, spec, ready_at=0.0) for i in range(cfg.n_replicas)
        ]
        self._registry: Dict[int, Replica] = {r.rid: r for r in self.replicas}
        self._rid = itertools.count(len(self.replicas))

        self.queue: List[Request] = []
        self.queued_cost = 0  # running sum of queue costs (O(1) router signal)
        self._batch_deadline: Optional[float] = None
        self.trace: Dict[str, List[float]] = {"t": [], "replicas": [], "queue": [], "p99": []}

        loop.on(f"batch_timeout:{name}", self._handle_timeout)
        loop.on(f"batch_done:{name}", self._handle_done)

    # ---- routing signals ----
    def predicted_latency(self, now: float, cost: int = 1) -> float:
        """Router signal: wait for the freest replica + service time of the
        backlog this request would join."""
        ready = [r for r in self.replicas if r.ready_at <= now] or self.replicas
        wait = min(r.load(now) for r in ready)
        return wait + self.spec.latency(self.queued_cost + cost)

    def recent_p99(self, now: float) -> float:
        return self.monitor.percentiles(now)["p99"]

    # ---- admission / batching ----
    def submit(self, now: float, req: Request) -> None:
        req.t_enqueue = now
        req.stamp("enqueue", now)
        if self.cfg.priority_bypass and req.priority:
            self._dispatch(now, [req])
            return
        self.queue.append(req)
        self.queued_cost += req.cost
        if len(self.queue) >= self.cfg.max_batch:
            self._flush(now)
        elif self._batch_deadline is None:
            self._batch_deadline = now + self.cfg.max_wait_s
            self.loop.push(self._batch_deadline, f"batch_timeout:{self.name}")

    def _dispatch(self, now: float, take: List[Request]) -> None:
        rep = self.picker(self, now)
        items = sum(r.cost for r in take)
        start, done = rep.start_batch(now, items)
        for r in take:
            r.stamp("start", start)
        self.loop.push(done, f"batch_done:{self.name}", (rep.rid, take))

    def _flush(self, now: float) -> None:
        while self.queue:
            take = self.queue[: self.cfg.max_batch]
            del self.queue[: self.cfg.max_batch]
            self.queued_cost -= sum(r.cost for r in take)
            self._dispatch(now, take)
            if len(self.queue) < self.cfg.max_batch:
                break
        if self.queue:
            # partial remainder waits (at most max_wait) for more arrivals —
            # re-arm the deadline so it always drains even if traffic stops
            self._batch_deadline = now + self.cfg.max_wait_s
            self.loop.push(self._batch_deadline, f"batch_timeout:{self.name}")
        else:
            self._batch_deadline = None

    def _handle_timeout(self, now: float, _payload) -> None:
        if self._batch_deadline is not None and now >= self._batch_deadline and self.queue:
            self._flush(now)

    def _handle_done(self, now: float, payload) -> None:
        rep_id, take = payload
        self._registry[rep_id].in_flight -= 1
        for r in take:
            r.stamp("done", now)
            self.monitor.record(now, now - r.t_enqueue)
            self.on_complete(now, r, self)

    # ---- scaling ----
    def utilisation(self, now: float, horizon: float) -> float:
        # booting replicas are excluded — counting them as busy makes the
        # scaler chase its own pending capacity (observed 25-replica
        # overshoot under cold starts)
        ready = [r for r in self.replicas if r.ready_at <= now]
        if not ready:
            return 1.0
        busy = sum(min(max(r.busy_until - now, 0.0), horizon) for r in ready)
        return busy / (horizon * len(ready))

    def scale_tick(self, now: float, tick_s: float) -> None:
        stats = self.monitor.percentiles(now)
        if self.cfg.autoscale:
            util = self.utilisation(now, tick_s)
            want = self.scaler.desired(now, len(self.replicas), util)
            grow = want - len(self.replicas)
            if grow > 0:
                if self.budget is not None:
                    grow = self.budget.acquire(grow)
                for _ in range(grow):
                    delay = self.scaler.take_start_delay(
                        self.spec.warm_start_s, self.spec.cold_start_s
                    )
                    rep = Replica(next(self._rid), self.spec, ready_at=now + delay)
                    self.replicas.append(rep)
                    self._registry[rep.rid] = rep
            elif grow < 0:
                # graceful scale-down: retire only drained replicas
                idle = [r for r in self.replicas if r.in_flight == 0 and r.busy_until <= now]
                while want < len(self.replicas) and len(self.replicas) > 1 and idle:
                    victim = idle.pop()
                    self.replicas.remove(victim)
                    self.scaler.replenish()
                    if self.budget is not None:
                        self.budget.release(1)
        self.trace["t"].append(now)
        self.trace["replicas"].append(len(self.replicas))
        self.trace["queue"].append(len(self.queue))
        self.trace["p99"].append(stats["p99"])

    # ---- reporting ----
    def summary(self) -> Dict:
        tot = self.monitor.totals()
        return {
            "variant": self.spec.variant,
            "completed": self.monitor.completed,
            "p50": tot["p50"],
            "p99": tot["p99"],
            "mean": tot["mean"],
            "slo_attainment": tot["attainment"],
            "final_replicas": len(self.replicas),
            "max_replicas": max(self.trace["replicas"], default=len(self.replicas)),
            "served_items": sum(r.served for r in self._registry.values()),
            "trace": self.trace,
        }
