"""Target-utilisation autoscaler + warm pool (paper §IV.B / k8s HPA style).

Post-refactor each ReplicaPool owns its own AutoScaler; a CapacityBudget
shared across pools caps the fleet-wide replica count so one pool scaling
up spends headroom the others can no longer claim (heterogeneous pools
compete for the same accelerators).

Budgets nest: a cell-local budget may point at a `parent` budget (the
global fleet cap in a multi-cell federation — see serving/federation.py).
A grant must then clear BOTH levels: a cell can never exceed its own
budget, and the sum of all cells can never exceed the parent's, so cells
stay independent until the global cap actually binds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ScalerConfig:
    min_replicas: int = 1
    max_replicas: int = 32
    target_util: float = 0.6
    scale_up_cooldown_s: float = 2.0
    scale_down_cooldown_s: float = 15.0
    warm_pool_size: int = 2


@dataclasses.dataclass
class CapacityBudget:
    """Replica budget shared by every pool's autoscaler. With a `parent`,
    this is one cell's slice of a global cap: acquire() grants only what
    both this budget AND the parent can cover."""

    total: int
    used: int = 0
    parent: Optional["CapacityBudget"] = None

    def acquire(self, n: int) -> int:
        """Grant up to n replicas' worth of capacity; returns the grant."""
        grant = max(0, min(n, self.total - self.used))
        if grant and self.parent is not None:
            grant = self.parent.acquire(grant)
        self.used += grant
        return grant

    def release(self, n: int) -> None:
        freed = min(n, self.used)
        self.used -= freed
        if freed and self.parent is not None:
            self.parent.release(freed)

    @property
    def available(self) -> int:
        mine = self.total - self.used
        if self.parent is not None:
            return min(mine, self.parent.available)
        return mine


class AutoScaler:
    """Decides the desired replica count from observed utilisation.
    Replicas spawned from the warm pool become ready in warm_start_s,
    beyond-pool spawns pay cold_start_s (the warm pool then replenishes)."""

    def __init__(self, cfg: ScalerConfig):
        self.cfg = cfg
        self.last_up = -1e9
        self.last_down = -1e9
        self.warm_available = cfg.warm_pool_size

    def desired(self, now: float, n_active: int, utilisation: float) -> int:
        want = n_active
        if utilisation > self.cfg.target_util and now - self.last_up >= self.cfg.scale_up_cooldown_s:
            # classic HPA formula: ceil(n * util / target)
            want = min(
                self.cfg.max_replicas,
                max(n_active + 1, int(n_active * utilisation / self.cfg.target_util + 0.999)),
            )
            if want > n_active:
                self.last_up = now
        elif utilisation < 0.3 * self.cfg.target_util and now - self.last_down >= self.cfg.scale_down_cooldown_s:
            want = max(self.cfg.min_replicas, n_active - 1)
            if want < n_active:
                self.last_down = now
        return want

    def take_start_delay(self, warm_start_s: float, cold_start_s: float) -> float:
        """Start latency for one new replica; consumes warm pool if available."""
        if self.warm_available > 0:
            self.warm_available -= 1
            return warm_start_s
        return cold_start_s

    def replenish(self):
        if self.warm_available < self.cfg.warm_pool_size:
            self.warm_available += 1
