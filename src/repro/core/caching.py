"""Real-array hot-row embedding cache: the system-side counterpart of
the simulator caches in core/serving/cache.py.

Embedding lookups dominate recommendation inference and their ID
popularity is heavily Zipf-skewed, so a small RESIDENT table of hot rows
(VMEM/L2-sized) serves most of the traffic while the full table stays in
slow memory. This module builds that resident tier and a cached
`embedding_bag` lookup path over it:

    hot_ids                deterministic top-k hot IDs of a stream
                           (frequency desc, id asc tie-break)
    build_resident_table   copy the hot rows out of the full table and
                           invert them into a [V] slot map (-1 = miss)
    residency_mask         per-lookup hit mask (measured hit-rate)
    cached_embedding_bag   residency-masked gather: hit rows come from
                           the small resident table, miss rows fall back
                           to the full table, then the SAME flat-gather +
                           segment_sum reduce as the system path — so the
                           output matches kernels/embedding_bag/ref.py
                           EXACTLY (bitwise) on resident and non-resident
                           ids alike (tests/test_kernels.py pins this)

The full table may be fp32 dense or the C5 int8-quantized layout
({"q": int8 [V,d], "s": f32 [V]}); resident rows are stored dequantized
(fp32), which is exactly what a real serving cache does — pay the
dequantize once at admission, not per lookup.

The simulator's `ReplicaSpec.embed_fetch_s` charges service time per
MISSED row; this module is where those misses correspond to real
gathers. Capacity here is rows, matching CacheConfig.capacity_rows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recsys.embedding import _take_rows


def hot_ids(ids: np.ndarray, capacity: int) -> np.ndarray:
    """The `capacity` hottest IDs of a stream, deterministically: sorted
    by (frequency desc, id asc), so equal-frequency ties never depend on
    hash or encounter order. Fewer unique ids than capacity returns them
    all."""
    uniq, counts = np.unique(np.asarray(ids).reshape(-1), return_counts=True)
    order = np.lexsort((uniq, -counts))  # freq desc, id asc within ties
    return uniq[order[: int(capacity)]].astype(np.int64)


@dataclasses.dataclass
class ResidentTable:
    """The hot tier: `rows` [C, d] fp32 copies of the hot embedding rows,
    `slot_of` [V] int32 mapping id -> resident slot (-1 = not resident)."""

    rows: jax.Array
    slot_of: jax.Array

    @property
    def n_resident(self) -> int:
        return int(self.rows.shape[0])


def build_resident_table(
    table: Union[jax.Array, dict], resident_ids: np.ndarray, vocab: Optional[int] = None
) -> ResidentTable:
    """Copy `resident_ids` rows out of the full table (dequantizing the
    int8 layout once, at admission) and build the inverse slot map."""
    ids = jnp.asarray(np.asarray(resident_ids, np.int64))
    rows = _take_rows(table, ids)
    if vocab is None:
        vocab = table["q"].shape[0] if isinstance(table, dict) else table.shape[0]
    slot_of = jnp.full((vocab,), -1, jnp.int32).at[ids].set(
        jnp.arange(ids.shape[0], dtype=jnp.int32)
    )
    return ResidentTable(rows=rows, slot_of=slot_of)


def residency_mask(resident: ResidentTable, idx: jax.Array) -> jax.Array:
    """Boolean hit mask for a lookup batch; `.mean()` is the measured
    hit-rate the simulator's EmbeddingCache models."""
    return resident.slot_of[idx] >= 0


def cached_embedding_bag(
    table: Union[jax.Array, dict],
    resident: ResidentTable,
    idx: jax.Array,
    mask: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """EmbeddingBag through the resident tier: rows whose id is resident
    gather from the small table, the rest fall back to the full table.

    table: [V, d] (or int8 dict layout); idx: [B, nnz] int; mask: [B, nnz]
    (1 = valid). Row selection happens BEFORE the reduce, and the reduce is
    the same flat-gather + segment_sum as models/recsys/embedding.py's
    embedding_bag — resident rows are exact copies, so the output is
    bitwise identical to the uncached reference for any hit/miss mix.
    """
    B, nnz = idx.shape
    flat_idx = idx.reshape(-1)
    miss_rows = _take_rows(table, flat_idx)  # the slow-tier fallback fetch
    if resident.n_resident == 0:  # degenerate empty tier: everything misses
        flat = miss_rows
    else:
        slot = resident.slot_of[flat_idx]
        hit = slot >= 0
        hit_rows = jnp.take(resident.rows, jnp.maximum(slot, 0), axis=0)
        flat = jnp.where(hit[:, None], hit_rows, miss_rows)
    if mask is not None:
        flat = flat * mask.reshape(-1, 1).astype(flat.dtype)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), nnz)
    out = jax.ops.segment_sum(flat, seg, num_segments=B)
    if combiner == "mean":
        denom = (
            jnp.clip(mask.sum(axis=1), 1)[:, None].astype(out.dtype)
            if mask is not None
            else jnp.full((B, 1), nnz, out.dtype)
        )
        out = out / denom
    return out
