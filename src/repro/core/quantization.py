"""C5 — dynamic-range quantization + QAT (paper §III.B, Formulas 8-9).

Stage 2 of the paper's closed loop:
  s = (max W − min W) / (2^{b−1} − 1)                      (Formula 8)
  ŵ = clip(round(w/s)·s, min W, max W)                     (Formula 9)
  QAT: fake-quant nodes in the forward pass, straight-through gradients.

Storage representations (dispatched by core/lightweight.py):
  weights  -> {"q": int8 [din,dout], "s": f32 [dout]}  per-output-channel
  tables   -> {"q": int8 [V,d],      "s": f32 [V]}     per-row (gather-then-
              dequantize: 4x less HBM traffic on the embedding hot path)
The int8 x int8 -> int32 MXU kernel lives in kernels/int8_matmul.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def dynamic_range_step(w: jax.Array, bits: int = 8) -> jax.Array:
    """Formula 8 step size over the whole tensor."""
    return (jnp.max(w) - jnp.min(w)) / (2.0 ** (bits - 1) - 1.0)


def fake_quant(w: jax.Array, bits: int = 8) -> jax.Array:
    """Formula 9: quantize-dequantize (float in, float out)."""
    s = jnp.maximum(dynamic_range_step(w, bits), 1e-12)
    return jnp.clip(jnp.round(w / s) * s, jnp.min(w), jnp.max(w))


def ste_quant(w: jax.Array, bits: int = 8) -> jax.Array:
    """QAT node: fake-quant forward, identity (straight-through) backward."""
    return w + jax.lax.stop_gradient(fake_quant(w, bits) - w)


def quantize_weight(w: jax.Array, bits: int = 8) -> dict:
    """Per-output-channel symmetric int8 rep {"q", "s"}."""
    assert bits == 8, "int8 storage path (other widths use fake_quant)"
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0) / 127.0, 1e-12)  # [dout]
    q = jnp.clip(jnp.round(w / s[None, :]), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.float32)}


def quantize_table(t: jax.Array) -> dict:
    """Per-row int8 rep for embedding tables."""
    s = jnp.maximum(jnp.max(jnp.abs(t), axis=1) / 127.0, 1e-12)  # [V]
    q = jnp.clip(jnp.round(t / s[:, None]), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.float32)}


def dequantize(rep: dict) -> jax.Array:
    if rep["s"].ndim == 1 and rep["q"].shape[0] == rep["s"].shape[0]:
        return rep["q"].astype(jnp.float32) * rep["s"][:, None]
    return rep["q"].astype(jnp.float32) * rep["s"][None, :]


def _path_keys(path):
    return [getattr(k, "key", getattr(k, "name", str(k))) for k in path]


def _is_table_path(path) -> bool:
    return any(k in ("tables", "table", "linear", "embed") for k in _path_keys(path))


# arrays used positionally by models (not through the linear dispatch)
_QUANT_EXCLUDE = ("pos",)


def quantize_tree(params, *, tables: bool = True, weights: bool = True):
    """Whole-model post-training quantization. Masked reps keep their mask
    ({"q","s","mask"} = pruned+quantized, the paper's combined variant)."""

    def visit(path, leaf):
        if isinstance(leaf, dict) and "w" in leaf and "mask" in leaf:
            if not weights:
                return leaf
            rep = quantize_weight(leaf["w"] * leaf["mask"])
            rep["mask"] = leaf["mask"]
            return rep
        if not isinstance(leaf, jax.Array) or not jnp.issubdtype(
            leaf.dtype, jnp.floating
        ):
            return leaf
        if leaf.ndim != 2 or any(k in _QUANT_EXCLUDE for k in _path_keys(path)):
            return leaf
        if _is_table_path(path):
            return quantize_table(leaf) if tables else leaf
        return quantize_weight(leaf) if weights else leaf

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, dict) and ("w" in x or "q" in x)
    )


def qat_params(params, bits: int = 8):
    """Insert STE fake-quant on every 2-D float weight (Formula 9 forward,
    full-precision backward). Call inside the loss: loss(qat_params(p), ...)."""

    def visit(path, leaf):
        if isinstance(leaf, dict) and "w" in leaf and "mask" in leaf:
            return {"w": ste_quant(leaf["w"] * leaf["mask"], bits), "mask": leaf["mask"]}
        if (
            isinstance(leaf, jax.Array)
            and leaf.ndim == 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            return ste_quant(leaf, bits)
        return leaf

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, dict) and "mask" in x
    )


def model_bytes(params) -> int:
    """Fig-7 storage accounting across representations."""
    from repro.core.lightweight import nbytes

    total = 0
    for leaf in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, dict) and ("q" in x or "w" in x or "a" in x or "gw" in x)
    ):
        if isinstance(leaf, dict) or (hasattr(leaf, "size") and hasattr(leaf, "dtype")):
            try:
                total += nbytes(leaf)
            except ValueError:
                total += sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(leaf))
    return total
