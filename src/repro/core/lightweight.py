"""C1 — lightweight projection representations (paper §III.A).

Every compressible linear in the framework is a *representation-dispatched*
apply: the parameter leaf decides the compute path. The compression passes
(core/pruning.py, core/quantization.py, core/compression_loop.py) transform
parameter trees between representations; model code never changes.

Representations:
  dense      : jnp.ndarray [d_in, d_out]
  masked     : {"w": [d_in,d_out], "mask": same}          (C4 pruning)
  lowrank    : {"a": [d_in,r], "b": [r,d_out]}            (C1 low-rank heads)
  grouped    : {"gw": [k, d_in/k, d_out/k]}               (C1 grouped linear)
  dwsep      : {"dw": [3, d_in], "pw": [d_in, d_out]}     (C1 depthwise-separable,
                sequence inputs only)
  int8       : {"q": int8 [d_in,d_out], "s": f32 [d_out]} (C5 dynamic-range quant,
                per-output-channel scale)
  int8 + mask: {"q","s","mask"}                           (C4+C5 combined)
"""
from __future__ import annotations

from typing import Dict, Union

import jax
import jax.numpy as jnp

Rep = Union[jax.Array, Dict[str, jax.Array]]


def linear(p: Rep, x: jax.Array) -> jax.Array:
    """Apply a compressible linear on the last axis of x."""
    if isinstance(p, (jax.Array, jnp.ndarray)) or not isinstance(p, dict):
        return x @ p
    if "q" in p:  # int8 dynamic-range weights
        w = p["q"].astype(jnp.float32) * p["s"][None, :]
        if "mask" in p:
            w = w * p["mask"]
        return (x.astype(jnp.float32) @ w).astype(x.dtype)
    if "mask" in p:
        return x @ (p["w"] * p["mask"])
    if "a" in p:  # low-rank
        return (x @ p["a"]) @ p["b"]
    if "gw" in p:  # grouped
        k, gin, gout = p["gw"].shape
        xg = x.reshape(x.shape[:-1] + (k, gin))
        out = jnp.einsum("...ki,kio->...ko", xg, p["gw"])
        return out.reshape(x.shape[:-1] + (k * gout,))
    if "dw" in p:  # depthwise(3) over seq + pointwise
        dw, pw = p["dw"], p["pw"]
        pad = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (0, 0)])
        y = (
            pad[..., :-2, :] * dw[0]
            + pad[..., 1:-1, :] * dw[1]
            + pad[..., 2:, :] * dw[2]
        )
        return y @ pw
    raise ValueError(f"unknown linear representation: {list(p.keys())}")


def weight_view(p: Rep) -> jax.Array:
    """Effective dense [d_in, d_out] weight of any representation (for
    analysis, distillation init, and test oracles)."""
    if not isinstance(p, dict):
        return p
    if "q" in p:
        w = p["q"].astype(jnp.float32) * p["s"][None, :]
        return w * p["mask"] if "mask" in p else w
    if "mask" in p:
        return p["w"] * p["mask"]
    if "a" in p:
        return p["a"] @ p["b"]
    if "gw" in p:
        k, gin, gout = p["gw"].shape
        blocks = [
            jnp.pad(p["gw"][i], ((0, 0), (i * gout, (k - 1 - i) * gout)))
            for i in range(k)
        ]
        return jnp.concatenate(blocks, axis=0)
    raise ValueError(f"no dense view for: {list(p.keys())}")


def nbytes(p: Rep) -> int:
    """Storage footprint of a representation (paper Fig. 7 resource accounting).
    Masked weights count only surviving entries (sparse storage)."""
    if not isinstance(p, dict):
        return p.size * p.dtype.itemsize
    if "q" in p:
        base = p["q"].size * 1 + p["s"].size * 4
        if "mask" in p:
            nz = int(jnp.sum(p["mask"]))
            base = nz * 1 + p["s"].size * 4  # paper accounting: survivors only
        return base
    if "mask" in p:
        nz = int(jnp.sum(p["mask"]))
        return nz * 4  # paper's Table-I accounting: surviving params x 4B
    return sum(v.size * v.dtype.itemsize for v in p.values())


def low_rank_factorize(w: jax.Array, rank: int):
    """SVD truncation of a dense weight -> lowrank rep (C1)."""
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    r = min(rank, s.shape[0])
    a = u[:, :r] * s[None, :r]
    return {"a": a.astype(w.dtype), "b": vt[:r].astype(w.dtype)}


def to_grouped(w: jax.Array, k: int):
    """Keep only the block-diagonal groups of a dense weight (C1 grouped
    linear). Used at *construction* time for student models — information
    off the diagonal is discarded by design."""
    d_in, d_out = w.shape
    assert d_in % k == 0 and d_out % k == 0
    gin, gout = d_in // k, d_out // k
    blocks = [w[i * gin : (i + 1) * gin, i * gout : (i + 1) * gout] for i in range(k)]
    return {"gw": jnp.stack(blocks)}
