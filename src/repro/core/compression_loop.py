"""The paper's closed loop (§III.B Fig 3 + §V ladder): prune → fine-tune →
quantize → QAT, plus the distilled student — producing the five Table-I
variants of any recsys model:

  baseline / quantized / pruned / pruned_quantized / distilled

Each variant is a parameter tree in the representations of
core/lightweight.py; the SAME model code serves all five.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.core import distillation, pruning, quantization
from repro.models.recsys import api as rec_api
from repro.training.optimizer import get_optimizer
from repro.training.train_loop import make_train_step


@dataclasses.dataclass
class LadderConfig:
    prune_target: float = 0.4  # paper: ≈40% params removed
    prune_rounds: int = 3  # paper: K = 3
    structured: bool = False  # block-structured variant (TPU-fast path)
    block: int = 128
    finetune_steps: int = 30
    qat_steps: int = 30
    distill_steps: int = 60
    lr: float = 1e-3


def _is_masked(x) -> bool:
    return isinstance(x, dict) and "mask" in x and "w" in x


def _restore_masks(params, ref):
    """Masks are constants of the pruning stage — never optimizer state."""
    return jax.tree.map(
        lambda p, r: {"w": p["w"], "mask": r["mask"]} if _is_masked(r) else p,
        params, ref, is_leaf=_is_masked,
    )


def _finetune(params, cfg, rules, batches, steps, lr, *, qat=False):
    opt = get_optimizer("adamw", lr)

    def loss_fn(p, b):
        p_eff = quantization.qat_params(p) if qat else p
        return rec_api.loss(p_eff, b, cfg, rules)

    step = make_train_step(loss_fn, opt)
    state = opt.init(params)
    jstep = jax.jit(step)
    ref = params
    for i, b in zip(range(steps), batches):
        params, state, _ = jstep(params, state, b)
        params = _restore_masks(params, ref)
    return params


def run_ladder(
    teacher_params: Dict,
    cfg: RecSysConfig,
    rules,
    batch_fn: Callable[[], Iterator],
    ladder: Optional[LadderConfig] = None,
    *,
    rng=None,
) -> Dict[str, Dict]:
    """Returns {variant: (params, cfg)} for the five Table-I rows."""
    lc = ladder or LadderConfig()
    rng = rng if rng is not None else jax.random.key(0)
    out: Dict[str, Dict] = {}
    out["baseline"] = {"params": teacher_params, "cfg": cfg}

    # ---- Quantized (C5 alone: PTQ of weights + tables) ----
    out["quantized"] = {
        "params": quantization.quantize_tree(teacher_params), "cfg": cfg
    }

    # ---- Pruned (C4: K rounds of dynamic-threshold + fine-tune) ----
    pruned = teacher_params
    for ratio in pruning.prune_schedule(lc.prune_target, lc.prune_rounds):
        pruned = pruning.prune_tree(
            pruned, ratio, structured=lc.structured, block=lc.block
        )
        pruned = _finetune(pruned, cfg, rules, batch_fn(), lc.finetune_steps, lc.lr)
    out["pruned"] = {"params": pruned, "cfg": cfg}

    # ---- Pruned + Quantized (C4 → QAT → int8 storage) ----
    pq = _finetune(pruned, cfg, rules, batch_fn(), lc.qat_steps, lc.lr, qat=True)
    out["pruned_quantized"] = {"params": quantization.quantize_tree(pq), "cfg": cfg}

    # ---- Distilled (C3 + C1 student) ----
    s_cfg = distillation.make_student_cfg(cfg)
    student = distillation.init_student_from_teacher(teacher_params, s_cfg, rng)
    opt = get_optimizer("adamw", lc.lr)

    def d_loss(p, b):
        return distillation.distill_loss(p, teacher_params, b, s_cfg, cfg, rules)

    step = jax.jit(make_train_step(d_loss, opt))
    state = opt.init(student)
    for i, b in zip(range(lc.distill_steps), batch_fn()):
        student, state, m = step(student, state, b)
    out["distilled"] = {"params": student, "cfg": s_cfg}
    return out


def variant_stats(variants: Dict[str, Dict]) -> Dict[str, Dict]:
    """Params / storage / sparsity per variant (Fig 7 accounting)."""
    stats = {}
    for name, v in variants.items():
        p = v["params"]
        n_params = 0
        for leaf in jax.tree.leaves(
            p, is_leaf=lambda x: isinstance(x, dict) and ("q" in x or "w" in x or "a" in x or "gw" in x)
        ):
            if isinstance(leaf, dict):
                if "q" in leaf:
                    n_params += leaf["q"].size
                elif "w" in leaf:
                    n_params += int(jnp.sum(leaf["mask"]))
                elif "a" in leaf:
                    n_params += leaf["a"].size + leaf["b"].size
                elif "gw" in leaf:
                    n_params += leaf["gw"].size
            else:
                n_params += leaf.size
        stats[name] = {
            "params": int(n_params),
            "bytes": quantization.model_bytes(p),
            "sparsity": pruning.sparsity(p),
        }
    return stats
