"""C2 — hybrid sparse attention (paper §III.A, Formula 4).

Full attention inside a local window w ≪ L plus fixed/random global samples:
nonzeros O(L·w) or O(L·log L), compute O(Lwd + L·logL·d). Three consumers:

  * taobao_ssa encoder (`window=` mask in the model),
  * LM long-context decode (models/layers.sparse_decode_attention),
  * the Pallas windowed-attention kernel (kernels/local_attention), whose
    oracle is `windowed_attention` below.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def local_global_mask(
    L: int, window: int, n_global: int = 0, *, causal: bool = False,
    seed: Optional[int] = None,
) -> jax.Array:
    """[L, L] boolean mask: |i−j| < window, plus n_global sampled key
    columns attendable from everywhere (fixed strided pattern by default,
    random with a seed — the paper allows either)."""
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    m = jnp.abs(i - j) < window
    if n_global:
        if seed is None:
            cols = jnp.linspace(0, L - 1, n_global).astype(jnp.int32)
        else:
            cols = jax.random.choice(
                jax.random.key(seed), L, (n_global,), replace=False
            )
        m = m | jnp.isin(j, cols)
    if causal:
        m = m & (j <= i)
    return m


def masked_attention(q, k, v, mask) -> jax.Array:
    """Reference dense-masked attention. q,k,v: [B,H,L,dh]; mask [L,L]."""
    dh = q.shape[-1]
    s = jnp.einsum("bhld,bhmd->bhlm", q, k) / math.sqrt(dh)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bhlm,bhmd->bhld", p, v)


def windowed_attention(q, k, v, window: int, *, causal: bool = False) -> jax.Array:
    """Pure local-window attention — oracle for kernels/local_attention.
    q,k,v: [B,H,L,dh]."""
    L = q.shape[2]
    return masked_attention(q, k, v, local_global_mask(L, window, 0, causal=causal))


def hybrid_sparse_attention(
    q, k, v, *, window: int, n_global: int = 0, causal: bool = False,
    seed: Optional[int] = None,
) -> jax.Array:
    """The paper's full C2 pattern (window + sampled globals)."""
    L = q.shape[2]
    mask = local_global_mask(L, window, n_global, causal=causal, seed=seed)
    return masked_attention(q, k, v, mask)


def attention_flops(L: int, d: int, window: int, n_global: int) -> dict:
    """Formula-4 accounting: dense O(L²d) vs sparse O(Lwd + L·ng·d)."""
    dense = 4 * L * L * d
    sparse = 4 * L * (min(window, L) + n_global) * d
    return {"dense": dense, "sparse": sparse, "ratio": sparse / dense}
