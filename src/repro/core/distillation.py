"""C3 — attention-map knowledge distillation (paper §III.A, Formula 3).

L_KD = KL(S_teacher ‖ S_student) over attention maps, plus soft-label
distillation on the task logits. The student is a lighter taobao_ssa:
fewer encoder layers and C1 low-rank/grouped projections, initialized from
the teacher via SVD truncation (core/lightweight.low_rank_factorize).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecSysConfig
from repro.core.lightweight import low_rank_factorize, to_grouped
from repro.models.common import init_params
from repro.models.recsys import taobao_ssa
from repro.models.recsys.rec_layers import bce_with_logits


def make_student_cfg(cfg: RecSysConfig) -> RecSysConfig:
    """~30% fewer parameters: half the encoder depth (paper: 'fewer layers')."""
    return dataclasses.replace(cfg, n_attn_layers=max(1, cfg.n_attn_layers // 2))


def init_student_from_teacher(
    teacher_params: Dict, student_cfg: RecSysConfig, rng, *, rank: int = 16,
    grouped_ffn: int = 4,
) -> Dict:
    """Student params: tables/tower shared-initialized from the teacher;
    encoder projections low-rank factorized (C1) from evenly-spaced teacher
    layers; FFN w1 grouped (C1 grouped linear)."""
    defs = taobao_ssa.param_defs(student_cfg)
    student = init_params(defs, rng)
    # copy shared structure
    for k in ("tables", "pos"):
        student[k] = jax.tree.map(lambda a: a, teacher_params[k])
    for name in list(student.keys()):
        if name.startswith("tower"):
            student[name] = teacher_params[name]
    # layer map: student layer l <- teacher layer floor(l * Lt / Ls)
    lt = sum(1 for k in teacher_params if k.startswith("enc"))
    ls = student_cfg.n_attn_layers
    for l in range(ls):
        tl = (l * lt) // ls
        tenc = teacher_params[f"enc{tl}"]
        senc = dict(tenc)
        for proj in ("wq", "wk", "wv", "wo"):
            senc[proj] = low_rank_factorize(tenc[proj], rank)
        senc["w1"] = to_grouped(tenc["w1"], grouped_ffn)
        student[f"enc{l}"] = senc
    return student


def attention_kl(t_probs, s_probs, eps: float = 1e-9) -> jax.Array:
    """Formula 3: KL(teacher ‖ student), mean over batch/heads/queries.
    Head counts may differ — both are head-averaged first (map-level KD)."""
    tm = jnp.mean(t_probs, axis=1)  # [B, L, L]
    sm = jnp.mean(s_probs, axis=1)
    kl = jnp.sum(tm * (jnp.log(tm + eps) - jnp.log(sm + eps)), axis=-1)
    return jnp.mean(kl)


def distill_loss(
    student_params,
    teacher_params,
    batch,
    student_cfg: RecSysConfig,
    teacher_cfg: RecSysConfig,
    rules,
    *,
    alpha_attn: float = 1.0,
    alpha_soft: float = 0.5,
    temperature: float = 2.0,
) -> Tuple[jax.Array, Dict]:
    t_logits, t_attn = taobao_ssa.logits_and_attn(
        jax.lax.stop_gradient(teacher_params), batch, teacher_cfg, rules,
        collect_attn=True,
    )
    s_logits, s_attn = taobao_ssa.logits_and_attn(
        student_params, batch, student_cfg, rules, collect_attn=True
    )
    task = bce_with_logits(s_logits, batch["label"])

    # student layer l distils teacher layer (l * Lt / Ls) — last maps last
    lt, ls = len(t_attn), len(s_attn)
    kd = jnp.zeros((), jnp.float32)
    for l in range(ls):
        kd += attention_kl(t_attn[min((l * lt) // ls, lt - 1)], s_attn[l])
    kd = kd / max(ls, 1)

    t_soft = jax.nn.sigmoid(jax.lax.stop_gradient(t_logits) / temperature)
    soft = bce_with_logits(s_logits / temperature, t_soft)

    total = task + alpha_attn * kd + alpha_soft * soft
    return total, {"task": task, "attn_kl": kd, "soft": soft}
