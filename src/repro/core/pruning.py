"""C4 — dynamic-threshold iterative pruning (paper §III.B, Formulas 5-7).

Stage 1 of the paper's closed loop:
  θ⁽⁰⁾ from the target ratio p (Formula 5: the p-quantile of |w|),
  M⁽ᵏ⁾ = 1[|w| ≥ θ⁽ᵏ⁾]     (Formula 6),
  W⁽ᵏ⁾ = W⁽ᵏ⁻¹⁾ ⊙ M⁽ᵏ⁾     (Formula 7), fine-tune between rounds.

TPU adaptation (DESIGN.md §5): unstructured masks preserve the paper's
accuracy semantics but do NOT speed up MXU matmuls, so a block-structured
variant prunes (bs x bs) weight blocks by L1 norm — those matmuls skip zero
blocks via kernels/block_pruned_matmul. Both variants share Formula 5-7
semantics (the block score is the block's aggregate magnitude).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def magnitude_threshold(w: jax.Array, p: float) -> jax.Array:
    """Formula 5: θ s.t. |{|w| < θ}| / nm = p (the p-quantile of |w|)."""
    return jnp.quantile(jnp.abs(w).reshape(-1).astype(jnp.float32), p)


def prune_mask(w: jax.Array, p: float) -> jax.Array:
    """Formula 6 mask at the dynamic threshold."""
    theta = magnitude_threshold(w, p)
    return (jnp.abs(w) >= theta).astype(w.dtype)


def block_prune_mask(w: jax.Array, p: float, block: int = 128) -> jax.Array:
    """Structured variant: score (bs x bs) blocks by mean |w|, prune the
    lowest-p fraction of blocks, expand back to elementwise mask."""
    n, m = w.shape
    pn, pm = (-n) % block, (-m) % block
    wp = jnp.pad(jnp.abs(w), ((0, pn), (0, pm)))
    nb, mb = wp.shape[0] // block, wp.shape[1] // block
    scores = wp.reshape(nb, block, mb, block).mean(axis=(1, 3))  # [nb, mb]
    theta = jnp.quantile(scores.reshape(-1), p)
    bmask = (scores >= theta).astype(w.dtype)
    full = jnp.broadcast_to(bmask[:, None, :, None], (nb, block, mb, block))
    return full.reshape(nb * block, mb * block)[:n, :m]


def _prunable(path: Tuple, leaf) -> bool:
    """Default selector: 2-D float weights outside embedding tables."""
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    if any(k in ("tables", "table", "linear", "embed", "lm_head", "pos") for k in keys):
        return False
    return (
        isinstance(leaf, jax.Array)
        and leaf.ndim == 2
        and jnp.issubdtype(leaf.dtype, jnp.floating)
    )


def prune_tree(
    params,
    ratio: float,
    *,
    structured: bool = False,
    block: int = 128,
    selector: Optional[Callable] = None,
):
    """One pruning round over a parameter tree. Dense leaves become
    {"w", "mask"} reps (core/lightweight.py dispatch); already-masked reps
    get their masks tightened (Formula 7: masks compose multiplicatively)."""
    sel = selector or _prunable
    mask_fn = (lambda w: block_prune_mask(w, ratio, block)) if structured else (
        lambda w: prune_mask(w, ratio)
    )

    def visit(path, leaf):
        if isinstance(leaf, dict) and "mask" in leaf and "w" in leaf:
            # Formula 7: tighten the mask — threshold over SURVIVORS only
            # (the quantile must ignore already-pruned zeros), i.e. total
            # below-threshold fraction z + p(1−z) for current sparsity z.
            w = leaf["w"] * leaf["mask"]
            z = 1.0 - jnp.mean(leaf["mask"].astype(jnp.float32))
            eff = jnp.clip(z + ratio * (1.0 - z), 0.0, 1.0)
            if structured:
                new_mask = block_prune_mask(w, float(eff), block) * leaf["mask"]
            else:
                theta = jnp.quantile(jnp.abs(w).reshape(-1).astype(jnp.float32), eff)
                new_mask = (jnp.abs(w) >= theta).astype(w.dtype) * leaf["mask"]
            return {"w": leaf["w"], "mask": new_mask}
        if sel(path, leaf):
            return {"w": leaf, "mask": mask_fn(leaf)}
        return leaf

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, dict) and "mask" in x
    )


def sparsity(params) -> float:
    """Fraction of pruned weights among maskable leaves."""
    zero, total = 0.0, 0.0

    def visit(leaf):
        nonlocal zero, total
        if isinstance(leaf, dict) and "mask" in leaf:
            zero += float(jnp.sum(leaf["mask"] == 0))
            total += leaf["mask"].size

    jax.tree.map(
        visit, params, is_leaf=lambda x: isinstance(x, dict) and "mask" in x
    )
    return zero / max(total, 1.0)


def prune_schedule(target: float, rounds: int) -> list:
    """Per-round incremental ratios reaching `target` total sparsity
    (paper: K=3 rounds to ~40%). Each round prunes the same fraction of the
    *surviving* weights: 1-(1-target)^(1/K)."""
    per = 1.0 - (1.0 - target) ** (1.0 / rounds)
    return [per] * rounds
